"""Shared trained cascade for the paper-table benchmarks (train once)."""
import functools

from repro.core.resnet_trainer import train_backtrack
from repro.data.synth_images import make_image_splits
from repro.models.resnet import CIResNet

N_CLASSES = 10


@functools.lru_cache(maxsize=1)
def trained_cascade():
    train, val, test = make_image_splits(n_classes=N_CLASSES, n_train=2048,
                                         n_val=512, n_test=1024, seed=11)
    model = CIResNet(n_blocks=1, n_classes=N_CLASSES, enhance_dim=64)
    report = train_backtrack(model, train, n_epochs=3, batch_size=128,
                             augment=False, test=test)
    return model, report, (train, val, test)
