from repro.core.confidence import (entropy_confidence, softmax_confidence,
                                   softmax_outputs)
from repro.core.calibration import (accuracy_vs_confidence, calibrate_thresholds,
                                    CalibrationResult, threshold_for_epsilon)
from repro.core.cascade import (cascade_evaluate, cascade_infer_sequential,
                                CascadeEvalResult)
from repro.core.training import (backtrack_training_plan, cascade_loss,
                                 trainability_mask)

__all__ = [
    "softmax_confidence", "softmax_outputs", "entropy_confidence",
    "calibrate_thresholds", "accuracy_vs_confidence", "CalibrationResult",
    "threshold_for_epsilon",
    "cascade_evaluate", "cascade_infer_sequential", "CascadeEvalResult",
    "backtrack_training_plan", "cascade_loss", "trainability_mask",
]
