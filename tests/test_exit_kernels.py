"""Exit-aware kernel parity + cohort-layout bit-identity.

Covers the skip-aware hot path end to end:

* the exit-masked decode-attention kernel vs its ref.py oracle over the
  live-mask edge cases (all-live, all-exited, single survivor), plus the
  per-row bit-identity guarantee for live rows;
* the fused exit-update kernel vs both its oracle and the dense
  :class:`~repro.core.policy.ExitDecider` scan (streaks, EMA fold, carry
  merge, padding shapes);
* ``cohort_layout="major"`` (exit-state dispatch: all-skip / mixed /
  all-run) decodes bit-identically to the legacy ``"copy"`` layout —
  tokens, exit indices, confidences, carried DecodeState AND cache bytes;
* the interpret auto-detection precedence and the cohort-capacity
  satellites.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.exec import StagedExecutor, effective_cohorts
from repro.core.policy import (ConfidenceMeasure, ExitDecider,
                               register_measure)
from repro.kernels import ref
from repro.kernels.backend import resolve_interpret
from repro.kernels.cohort_cache import cohort_scatter
from repro.kernels.decode_attention import decode_attention
from repro.kernels.exit_update import exit_update
from repro.kernels.megakernel import exit_head_update
from repro.kernels.ops import exit_update_fused, rmsnorm_fused
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request
from repro.serving.batching import cohort_capacity

RNG = np.random.default_rng(7)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# exit-masked decode attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("live", [
    [1, 1, 1, 1],          # all live
    [0, 0, 0, 0],          # all exited
    [0, 0, 1, 0],          # single survivor
    [1, 0, 1, 1],
])
def test_decode_attention_live_mask_vs_ref(live):
    B, KV, qpk, W, hd, t = 4, 2, 2, 96, 32, 57
    q = _arr((B, KV, qpk, hd))
    kc = _arr((B, KV, W, hd))
    vc = _arr((B, KV, W, hd))
    kpos = jnp.asarray(np.where(np.arange(W) <= t, np.arange(W), -1),
                       jnp.int32)
    got = decode_attention(q, kc, vc, t, kpos, jnp.asarray(live, jnp.int32),
                           tk=32)
    want = ref.ref_decode_attention(
        q.reshape(B, KV * qpk, hd), kc.transpose(0, 2, 1, 3),
        vc.transpose(0, 2, 1, 3), t, kpos, live=np.asarray(live, bool))
    np.testing.assert_allclose(np.asarray(got.reshape(B, KV * qpk, hd)),
                               np.asarray(want), rtol=1e-4, atol=1e-5)
    # dead rows zero-fill EXACTLY; live rows are BIT-identical to the
    # unmasked kernel (decode attention is batch-separable, so masking one
    # slot cannot perturb another)
    unmasked = decode_attention(q, kc, vc, t, kpos, tk=32)
    live_b = np.asarray(live, bool)
    assert (np.asarray(got)[~live_b] == 0.0).all()
    np.testing.assert_array_equal(np.asarray(got)[live_b],
                                  np.asarray(unmasked)[live_b])


def test_decode_attention_live_none_matches_all_ones():
    B, KV, qpk, W, hd, t = 2, 1, 4, 64, 32, 30
    q = _arr((B, KV, qpk, hd))
    kc = _arr((B, KV, W, hd))
    vc = _arr((B, KV, W, hd))
    kpos = jnp.asarray(np.where(np.arange(W) <= t, np.arange(W), -1),
                       jnp.int32)
    a = decode_attention(q, kc, vc, t, kpos, tk=32)
    b = decode_attention(q, kc, vc, t, kpos, jnp.ones((B,), jnp.int32),
                         tk=32)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fused exit-update kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,V", [(1, 128), (5, 151), (8, 4096), (3, 50304)])
@pytest.mark.parametrize("m,n,k,decay", [
    (0, 3, 0, 0.0),        # stateless mid-scan component
    (1, 3, 2, 0.0),        # patience@2 rewrite
    (2, 3, 0, 0.8),        # final component + EMA fold
    (2, 3, 3, 0.8),        # final component, patience streak still advances
    (0, 1, 0, 0.8),        # single-component cascade
])
def test_exit_update_kernel_vs_oracle(B, V, m, n, k, decay):
    logits = _arr((B, V), scale=3.0)
    args = (logits,
            jnp.asarray(RNG.integers(0, 2, B), bool),
            jnp.asarray(RNG.integers(0, V, B), jnp.int32),
            jnp.asarray(RNG.integers(0, n, B), jnp.int32),
            jnp.asarray(RNG.random(B), jnp.float32),
            jnp.asarray(RNG.integers(0, 3, B), jnp.int32),
            jnp.asarray(RNG.random(B), jnp.float32),
            jnp.asarray(RNG.integers(0, 2, B), bool))
    kw = dict(threshold=0.01, m=m, n_components=n, patience_k=k,
              ema_decay=decay)
    got = exit_update(*args, **kw)
    want = ref.ref_exit_update(*args, **kw)
    names = ("answered", "pred", "exit", "conf", "streak", "ema")
    for g, w, name in zip(got, want, names):
        np.testing.assert_allclose(
            np.asarray(g, np.float64), np.asarray(w, np.float64),
            rtol=1e-5, atol=1e-6, err_msg=f"{name} (m={m}, k={k})")


@pytest.mark.parametrize("measure", ["softmax_max", "patience@2"])
def test_fused_scan_matches_dense_decider(measure):
    """ExitDecider.scan_logits through the fused kernel == the dense
    measure_one + scan_component path (same gates/exits; confidences to
    float tolerance) across a multi-component scan with an EMA fold."""
    n_m, B, V = 3, 6, 512
    logits = [_arr((B, V), scale=4.0) for _ in range(n_m)]
    ths = (0.04, 0.04, 0.0)
    dense = ExitDecider(measure, thresholds=ths, use_kernels=False)
    fused = ExitDecider(measure, thresholds=ths, use_kernels=True)
    assert fused.fused_scan and not dense.fused_scan

    def scan(dec):
        carry = None
        for m in range(n_m):
            carry = dec.scan_logits(m, n_m, logits[m], ths, carry,
                                    ema_decay=(0.8 if m == n_m - 1 else 0.0))
            if m == 0:
                carry["ema"] = jnp.zeros((B,), jnp.float32)
                carry["act"] = jnp.ones((B,), bool)
        return carry

    a, b = scan(dense), scan(fused)
    np.testing.assert_array_equal(np.asarray(a["answered"]),
                                  np.asarray(b["answered"]))
    np.testing.assert_array_equal(np.asarray(a["pred"]), np.asarray(b["pred"]))
    np.testing.assert_array_equal(np.asarray(a["exit"]), np.asarray(b["exit"]))
    np.testing.assert_allclose(np.asarray(a["conf"]), np.asarray(b["conf"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a["ema"]), np.asarray(b["ema"]),
                               rtol=1e-5, atol=1e-6)
    if a["streak"] is not None:
        np.testing.assert_array_equal(np.asarray(a["streak"]),
                                      np.asarray(b["streak"]))


# ---------------------------------------------------------------------------
# per-segment exit-head megakernel
# ---------------------------------------------------------------------------

def _head_args(B, V, n):
    return (jnp.asarray(RNG.integers(0, 2, B), bool),
            jnp.asarray(RNG.integers(0, V, B), jnp.int32),
            jnp.asarray(RNG.integers(0, n, B), jnp.int32),
            jnp.asarray(RNG.random(B), jnp.float32),
            jnp.asarray(RNG.integers(0, 3, B), jnp.int32),
            jnp.asarray(RNG.random(B), jnp.float32),
            jnp.asarray(RNG.integers(0, 2, B), bool))


@pytest.mark.parametrize("B,d,V", [(8, 64, 512), (6, 32, 300)])
@pytest.mark.parametrize("m,n,k,decay", [
    (0, 3, 0, 0.0),        # stateless mid-scan component
    (1, 3, 2, 0.0),        # patience@2 rewrite
    (2, 3, 0, 0.8),        # final component + EMA fold
])
@pytest.mark.parametrize("live_pat", ["none", "rand", "block_dead"])
def test_exit_head_megakernel_vs_oracle(B, d, V, m, n, k, decay, live_pat):
    """The fused exit-head megakernel (rmsnorm + unembed matmul + streaming
    confidence + exit-update merge in ONE pallas_call) vs its pure-jnp
    oracle, including the live-mask early-out contract (dead rows pass
    every carry through unchanged; a fully dead batch block skips the
    matmul)."""
    h = _arr((B, d))
    w = jnp.asarray(1.0 + 0.1 * RNG.standard_normal(d), jnp.float32)
    head = _arr((d, V), scale=0.3)
    args = _head_args(B, V, n)
    live = {"none": None,
            "rand": jnp.asarray(RNG.integers(0, 2, B), bool),
            # the first full bt-block dead -> the grid early-out path
            "block_dead": jnp.asarray([0] * (B // 2) + [1] * (B - B // 2),
                                      bool)}[live_pat]
    kw = dict(threshold=0.5, m=m, n_components=n, patience_k=k,
              ema_decay=decay, live=live)
    got = exit_head_update(h, w, head, *args, bt=4, vt=128, **kw)
    want = ref.ref_exit_head_update(h, w, head, *args, **kw)
    names = ("answered", "pred", "exit", "conf", "streak", "ema")
    for g, x, name in zip(got, want, names):
        if np.asarray(g).dtype.kind in "bi":
            np.testing.assert_array_equal(np.asarray(g), np.asarray(x),
                                          err_msg=f"{name} ({live_pat})")
        else:
            np.testing.assert_allclose(
                np.asarray(g, np.float64), np.asarray(x, np.float64),
                rtol=1e-5, atol=1e-6, err_msg=f"{name} ({live_pat})")


def test_exit_head_megakernel_bitwise_vs_fused_kernels():
    """With MATCHING vocab tiles (the shipped defaults: both the megakernel
    and exit_update stream vt=2048 columns) the megakernel is BIT-identical
    to the unfused kernel pipeline rmsnorm_fused -> XLA matmul ->
    exit_update_fused — same streaming accumulation order, same rounding.
    This is the contract that lets cfg.kernel_tune.megakernel flip on
    without perturbing any pinned stream."""
    for (B, d, V) in [(8, 64, 512), (6, 32, 300), (16, 128, 2048)]:
        for (m, n, k, decay) in [(0, 3, 0, 0.0), (1, 3, 2, 0.0),
                                 (2, 3, 0, 0.8)]:
            h = _arr((B, d))
            w = jnp.asarray(1.0 + 0.1 * RNG.standard_normal(d), jnp.float32)
            head = _arr((d, V), scale=0.3)
            args = _head_args(B, V, n)
            kw = dict(threshold=0.5, m=m, n_components=n, patience_k=k,
                      ema_decay=decay)
            got = exit_head_update(h, w, head, *args, **kw)
            xn = rmsnorm_fused(h, w, interpret=True)
            want = exit_update_fused(xn @ head, *args, interpret=True, **kw)
            for gi, (g, x) in enumerate(zip(got, want)):
                np.testing.assert_array_equal(
                    np.asarray(g), np.asarray(x),
                    err_msg=f"output {gi} (B={B}, V={V}, m={m})")


def test_scan_hidden_matches_scan_logits():
    """ExitDecider.scan_hidden (megakernel route) == exit-head matmul +
    scan_logits (fused exit-update route) across a full scan, bitwise."""
    n_m, B, d, V = 3, 8, 64, 512
    ths = (0.04, 0.04, 0.0)
    dec = ExitDecider("patience@2", thresholds=ths, use_kernels=True,
                      kernel_interpret=True)
    assert dec.fused_scan
    hs = [_arr((B, d)) for _ in range(n_m)]
    w = jnp.asarray(1.0 + 0.1 * RNG.standard_normal(d), jnp.float32)
    head = _arr((d, V), scale=0.3)
    ca = cb = None
    for m in range(n_m):
        lg = rmsnorm_fused(hs[m], w, interpret=True) @ head
        ca = dec.scan_logits(m, n_m, lg, ths, ca)
        cb = dec.scan_hidden(m, n_m, hs[m], w, head, ths, cb)
    for key in ("answered", "pred", "exit", "conf", "streak"):
        np.testing.assert_array_equal(np.asarray(ca[key]),
                                      np.asarray(cb[key]), err_msg=key)


# ---------------------------------------------------------------------------
# cohort cache scatter (mixed-exit re-join)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape,C", [((3, 8, 16, 2, 8), 4), ((2, 6, 5), 3),
                                     ((4, 8), 2)])
def test_cohort_scatter_matches_at_set(shape, C):
    L, B = shape[0], shape[1]
    Bc = B // C
    dst = _arr(shape)
    for c in range(C):
        src = _arr((L, Bc) + shape[2:])
        got = cohort_scatter(dst, src, c, C, interpret=True)
        want = dst.at[:, c * Bc:(c + 1) * Bc].set(src)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        dst = got


def test_cohort_scatter_chain_equals_concat():
    """Chaining one scatter per cohort rebuilds exactly the concat of the
    per-cohort parts — the _mixed re-join replacement contract."""
    L, B, C = 2, 8, 4
    Bc = B // C
    parts = [_arr((L, Bc, 4, 8)) for _ in range(C)]
    cur = _arr((L, B, 4, 8))
    for c in range(C):
        cur = cohort_scatter(cur, parts[c], c, C, interpret=True)
    want = jnp.concatenate(parts, axis=1)
    np.testing.assert_array_equal(np.asarray(cur), np.asarray(want))


# ---------------------------------------------------------------------------
# cohort-layout bit-identity (the tentpole contract)
# ---------------------------------------------------------------------------

@register_measure("exit_kernels_parity")
class _ParityMeasure(ConfidenceMeasure):
    """Deterministic mixed-difficulty measure: confident iff the argmax
    token is even — exercises the mixed (per-cohort) dispatch branch."""

    name = "exit_kernels_parity"

    def __init__(self, arg: str = ""):
        del arg

    def __call__(self, logits):
        out = jnp.argmax(logits, axis=-1)
        return out, (out % 2 == 0).astype(jnp.float32)


@pytest.fixture(scope="module")
def tiny3():
    cfg = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32").with_cascade(n_components=3, exit_boundaries=(1, 2),
                                      n_cohorts=2)
    model = build_model(cfg)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _decode_trace(model, params, cfg, steps=6):
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 6)), jnp.int32)
    ex = StagedExecutor(model, cfg)
    cache = model.init_cache(4, 32)
    step = jax.jit(ex.decode_step)
    d, cache, state = ex.prefill(params, toks, cache)
    out = []
    for _ in range(steps):
        d, cache, state = step(params, d.prediction[:, None], cache, state)
        out.append((np.asarray(d.prediction), np.asarray(d.exit_index),
                    np.asarray(d.confidence)))
    return out, state, cache


@pytest.mark.parametrize("kernels", [False, True])
@pytest.mark.parametrize("measure,ths", [
    ("softmax_max", (0.0, 0.0, 0.0)),            # all-skip branch every step
    ("exit_kernels_parity", (0.5, 0.5, 0.0)),    # mixed per-cohort branch
    ("softmax_max", (1.1, 1.1, 0.0)),            # all-run branch every step
])
def test_cohort_major_bit_identical_to_copy(tiny3, kernels, measure, ths):
    """layout="major" (exit-state dispatch over cohort-major views) must
    reproduce layout="copy" EXACTLY: tokens, exit indices, confidences,
    segments_run, confidence EMA, and every cache byte."""
    cfg, model, params = tiny3
    base = cfg.replace(use_kernels=kernels).with_cascade(
        thresholds=ths, exit_mode="cond_batch", confidence=measure)
    o0, s0, c0 = _decode_trace(model, params,
                               base.with_cascade(cohort_layout="copy"))
    o1, s1, c1 = _decode_trace(model, params,
                               base.with_cascade(cohort_layout="major"))
    for a, b in zip(o0, o1):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(np.asarray(s0.segments_run),
                                  np.asarray(s1.segments_run))
    np.testing.assert_array_equal(np.asarray(s0.ema_conf),
                                  np.asarray(s1.ema_conf))
    for a, b in zip(jax.tree_util.tree_leaves(c0),
                    jax.tree_util.tree_leaves(c1)):
        assert bool(jnp.all(a == b)), "cache bytes diverged between layouts"


def test_select_matches_cond_batch_in_major_layout(tiny3):
    """exit_mode stays an execution strategy in the major layout: the
    fixed-graph select mode and the dispatching cond_batch mode produce
    identical streams and state (kernels on, all-skip dominant)."""
    cfg, model, params = tiny3
    base = cfg.replace(use_kernels=True).with_cascade(
        thresholds=(0.02, 0.02, 0.0), cohort_layout="major")
    o0, s0, _ = _decode_trace(model, params,
                              base.with_cascade(exit_mode="select"))
    o1, s1, _ = _decode_trace(model, params,
                              base.with_cascade(exit_mode="cond_batch"))
    for a, b in zip(o0, o1):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(np.asarray(s0.ema_conf),
                                  np.asarray(s1.ema_conf))


# ---------------------------------------------------------------------------
# megakernel / cohort-scatter end-to-end stream identity
# ---------------------------------------------------------------------------

def _caches_equal(c0, c1):
    for a, b in zip(jax.tree_util.tree_leaves(c0),
                    jax.tree_util.tree_leaves(c1)):
        assert bool(jnp.all(a == b)), "cache bytes diverged"


@pytest.mark.parametrize("measure,ths", [
    ("softmax_max", (0.02, 0.02, 0.0)),
    ("patience@2", (0.04, 0.04, 0.0)),
])
@pytest.mark.parametrize("exit_mode", ["cond_batch", "select"])
def test_megakernel_decode_streams_bit_identical(tiny3, measure, ths,
                                                 exit_mode):
    """Flipping cfg.kernel_tune.megakernel must not perturb ANY stream:
    tokens, exit indices, confidences, EMA, segment counts, cache bytes —
    the megakernel and the unfused kernel path share tile sizes, hence
    accumulation order, hence bits."""
    cfg, model, params = tiny3
    base = cfg.replace(use_kernels=True).with_cascade(
        thresholds=ths, confidence=measure, exit_mode=exit_mode,
        cohort_layout="major")
    on = base.with_kernel_tune(megakernel=True)
    assert StagedExecutor(model, on).use_megakernel
    assert model.exit_head_params(params, 0) is not None
    o0, s0, c0 = _decode_trace(model, params, base)
    o1, s1, c1 = _decode_trace(model, params, on)
    for a, b in zip(o0, o1):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(np.asarray(s0.segments_run),
                                  np.asarray(s1.segments_run))
    np.testing.assert_array_equal(np.asarray(s0.ema_conf),
                                  np.asarray(s1.ema_conf))
    _caches_equal(c0, c1)


def test_cohort_scatter_decode_bit_identical(tiny3):
    """cfg.kernel_tune.cohort_scatter replaces the mixed-branch per-cohort
    concat with aliased partial writes — streams and cache bytes must not
    move (the parity measure forces the mixed dispatch every step)."""
    cfg, model, params = tiny3
    base = cfg.replace(use_kernels=True).with_cascade(
        thresholds=(0.5, 0.5, 0.0), confidence="exit_kernels_parity",
        exit_mode="cond_batch", cohort_layout="major")
    on = base.with_kernel_tune(cohort_scatter=True)
    assert StagedExecutor(model, on).use_cohort_scatter
    o0, s0, c0 = _decode_trace(model, params, base)
    o1, s1, c1 = _decode_trace(model, params, on)
    for a, b in zip(o0, o1):
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
    np.testing.assert_array_equal(np.asarray(s0.segments_run),
                                  np.asarray(s1.segments_run))
    _caches_equal(c0, c1)


@pytest.fixture(scope="module")
def eng_params():
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    return cfg, model.init(jax.random.PRNGKey(0))


@pytest.mark.parametrize("paged,runtime", [
    (False, "host"), (True, "host"), (False, "device"), (True, "device"),
])
def test_megakernel_engine_streams_identical(eng_params, paged, runtime):
    """The serving engine's token/exit streams are identical with the
    megakernel + cohort scatter on vs off, across dense/paged caches and
    the host/device decode runtimes."""
    cfg0, params = eng_params
    cascade = dict(thresholds=(0.6, 0.0), confidence="patience@2",
                   exit_mode="cond_batch", n_cohorts=2)
    fins = {}
    for mk in (False, True):
        cfg = cfg0.replace(use_kernels=True,
                           kernel_interpret=True).with_cascade(**cascade)
        if paged:
            cfg = cfg.with_paged_cache(layout="paged", block_size=8,
                                       num_blocks=0)
        if mk:
            cfg = cfg.with_kernel_tune(megakernel=True, cohort_scatter=True)
        kw = dict(lane_batch=2, n_lanes=2, cache_len=32)
        if runtime == "device":
            kw.update(runtime="device", chunk=4)
        model = build_model(cfg)
        eng = CascadeServingEngine(cfg, model, params, **kw)
        rng = np.random.default_rng(3)
        for i in range(4):
            eng.submit(Request(
                rid=i,
                prompt=rng.integers(1, 50, size=rng.integers(2, 7))
                .astype(np.int32),
                max_new_tokens=4))
        fins[mk] = eng.run(max_ticks=200)
    assert set(fins[False]) == set(fins[True]) == {0, 1, 2, 3}
    for rid in fins[False]:
        assert fins[False][rid]["tokens"] == fins[True][rid]["tokens"], rid
        assert (fins[False][rid]["exit_depths"]
                == fins[True][rid]["exit_depths"]), rid


# ---------------------------------------------------------------------------
# satellites: interpret auto-detection, cohort capacity
# ---------------------------------------------------------------------------

def test_resolve_interpret_precedence(monkeypatch):
    monkeypatch.delenv("REPRO_KERNEL_INTERPRET", raising=False)
    on_cpu = jax.default_backend() != "tpu"
    assert resolve_interpret(None) is on_cpu     # auto-detect
    assert resolve_interpret(True) is True       # explicit override wins
    assert resolve_interpret(False) is False
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "0")
    assert resolve_interpret(None) is False      # env forces compiled
    monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
    assert resolve_interpret(None) is True       # env forces interpreter
    assert resolve_interpret(False) is False     # explicit still wins


def test_cohort_capacity_rounds_up():
    assert cohort_capacity(4, 2) == 4
    assert cohort_capacity(3, 2) == 4
    assert cohort_capacity(1, 4) == 4
    assert cohort_capacity(5, 4) == 8
    assert cohort_capacity(6, 1) == 6


def test_effective_cohorts_warns_once_on_degradation():
    from repro.core import exec as exec_mod
    exec_mod._COHORT_WARNED.discard((2, 3))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert effective_cohorts(2, 4, warn=True) == 2      # divides: silent
        assert effective_cohorts(2, 3, warn=True) == 1      # degrades: warns
        assert effective_cohorts(2, 3, warn=True) == 1      # ... once
    msgs = [str(x.message) for x in w]
    assert sum("degrading" in m for m in msgs) == 1
    assert any("cohort_capacity" in m for m in msgs)


def test_engine_rounds_lane_capacity_to_cohort_multiple():
    """The engine admits with cohort-multiple lanes, so the effective
    cohort count never silently degrades below the config's request."""
    cfg = reduced(get_config("qwen2.5-3b")).replace(
        dtype="float32").with_cascade(n_cohorts=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=3, n_lanes=1,
                               cache_len=32)
    assert eng.lane_batch == 4
    assert eng.cohorts == 2
    assert all(len(lane["slots"]) == 4 for lane in eng.lanes)
    assert eng.stats()["lane_batch"] == 4
