from repro.kernels import ref
from repro.kernels.backend import resolve_interpret
from repro.kernels.ops import (decode_attention_cache, exit_update_fused,
                               flash_attention_bshd, rmsnorm_fused,
                               softmax_confidence_fused)

__all__ = ["ref", "resolve_interpret", "softmax_confidence_fused",
           "rmsnorm_fused", "flash_attention_bshd",
           "decode_attention_cache", "exit_update_fused"]
