"""Analytic MAC / FLOP accounting checks (the paper's §6.2 metric)."""
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.core.macs import (active_param_count, exit_head_macs, model_flops,
                             param_count, resnet_component_macs,
                             segment_macs_per_token)


def test_resnet110_canonical_macs():
    """CI-RESNET(18) must land on ResNet-110's canonical ~253M MACs and the
    paper's observed max-speedup ratio ~2.95."""
    p = resnet_component_macs(18, 10)
    assert len(p) == 3 and p[0] < p[1] < p[2]
    assert 2.4e8 < p[2] < 2.7e8
    assert 2.9 < p[2] / p[0] < 3.05


def test_resnet_macs_scale_with_depth_and_classes():
    p3 = resnet_component_macs(3, 10)
    p9 = resnet_component_macs(9, 10)
    assert p9[2] > 2.5 * p3[2]
    p100 = resnet_component_macs(3, 100)
    assert p100[2] > p3[2]                     # bigger classifier head


@pytest.mark.parametrize("arch", [a for a in list_configs()
                                  if a != "ci-resnet18"])
def test_segment_macs_monotone_prefix(arch):
    cfg = get_config(arch)
    prefix = segment_macs_per_token(cfg, kv_len=4096)
    assert len(prefix) == cfg.cascade.n_components
    assert all(b > a for a, b in zip(prefix, prefix[1:]))
    assert prefix[0] > exit_head_macs(cfg) > 0


def test_moe_active_params_less_than_total():
    cfg = get_config("mixtral-8x7b")
    assert active_param_count(cfg) < param_count(cfg)
    # mixtral: ~47B total, ~13B active — accept generous analytic bounds
    assert 35e9 < param_count(cfg) < 60e9
    assert 9e9 < active_param_count(cfg) < 18e9


def test_known_param_counts_roughly():
    """Analytic N vs the models' public parameter counts (±35% — our zoo
    adds untied exit/unembed heads and simplified blocks)."""
    expect = {"yi-9b": 9e9, "deepseek-coder-33b": 33e9,
              "qwen2.5-3b": 3e9, "minitron-4b": 4e9}
    for arch, n in expect.items():
        got = param_count(get_config(arch))
        assert 0.65 * n < got < 1.6 * n, (arch, got)


def test_model_flops_train_vs_infer():
    cfg = get_config("yi-9b")
    assert model_flops(cfg, 1000, True) == 3 * model_flops(cfg, 1000, False)


def test_window_caps_attention_macs():
    cfg = get_config("mixtral-8x7b")           # window 4096
    short = segment_macs_per_token(cfg, kv_len=4096)[-1]
    long = segment_macs_per_token(cfg, kv_len=1_000_000)[-1]
    assert long == short                        # SWA: kv term capped
    nf = cfg.replace(attn_window=0)
    assert segment_macs_per_token(nf, kv_len=1_000_000)[-1] > long
