"""Block registry: every architecture family is a sequence of *block kinds*.

A block kind provides:
  init(key, cfg)                      -> params (one layer)
  apply(cfg, params, h, ctx, cache)   -> (h, new_cache, aux)
  init_cache(cfg, batch, W, dtype)    -> per-layer cache pytree ({} if none)
  backfill(cfg, params, h, ctx, cache)-> new_cache   (cascade state backfill:
        update this layer's KV / recurrent state from the early-exit hidden
        state WITHOUT computing the layer's output — the cheap path that keeps
        deeper caches coherent when a token exits early.)

``ctx`` carries everything invariant across the layers of a stage:
  mode: "full" | "decode"      (static, via closure)
  positions: (B,S) absolute positions of the current tokens (full mode)
  t: scalar int32 current decode position (decode mode)
  kpos: (W,) absolute position of each KV slot (-1 empty)  [attention kinds]
  write_slots: (S,) ring slots to write during full-mode cache fill
  cross: (B,T,d) cross-attention memory (vlm image / whisper audio), or None
  shared: shared-parameter dict for 'attn_shared' blocks
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn, ssm, xlstm
from repro.models.layers import (attend_chunked, attend_decode, attend_full,
                                 attn_init, mlp_apply, mlp_init, norm_apply,
                                 norm_init, pick_attend, qkv_project)
from repro.models.moe import moe_apply, moe_init

ZERO = jnp.zeros((), jnp.float32)


@dataclasses.dataclass(frozen=True)
class BlockDef:
    init: Callable
    apply: Callable
    init_cache: Callable
    backfill: Callable


# ---------------------------------------------------------------------------
# attention cache helpers (ring buffer, shared by all attention kinds)
# ---------------------------------------------------------------------------

def attn_cache_init(cfg, batch, W, dtype):
    hd = cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, W, cfg.n_kv_heads, hd), dtype)}


def _write_full(cache, k, v, gather_idx):
    """Fill ring slots from a full-sequence prefill.  gather_idx: (W,) —
    for each cache slot, the token index that lands in it (-1 = slot stays
    empty).  A gather per slot avoids nondeterministic duplicate scatters."""
    if cache is None:
        return None
    valid = gather_idx >= 0
    idx = jnp.maximum(gather_idx, 0)
    sel = valid[None, :, None, None]
    ck = jnp.where(sel, k[:, idx].astype(cache["k"].dtype), cache["k"])
    cv = jnp.where(sel, v[:, idx].astype(cache["v"].dtype), cache["v"])
    return {"k": ck, "v": cv}


def _write_decode(cache, k, v, slot):
    ck = lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
    return {"k": ck, "v": cv}


# ---------------------------------------------------------------------------
# paged-layout variants (cache_layout="paged"): the per-layer cache leaf is a
# SHARED block store (num_blocks, block_size, kv, hd) addressed through the
# slot's block-table row ``table`` (B, nblk) — ring position p lives at
# (table[b, p // bs], p % bs).  Dead/uncovered rows point at the trash block
# 0: duplicate scatters there are nondeterministic but the per-slot kpos ring
# masks those positions out of every read (masking, not zeroing, is the
# coherence mechanism — see DESIGN.md).
# ---------------------------------------------------------------------------

def _write_decode_paged(cache, k, v, slot, table):
    """One decode token through the block table.  slot = t % W (scalar);
    k/v (B, 1, kv, hd)."""
    bs = cache["k"].shape[1]
    phys = jnp.take(table, slot // bs, axis=1)      # (B,) physical blocks
    off = slot % bs
    ck = cache["k"].at[phys, off].set(k[:, 0].astype(cache["k"].dtype))
    cv = cache["v"].at[phys, off].set(v[:, 0].astype(cache["v"].dtype))
    return {"k": ck, "v": cv}


def _write_full_paged(cache, k, v, gather_idx, table):
    """Prefill fill through the block table: gather the current logical
    ring view, apply the same valid-masked merge as :func:`_write_full`,
    scatter whole table rows back."""
    if cache is None:
        return None
    B, nblk = table.shape
    bs = cache["k"].shape[1]
    valid = gather_idx >= 0
    idx = jnp.maximum(gather_idx, 0)
    sel = valid[None, :, None, None]

    def merge(store, x):
        cur = store[table].reshape((B, nblk * bs) + store.shape[2:])
        new = jnp.where(sel, x[:, idx].astype(store.dtype), cur)
        return store.at[table].set(
            new.reshape((B, nblk, bs) + store.shape[2:]))

    return {"k": merge(cache["k"], k), "v": merge(cache["v"], v)}


def _paged_kv_view(cfg, cache, table):
    """The slot-logical (B, W, kv, hd) ring view of a paged store — the
    gather that makes the downstream attention (reference or Pallas
    kernel) IDENTICAL to the dense layout's, and therefore bit-identical:
    re-tiling attention to block granularity would change the online-
    softmax accumulation order."""
    if cfg.use_kernels:
        from repro.kernels.ops import paged_gather
        return (paged_gather(cache["k"], table,
                             interpret=cfg.kernel_interpret),
                paged_gather(cache["v"], table,
                             interpret=cfg.kernel_interpret))
    B, nblk = table.shape
    bs = cache["k"].shape[1]

    def view(store):
        return store[table].reshape((B, nblk * bs) + store.shape[2:])

    return view(cache["k"]), view(cache["v"])


def _self_attention(cfg, params, h, ctx, cache):
    """Shared self-attention sublayer logic for full and decode modes."""
    x = norm_apply(params["norm"], cfg, h)
    if ctx["mode"] == "full":
        q, k, v = qkv_project(params, cfg, x, rope_positions=ctx["positions"])
        S = x.shape[1]
        if cfg.use_kernels and S % 128 == 0 and q.shape[-1] % 8 == 0:
            from repro.kernels.ops import flash_attention_bshd
            out = flash_attention_bshd(q, k, v, causal=True,
                                       window=cfg.attn_window,
                                       interpret=cfg.kernel_interpret)
        else:
            attend = pick_attend(cfg, S, S, differentiable=cache is None)
            out = attend(q, k, v, ctx["positions"], ctx["positions"],
                         window=cfg.attn_window, causal=True)
        table = ctx.get("block_table")
        if cache is None:
            new_cache = None
        elif table is not None:
            new_cache = _write_full_paged(cache, k, v, ctx["write_slots"],
                                          table)
        else:
            new_cache = _write_full(cache, k, v, ctx["write_slots"])
    else:
        t = ctx["t"]
        q, k, v = qkv_project(params, cfg, x,
                              rope_positions=jnp.full((1, 1), t))
        slot = ctx["slot"]
        table = ctx.get("block_table")
        if table is not None:
            new_cache = _write_decode_paged(cache, k, v, slot, table)
            kv_k, kv_v = _paged_kv_view(cfg, new_cache, table)
        else:
            new_cache = _write_decode(cache, k, v, slot)
            kv_k, kv_v = new_cache["k"], new_cache["v"]
        # dense: lane-wide (W,) ring; paged: per-slot (B, W) ring
        kpos = ctx["kpos"].at[..., slot].set(t)
        if cfg.use_kernels and q.shape[-1] % 8 == 0:
            from repro.kernels.ops import decode_attention_cache
            # ctx["live"] is the per-slot exit mask threaded down from the
            # carried DecodeState: dead slots' (b, h, ik) grid cells
            # early-out inside the kernel (zero-filled rows; live rows are
            # bit-identical — decode attention is batch-separable)
            out = decode_attention_cache(q, kv_k, kv_v,
                                         t, kpos, window=cfg.attn_window,
                                         live=ctx.get("live"),
                                         interpret=cfg.kernel_interpret)
        else:
            out = attend_decode(q, kv_k, kv_v, t, kpos,
                                window=cfg.attn_window)
    B, S = x.shape[0], x.shape[1]
    out = out.reshape(B, S, -1) @ params["wo"].astype(x.dtype)
    return out, new_cache


def _attn_backfill(cfg, params, h, ctx, cache):
    """KV backfill: project k/v from the exit hidden state, write, skip attn."""
    if cache is None:
        return None
    x = norm_apply(params["norm"], cfg, h)
    hd = cfg.resolved_head_dim
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    B, S = x.shape[0], x.shape[1]
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    table = ctx.get("block_table")
    if ctx["mode"] == "decode":
        from repro.models.layers import apply_rope
        k = apply_rope(k, jnp.full((1, 1), ctx["t"]), cfg.rope_theta)
        if table is not None:
            return _write_decode_paged(cache, k, v, ctx["slot"], table)
        return _write_decode(cache, k, v, ctx["slot"])
    from repro.models.layers import apply_rope
    k = apply_rope(k, ctx["positions"], cfg.rope_theta)
    if table is not None:
        return _write_full_paged(cache, k, v, ctx["write_slots"], table)
    return _write_full(cache, k, v, ctx["write_slots"])


# ---------------------------------------------------------------------------
# dense / moe blocks
# ---------------------------------------------------------------------------

def dense_init_block(key, cfg):
    ka, km = nn.split_keys(key, 2)
    return {"attn": attn_init(ka, cfg), "mlp": mlp_init(km, cfg)}


def dense_apply(cfg, params, h, ctx, cache):
    a, new_cache = _self_attention(cfg, params["attn"], h, ctx, cache)
    h = h + a
    m = mlp_apply(params["mlp"], cfg,
                  norm_apply(params["mlp"]["norm"], cfg, h))
    return h + m, new_cache, ZERO


def dense_backfill(cfg, params, h, ctx, cache):
    return _attn_backfill(cfg, params["attn"], h, ctx, cache)


def moe_init_block(key, cfg):
    ka, km = nn.split_keys(key, 2)
    return {"attn": attn_init(ka, cfg), "moe": moe_init(km, cfg)}


def moe_apply_block(cfg, params, h, ctx, cache):
    a, new_cache = _self_attention(cfg, params["attn"], h, ctx, cache)
    h = h + a
    x = norm_apply(params["moe"]["norm"], cfg, h)
    m, aux = moe_apply(params["moe"], cfg, x)
    return h + m, new_cache, aux


# ---------------------------------------------------------------------------
# mamba / hybrid shared-attention blocks
# ---------------------------------------------------------------------------

def mamba_init_block(key, cfg):
    return {"ssm": ssm.ssm_init(key, cfg)}


def mamba_apply(cfg, params, h, ctx, cache):
    x = norm_apply(params["ssm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        y, new_cache = ssm.ssm_forward_full(params["ssm"], cfg, x, cache)
    else:
        y, new_cache = ssm.ssm_decode_step(params["ssm"], cfg, x, cache)
    return h + y, new_cache, ZERO


def mamba_cache(cfg, batch, W, dtype):
    del W
    return ssm.ssm_init_cache(cfg, batch, dtype)


def mamba_backfill(cfg, params, h, ctx, cache):
    """SSM state backfill = run the recurrence but skip out_proj/gating."""
    if cache is None:
        return None
    x = norm_apply(params["ssm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        _, new_cache = ssm.ssm_forward_full(params["ssm"], cfg, x, cache)
    else:
        _, new_cache = ssm.ssm_decode_step(params["ssm"], cfg, x, cache)
    return new_cache


def shared_attn_init(key, cfg):
    """Per-invocation params of the zamba2-style shared block: LoRA deltas on
    q/k/v.  The shared full-rank weights live in ctx['shared']."""
    r = 16
    hd = cfg.resolved_head_dim
    k1, k2, k3, k4, k5, k6 = nn.split_keys(key, 6)
    return {
        "lora_q_a": nn.dense_init(k1, (cfg.d_model, r)),
        "lora_q_b": nn.zeros_init(k2, (r, cfg.n_heads * hd)),
        "lora_k_a": nn.dense_init(k3, (cfg.d_model, r)),
        "lora_k_b": nn.zeros_init(k4, (r, cfg.n_kv_heads * hd)),
        "lora_v_a": nn.dense_init(k5, (cfg.d_model, r)),
        "lora_v_b": nn.zeros_init(k6, (r, cfg.n_kv_heads * hd)),
    }


def shared_attn_apply(cfg, params, h, ctx, cache):
    shared = ctx["shared"]          # full attention + mlp params, shared
    lora = params
    # merge LoRA into the projections by adding low-rank outputs
    attn_p = dict(shared["attn"])

    def proj_with_lora(x, w, a, b):
        return x @ w.astype(x.dtype) + (x @ a.astype(x.dtype)) @ b.astype(x.dtype)

    x = norm_apply(attn_p["norm"], cfg, h)
    hd = cfg.resolved_head_dim
    B, S = x.shape[0], x.shape[1]
    q = proj_with_lora(x, attn_p["wq"], lora["lora_q_a"], lora["lora_q_b"])
    k = proj_with_lora(x, attn_p["wk"], lora["lora_k_a"], lora["lora_k_b"])
    v = proj_with_lora(x, attn_p["wv"], lora["lora_v_a"], lora["lora_v_b"])
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    from repro.models.layers import apply_rope
    if ctx["mode"] == "full":
        q = apply_rope(q, ctx["positions"], cfg.rope_theta)
        k = apply_rope(k, ctx["positions"], cfg.rope_theta)
        attend = pick_attend(cfg, S, S, differentiable=cache is None)
        out = attend(q, k, v, ctx["positions"], ctx["positions"],
                     window=0, causal=True)
        new_cache = (_write_full(cache, k, v, ctx["write_slots"])
                     if cache is not None else None)
    else:
        t = ctx["t"]
        q = apply_rope(q, jnp.full((1, 1), t), cfg.rope_theta)
        k = apply_rope(k, jnp.full((1, 1), t), cfg.rope_theta)
        new_cache = _write_decode(cache, k, v, ctx["slot"])
        kpos = ctx["kpos"].at[..., ctx["slot"]].set(t)
        out = attend_decode(q, new_cache["k"], new_cache["v"], t, kpos)
    out = out.reshape(B, S, -1) @ attn_p["wo"].astype(x.dtype)
    h = h + out
    m = mlp_apply(shared["mlp"], cfg, norm_apply(shared["mlp"]["norm"], cfg, h))
    return h + m, new_cache, ZERO


def shared_attn_backfill(cfg, params, h, ctx, cache):
    if cache is None:
        return None
    return _attn_backfill(cfg, ctx["shared"]["attn"], h, ctx, cache)


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def mlstm_init_block(key, cfg):
    return {"mlstm": xlstm.mlstm_init(key, cfg)}


def mlstm_apply(cfg, params, h, ctx, cache):
    x = norm_apply(params["mlstm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        y, new_cache = xlstm.mlstm_forward_full(params["mlstm"], cfg, x, cache)
    else:
        y, new_cache = xlstm.mlstm_decode_step(params["mlstm"], cfg, x, cache)
    return h + y, new_cache, ZERO


def mlstm_cache(cfg, batch, W, dtype):
    del W
    return xlstm.mlstm_init_cache(cfg, batch, dtype)


def mlstm_backfill(cfg, params, h, ctx, cache):
    if cache is None:
        return None
    x = norm_apply(params["mlstm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        _, new_cache = xlstm.mlstm_forward_full(params["mlstm"], cfg, x, cache)
    else:
        _, new_cache = xlstm.mlstm_decode_step(params["mlstm"], cfg, x, cache)
    return new_cache


def slstm_init_block(key, cfg):
    return {"slstm": xlstm.slstm_init(key, cfg)}


def slstm_apply(cfg, params, h, ctx, cache):
    x = norm_apply(params["slstm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        y, new_cache = xlstm.slstm_forward_full(params["slstm"], cfg, x, cache)
    else:
        y, new_cache = xlstm.slstm_decode_step(params["slstm"], cfg, x, cache)
    return h + y, new_cache, ZERO


def slstm_cache(cfg, batch, W, dtype):
    del W
    return xlstm.slstm_init_cache(cfg, batch, dtype)


def slstm_backfill(cfg, params, h, ctx, cache):
    if cache is None:
        return None
    x = norm_apply(params["slstm"]["norm"], cfg, h)
    if ctx["mode"] == "full":
        _, new_cache = xlstm.slstm_forward_full(params["slstm"], cfg, x, cache)
    else:
        _, new_cache = xlstm.slstm_decode_step(params["slstm"], cfg, x, cache)
    return new_cache


# ---------------------------------------------------------------------------
# cross-attention blocks (vlm / whisper)
# ---------------------------------------------------------------------------

def _cross_attention(cfg, params, h, ctx, cache):
    """Cross-attend to ctx['cross'] (B,T,d).  Cross K/V cached at prefill."""
    x = norm_apply(params["norm"], cfg, h)
    hd = cfg.resolved_head_dim
    B, S = x.shape[0], x.shape[1]
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    if cache is not None and ctx["mode"] == "decode":
        k, v = cache["k"].astype(x.dtype), cache["v"].astype(x.dtype)
        new_cache = cache
    else:
        mem = ctx["cross"].astype(x.dtype)
        T = mem.shape[1]
        k = (mem @ params["wk"].astype(x.dtype)).reshape(B, T, cfg.n_kv_heads, hd)
        v = (mem @ params["wv"].astype(x.dtype)).reshape(B, T, cfg.n_kv_heads, hd)
        new_cache = ({"k": k.astype(cache["k"].dtype),
                      "v": v.astype(cache["v"].dtype)}
                     if cache is not None else None)
    T = k.shape[1]
    kpos = jnp.arange(T)
    qpos = jnp.full((S,), T, jnp.int32)  # non-causal: all memory visible
    out = attend_full(q, k, v, qpos, kpos, window=0, causal=False)
    out = out.reshape(B, S, -1) @ params["wo"].astype(x.dtype)
    if "gate" in params:  # llama-3.2-vision tanh gating
        out = out * jnp.tanh(params["gate"]).astype(out.dtype)
    return out, new_cache


def cross_cache_init(cfg, batch, W, dtype):
    del W
    hd = cfg.resolved_head_dim
    T = cfg.n_image_tokens or cfg.n_audio_frames
    return {"k": jnp.zeros((batch, T, cfg.n_kv_heads, hd), dtype),
            "v": jnp.zeros((batch, T, cfg.n_kv_heads, hd), dtype)}


def xattn_init_block(key, cfg):
    ka, km = nn.split_keys(key, 2)
    return {"xattn": attn_init(ka, cfg, cross=True), "mlp": mlp_init(km, cfg)}


def xattn_apply(cfg, params, h, ctx, cache):
    a, new_cache = _cross_attention(cfg, params["xattn"], h, ctx, cache)
    h = h + a
    m = mlp_apply(params["mlp"], cfg, norm_apply(params["mlp"]["norm"], cfg, h))
    return h + m, new_cache, ZERO


def xattn_backfill(cfg, params, h, ctx, cache):
    return cache  # cross K/V depend only on the image/audio memory


def encdec_init_block(key, cfg):
    ka, kx, km = nn.split_keys(key, 3)
    return {"attn": attn_init(ka, cfg), "xattn": attn_init(kx, cfg),
            "mlp": mlp_init(km, cfg)}


def encdec_apply(cfg, params, h, ctx, cache):
    self_cache = cache["self"] if cache is not None else None
    a, new_self = _self_attention(cfg, params["attn"], h, ctx, self_cache)
    h = h + a
    cross_cache = cache["cross"] if cache is not None else None
    c, new_cross = _cross_attention(cfg, params["xattn"], h, ctx, cross_cache)
    h = h + c
    m = mlp_apply(params["mlp"], cfg, norm_apply(params["mlp"]["norm"], cfg, h))
    new_cache = ({"self": new_self, "cross": new_cross}
                 if cache is not None else None)
    return h + m, new_cache, ZERO


def encdec_cache(cfg, batch, W, dtype):
    return {"self": attn_cache_init(cfg, batch, W, dtype),
            "cross": cross_cache_init(cfg, batch, W, dtype)}


def encdec_backfill(cfg, params, h, ctx, cache):
    if cache is None:
        return None
    return {"self": _attn_backfill(cfg, params["attn"], h, ctx, cache["self"]),
            "cross": cache["cross"]}


def enc_init_block(key, cfg):
    ka, km = nn.split_keys(key, 2)
    return {"attn": attn_init(ka, cfg), "mlp": mlp_init(km, cfg)}


def enc_apply(cfg, params, h, ctx, cache):
    """Bidirectional encoder layer (whisper encoder)."""
    x = norm_apply(params["attn"]["norm"], cfg, h)
    S = x.shape[1]
    pos = jnp.arange(S)
    q, k, v = qkv_project(params["attn"], cfg, x, rope_positions=None)
    out = attend_full(q, k, v, pos, pos, window=0, causal=False)
    out = out.reshape(x.shape[0], S, -1) @ params["attn"]["wo"].astype(x.dtype)
    h = h + out
    m = mlp_apply(params["mlp"], cfg, norm_apply(params["mlp"]["norm"], cfg, h))
    return h + m, None, ZERO


def _no_cache(cfg, batch, W, dtype):
    return {}


def _no_backfill(cfg, params, h, ctx, cache):
    return cache


BLOCKS: Dict[str, BlockDef] = {
    "dense": BlockDef(dense_init_block, dense_apply,
                      lambda cfg, b, W, dt: attn_cache_init(cfg, b, W, dt),
                      dense_backfill),
    "moe": BlockDef(moe_init_block, moe_apply_block,
                    lambda cfg, b, W, dt: attn_cache_init(cfg, b, W, dt),
                    dense_backfill),
    "mamba": BlockDef(mamba_init_block, mamba_apply, mamba_cache,
                      mamba_backfill),
    "attn_shared": BlockDef(shared_attn_init, shared_attn_apply,
                            lambda cfg, b, W, dt: attn_cache_init(cfg, b, W, dt),
                            shared_attn_backfill),
    "mlstm": BlockDef(mlstm_init_block, mlstm_apply, mlstm_cache,
                      mlstm_backfill),
    "slstm": BlockDef(slstm_init_block, slstm_apply, slstm_cache,
                      slstm_backfill),
    "xattn": BlockDef(xattn_init_block, xattn_apply, cross_cache_init,
                      xattn_backfill),
    "encdec": BlockDef(encdec_init_block, encdec_apply, encdec_cache,
                       encdec_backfill),
    "enc": BlockDef(enc_init_block, enc_apply, _no_cache, _no_backfill),
}


def layer_kinds(cfg) -> list[str]:
    """The per-layer kind sequence of an architecture."""
    L = cfg.n_layers
    fam = cfg.family
    if fam == "dense":
        return ["dense"] * L
    if fam == "moe":
        return ["moe"] * L
    if fam == "ssm":  # xlstm
        if cfg.slstm_every:
            return ["slstm" if (i % cfg.slstm_every == cfg.slstm_every - 1)
                    else "mlstm" for i in range(L)]
        return ["mamba"] * L
    if fam == "hybrid":
        k = cfg.shared_attn_every
        return ["attn_shared" if (k and i % k == 0) else "mamba"
                for i in range(L)]
    if fam == "vlm":
        k = cfg.cross_attn_every
        return ["xattn" if (k and i % k == k - 1) else "dense"
                for i in range(L)]
    if fam == "audio":
        return ["encdec"] * L
    raise ValueError(f"unknown family {fam}")
