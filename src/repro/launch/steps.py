"""Step builders shared by train.py, serve.py, and dryrun.py.

``make_train_step``: joint-loss cascade training step (fwd + bwd + AdamW).
``make_prefill_step`` / ``make_serve_step``: inference steps built on the
staged executor; serve_step is ONE new token against a KV/state cache (what
the decode shapes lower).  ``make_decode_loop_step``: the device-resident
multi-token variant — a ``lax.while_loop`` over the staged executor that
decodes up to K tokens per dispatch into preallocated device buffers (the
body of :class:`repro.serving.runtime.DeviceDecodeLoop`).

Serve-step signature (the DecodeState redesign)::

    serve_step(params, token, cache, state, extra)
        -> (prediction, exit_index, confidence, cache, state)

``state`` is a :class:`repro.core.exec.DecodeState` pytree carrying the
position cursor, active mask, stateful-measure carry (patience streaks) and
segment execution counters — so stateful measures now lower through the
dry-run and serve end-to-end instead of raising.  The old
``(params, token, t, cache, extra)`` signature is gone: the scalar ``t``
rides in ``state.t`` (see README "Migration" for the one-line port).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.exec import DecodeState, StagedExecutor, init_decode_state
from repro.core.policy import ExitDecider
from repro.core.training import cascade_loss
from repro.models.model import CascadeModel, extra_input_shapes
from repro.optim import adamw
from repro.optim.optimizer import Optimizer, apply_updates


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    return adamw(lr=3e-4, weight_decay=0.1)


def make_train_step(model: CascadeModel, cfg: ModelConfig,
                    optimizer: Optimizer):
    def train_step(params, opt_state, step, batch):
        def loss_fn(p):
            logits, aux = model.forward_train(p, batch["tokens"],
                                              batch.get("extra"))
            return cascade_loss(logits, batch["labels"],
                                cfg.cascade.loss_mode or "joint",
                                joint_weights=cfg.cascade.joint_weights,
                                aux=aux, aux_coef=cfg.router_aux_coef)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss
    return train_step


def make_prefill_step(model: CascadeModel, cfg: ModelConfig):
    """Prefill step: consumes the prompt, emits the first decision AND the
    initial :class:`DecodeState` (t past the prompt, streaks seeded by the
    prefill decision) that the serve step then carries."""
    executor = StagedExecutor(model, cfg)

    def prefill_step(params, tokens, cache, extra):
        d, cache, state = executor.prefill(params, tokens, cache, extra)
        return d.prediction, d.exit_index, d.confidence, cache, state
    return prefill_step


def make_serve_step(model: CascadeModel, cfg: ModelConfig):
    """Staged decode step.  Works for EVERY registered measure — stateful
    patience@k included: its streaks ride in ``state.policy`` instead of
    being re-initialized (which would silently disable early exit).

    ``cfg.cascade.exit_mode`` picks the execution strategy: ``select``
    (fixed graph, the dry-run/roofline shape) or ``cond_batch`` (lax.cond
    skips exited segments' compute).  Outputs are identical either way.
    """
    executor = StagedExecutor(model, cfg)

    def serve_step(params, token, cache, state, extra):
        d, cache, state = executor.decode_step(params, token, cache, state,
                                               extra)
        return d.prediction, d.exit_index, d.confidence, cache, state
    return serve_step


def make_decode_loop_step(model: CascadeModel, cfg: ModelConfig,
                          chunk: int, cache_len: int):
    """Device-resident multi-token decode: a ``lax.while_loop`` over the
    staged executor that generates up to ``chunk`` tokens per call with NO
    host round-trip between tokens.

    Signature::

        loop_step(params, token, cache, state, remaining, extra)
            -> (tokens, exits, confs, live, n_steps, cache, state, remaining)

    ``token`` is the (B, 1) continuation token, ``remaining`` the (B,)
    per-slot token budget (``max_new_tokens`` minus tokens already
    generated; 0 for finished slots).  Outputs land in preallocated
    ``(chunk, B)`` device buffers — tokens, exit indices, confidences, and
    the per-step live mask — so the caller syncs to host once per chunk
    instead of once per token.  ``n_steps`` is how many loop iterations
    actually ran: the loop ends early once every slot has either spent its
    budget or hit the cache limit (``state.active`` goes all-False), exactly
    mirroring the host engine's per-token finish rule
    (``len(generated) >= max_new_tokens or pos >= cache_len - 1``), which is
    what keeps host- and device-runtime token streams bit-identical.

    Each iteration is one :meth:`StagedExecutor.decode_step`, so cond_batch
    segment skipping and cohort-split predicates (``cascade.n_cohorts``)
    apply inside the loop body unchanged.
    """
    executor = StagedExecutor(model, cfg)
    K = int(chunk)
    limit = int(cache_len) - 1

    def loop_step(params, token, cache, state, remaining, extra):
        B = token.shape[0]
        bufs = {
            "tokens": jnp.zeros((K, B), jnp.int32),
            "exits": jnp.zeros((K, B), jnp.int32),
            "confs": jnp.zeros((K, B), jnp.float32),
            "live": jnp.zeros((K, B), bool),
        }

        def cond_fn(carry):
            i, _token, _cache, st, _remaining, _bufs = carry
            return jnp.logical_and(i < K, jnp.any(st.active))

        def body_fn(carry):
            i, token, cache, st, remaining, bufs = carry
            live = st.active
            d, cache, st = executor.decode_step(params, token, cache, st,
                                                extra)
            bufs = {
                "tokens": bufs["tokens"].at[i].set(
                    d.prediction.astype(jnp.int32)),
                "exits": bufs["exits"].at[i].set(
                    d.exit_index.astype(jnp.int32)),
                "confs": bufs["confs"].at[i].set(
                    d.confidence.astype(jnp.float32)),
                "live": bufs["live"].at[i].set(live),
            }
            remaining = remaining - live.astype(jnp.int32)
            st = st.replace(active=jnp.logical_and(
                jnp.logical_and(live, remaining > 0), st.t < limit))
            token = d.prediction[:, None].astype(jnp.int32)
            return (i + 1, token, cache, st, remaining, bufs)

        carry = (jnp.zeros((), jnp.int32), token, cache, state,
                 jnp.asarray(remaining, jnp.int32), bufs)
        i, token, cache, state, remaining, bufs = jax.lax.while_loop(
            cond_fn, body_fn, carry)
        return (bufs["tokens"], bufs["exits"], bufs["confs"], bufs["live"],
                i, cache, state, remaining)

    return loop_step


def make_decode_state(cfg: ModelConfig, batch: int, t: int = 0,
                      mac_weights=None) -> DecodeState:
    """A fresh DecodeState for ``batch`` lanes of this config.  With
    ``cfg.autotune.enabled`` the state carries zeroed exit-telemetry
    counters and the config's thresholds as a live vector (see
    :mod:`repro.autotune`)."""
    telemetry = thresholds = None
    if cfg.autotune.enabled:
        from repro.autotune.telemetry import telemetry_for
        telemetry = telemetry_for(cfg, mac_weights)
        thresholds = cfg.cascade.thresholds
    return init_decode_state(ExitDecider.from_config(cfg), batch,
                             cfg.cascade.n_components, t=t,
                             telemetry=telemetry, thresholds=thresholds)


def make_decode_state_struct(cfg: ModelConfig, batch: int):
    """ShapeDtypeStruct pytree of the DecodeState the serve step carries
    (what the dry-run lowers and shards)."""
    return jax.eval_shape(lambda: make_decode_state(cfg, batch))


def make_batch_structs(cfg: ModelConfig, batch: int, seq: int,
                       dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for a training batch."""
    extra = {k: jax.ShapeDtypeStruct(v, dtype)
             for k, v in extra_input_shapes(cfg, batch).items()}
    d = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if extra:
        d["extra"] = extra
    return d
