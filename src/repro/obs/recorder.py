"""Flight recorder: a per-request span tree assembled host-side.

The serving engine already syncs to the host at well-defined points —
admission, lane (re)prefill, once per decode chunk, retire — and at each
of those points the data a trace needs (tokens, exit components,
confidences, segment-execution deltas) is ALREADY on the host as numpy.
The recorder simply stamps ``time.perf_counter`` around those existing
boundaries and files the data into per-request flights, so the jitted
programs gain **zero new host syncs and zero retraces**; token streams
are bit-identical recorder-on vs recorder-off.

Structures:

* :class:`Span` — one named interval (or instant, ``t1 == t0``) with a
  flat attrs dict.  Span names: ``queue_wait``, ``admit``, ``prefill``,
  ``chunk``, and exactly one terminal per flight — ``exit`` (natural
  finish, including cache-length budget), ``escalate`` (deferred to the
  next model tier), ``migrate`` (drained to a sibling fleet member) or
  ``cancelled``.
* :class:`Flight` — one request's spans + flight-level attrs (lane,
  slot, cohort, predicted depth, kernel backend, MACs, token count).
* :class:`EventLog` — bounded engine-level events (threshold pushes,
  drains, autotune resolves, per-lane chunk slices for the timeline).
* :class:`FlightRecorder` — live flights (bounded by slot capacity), a
  bounded ring of completed flights (oldest evicted), the event log and
  bounded latency reservoirs feeding p50/p95/p99 summaries.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

TERMINAL_KINDS = ("exit", "escalate", "migrate", "cancelled")


def quantiles(values, qs=(0.5, 0.95, 0.99)) -> Optional[dict]:
    """p-quantile summary of a value list (None when empty).  Linear
    interpolation on the sorted sample — matches numpy's default without
    paying an array round-trip per scrape."""
    if not values:
        return None
    xs = sorted(float(v) for v in values)
    n = len(xs)
    out = {"count": n, "sum": float(sum(xs))}
    for q in qs:
        pos = q * (n - 1)
        lo = int(pos)
        hi = min(lo + 1, n - 1)
        out[f"p{int(q * 100)}"] = xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)
    return out


class _Reservoir:
    """Bounded newest-wins sample reservoir with lossless count/sum."""

    def __init__(self, maxlen: int):
        self._ring = collections.deque(maxlen=maxlen)
        self.count = 0
        self.total = 0.0

    def add(self, v: float):
        v = float(v)
        self._ring.append(v)
        self.count += 1
        self.total += v

    def values(self) -> List[float]:
        return list(self._ring)

    def summary(self) -> Optional[dict]:
        s = quantiles(self._ring)
        if s is None:
            return None
        # count/sum cover the full lifetime even after ring eviction;
        # quantiles describe the newest `maxlen` samples
        s["count"] = self.count
        s["sum"] = self.total
        return s


class EventLog:
    """Bounded engine-level event deque + lifetime per-name counters."""

    def __init__(self, maxlen: int = 1024, clock=time.perf_counter):
        self._ring = collections.deque(maxlen=maxlen)
        self.counts = collections.Counter()
        self.dropped = 0
        self._clock = clock

    def add(self, name: str, attrs: Optional[dict] = None,
            t: Optional[float] = None):
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self.counts[name] += 1
        self._ring.append({"name": name,
                           "t": self._clock() if t is None else float(t),
                           "attrs": dict(attrs or {})})

    def snapshot(self) -> List[dict]:
        return [dict(e) for e in self._ring]

    def __len__(self):
        return len(self._ring)


@dataclasses.dataclass
class Span:
    name: str
    t0: float
    t1: float
    attrs: dict

    def to_dict(self) -> dict:
        return {"name": self.name, "t0": self.t0, "t1": self.t1,
                "attrs": dict(self.attrs)}


class Flight:
    """One request's span tree.  ``attrs`` is flight-level context that
    spans shouldn't repeat (lane, slot, cohort, kernel backend, ...)."""

    def __init__(self, rid: int, t_submit: float, submit_tick: int):
        self.rid = rid
        self.t_submit = t_submit
        self.submit_tick = submit_tick
        self.spans: List[Span] = []
        self.attrs: dict = {}
        self.terminal: Optional[str] = None
        self.t_final: Optional[float] = None

    def span(self, name: str, t0: float, t1: float,
             attrs: Optional[dict] = None) -> Span:
        s = Span(name, float(t0), float(t1), dict(attrs or {}))
        self.spans.append(s)
        return s

    def to_dict(self) -> dict:
        return {
            "rid": self.rid,
            "submit_tick": self.submit_tick,
            "t_submit": self.t_submit,
            "t_final": self.t_final,
            "terminal": self.terminal,
            "attrs": dict(self.attrs),
            "spans": [s.to_dict() for s in self.spans],
        }


class FlightRecorder:
    """Bounded per-request flight recording for one engine.

    ``live`` is bounded by the engine's slot + queue population; ``done``
    is a ring of the last ``max_flights`` completed flights (oldest
    evicted, ``evicted`` counts them); reservoirs are bounded
    newest-wins.  Every method is plain host bookkeeping — O(entries)
    dict/list work per existing sync point, no device interaction.
    """

    def __init__(self, max_flights: int = 64, max_events: int = 1024,
                 reservoir: int = 1024, name: str = "engine",
                 clock=time.perf_counter):
        self.name = name
        self._clock = clock
        self.max_flights = int(max_flights)
        self.live: Dict[int, Flight] = {}
        self.done: "collections.OrderedDict[int, Flight]" = \
            collections.OrderedDict()
        self.evicted = 0
        self.events = EventLog(max_events, clock=clock)
        self.reservoirs = {
            "admission_wait_ticks": _Reservoir(reservoir),
            "e2e_seconds": _Reservoir(reservoir),
            "per_token_seconds": _Reservoir(reservoir),
            "macs_per_request": _Reservoir(reservoir),
            "tokens_per_request": _Reservoir(reservoir),
        }

    @classmethod
    def from_config(cls, obs_cfg, name: str = "engine") -> "FlightRecorder":
        return cls(max_flights=obs_cfg.max_flights,
                   max_events=obs_cfg.max_events,
                   reservoir=obs_cfg.reservoir, name=name)

    # -- request lifecycle ------------------------------------------------
    def on_submit(self, rid: int, tick: int):
        t = self._clock()
        if rid in self.live:
            # a rid resubmitted before its previous flight finalized (should
            # not happen through the engine; be robust for direct callers)
            self._finalize(self.live[rid], "cancelled",
                           {"superseded": True}, t)
        f = Flight(rid, t, tick)
        self.live[rid] = f

    def on_admit(self, rid: int, *, lane: int, slot: Optional[int],
                 cohort: Optional[int], predicted_depth: Optional[float],
                 wait_ticks: int, tick: int,
                 attrs: Optional[dict] = None):
        f = self.live.get(rid)
        if f is None:              # admitted without a recorded submit
            f = Flight(rid, self._clock(), tick - wait_ticks)
            self.live[rid] = f
        t = self._clock()
        f.span("queue_wait", f.t_submit, t, {"wait_ticks": wait_ticks})
        a = {"lane": lane, "slot": slot, "cohort": cohort,
             "predicted_depth": predicted_depth, "tick": tick}
        if attrs:
            a.update(attrs)
        f.span("admit", t, t, a)
        f.attrs.update({k: v for k, v in a.items() if k != "tick"})
        self.reservoirs["admission_wait_ticks"].add(wait_ticks)

    def on_prefill(self, lane: int, t0: float, seconds: float,
                   rids: List[int], fresh: List[int], positions: int):
        """A lane (re)prefill dispatch: one span on every FRESH rid it
        admitted (in-flight co-residents re-prefill as a side effect and
        get a ``reprefill`` span instead), plus a lane-track slice."""
        fresh_set = set(fresh)
        for rid in rids:
            f = self.live.get(rid)
            if f is None:
                continue
            f.span("prefill" if rid in fresh_set else "reprefill",
                   t0, t0 + seconds,
                   {"lane": lane, "positions": positions,
                    "shared_rids": len(rids)})
        self.events.add("lane_prefill",
                        {"lane": lane, "seconds": seconds,
                         "positions": positions, "rids": len(rids)},
                        t=t0)
        # the event above is the slice START stamp; traceviz re-derives the
        # interval from attrs["seconds"]

    def on_chunk(self, lane: int, t0: float, seconds: float, steps: int,
                 entries, compiled: bool = False,
                 segments_run=None, backend: Optional[str] = None):
        """One decode dispatch (host tick: steps=1; device loop: one
        chunk).  ``entries`` is ``[(rid, tokens, exits, confs), ...]`` for
        every live slot, where tokens/exits/confs are that slot's NEW
        values this chunk (python lists, already synced)."""
        t1 = t0 + seconds
        for rid, toks, exits, confs in entries:
            f = self.live.get(rid)
            if f is None or not toks:
                continue
            f.span("chunk", t0, t1, {
                "lane": lane, "steps": steps, "tokens": len(toks),
                "exit_components": [int(e) for e in exits],
                "conf_at_exit": float(confs[-1]) if confs else None,
                "compiled": bool(compiled),
            })
            if not compiled and toks:
                per_tok = seconds / max(1, sum(
                    len(e[1]) for e in entries))
                for _ in toks:
                    self.reservoirs["per_token_seconds"].add(per_tok)
        ev = {"lane": lane, "seconds": seconds, "steps": steps,
              "tokens": sum(len(e[1]) for e in entries),
              "compiled": bool(compiled)}
        if segments_run is not None:
            ev["segments_run"] = [int(x) for x in segments_run]
        if backend is not None:
            ev["backend"] = backend
        self.events.add("lane_chunk", ev, t=t0)

    def annotate(self, rid: int, attrs: dict):
        """Merge attrs into a flight (live first, then the done ring) —
        the escalation tier / fleet use this to stamp stage + replay
        context that only they know."""
        f = self.live.get(rid) or self.done.get(rid)
        if f is not None:
            f.attrs.update(attrs)

    def on_finish(self, rid: int, kind: str, attrs: Optional[dict] = None):
        if kind not in TERMINAL_KINDS:
            raise ValueError(f"terminal kind {kind!r} not in "
                             f"{TERMINAL_KINDS}")
        f = self.live.pop(rid, None)
        if f is None:
            return
        self._finalize(f, kind, attrs, self._clock())

    def _finalize(self, f: Flight, kind: str, attrs: Optional[dict],
                  t: float):
        self.live.pop(f.rid, None)
        a = dict(attrs or {})
        f.span(kind, t, t, a)
        f.terminal = kind
        f.t_final = t
        f.attrs.update(a)
        self.reservoirs["e2e_seconds"].add(t - f.t_submit)
        if "n_tokens" in a:
            self.reservoirs["tokens_per_request"].add(a["n_tokens"])
        if "macs" in a:
            self.reservoirs["macs_per_request"].add(a["macs"])
        self.done.pop(f.rid, None)     # re-finished rid: newest wins
        self.done[f.rid] = f
        while len(self.done) > self.max_flights:
            self.done.popitem(last=False)
            self.evicted += 1

    # -- engine-level events ----------------------------------------------
    def on_event(self, name: str, attrs: Optional[dict] = None):
        self.events.add(name, attrs)

    # -- introspection ----------------------------------------------------
    def dump(self, rid: int) -> Optional[dict]:
        f = self.live.get(rid) or self.done.get(rid)
        return f.to_dict() if f is not None else None

    def flights(self, include_live: bool = False) -> List[dict]:
        out = [f.to_dict() for f in self.done.values()]
        if include_live:
            out += [f.to_dict() for f in self.live.values()]
        return out

    def latency(self) -> dict:
        """p50/p95/p99 summaries of every reservoir (None when empty)."""
        return {k: r.summary() for k, r in self.reservoirs.items()}

    def stats(self) -> dict:
        return {
            "name": self.name,
            "flights_live": len(self.live),
            "flights_done": len(self.done),
            "flights_evicted": self.evicted,
            "events": len(self.events),
            "events_dropped": self.events.dropped,
            "event_counts": dict(self.events.counts),
        }
