"""Kernel microbenchmarks: interpret-mode wall time (CPU — correctness-path
timing only) + the analytic per-call HBM traffic the fused kernels save on
the TPU target.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.confidence import confidence
from repro.kernels.ref import ref_confidence
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ref import ref_rmsnorm


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        fn(*args).block_until_ready()
    t0 = time.time()
    for _ in range(reps):
        out = fn(*args)
        (out[0] if isinstance(out, tuple) else out).block_until_ready()
    return (time.time() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    # confidence over a 151936 vocab (qwen) — the paper's hot-spot at scale
    B, V = 8, 151936
    x = jnp.asarray(rng.standard_normal((B, V)), jnp.float32)
    us_k = _time(confidence, x)
    us_r = _time(jax.jit(ref_confidence), x)
    naive_bytes = B * V * 4 * 2          # logits read + softmax write
    fused_bytes = B * V * 4              # single streamed read
    rows.append(("kernels/confidence_fused_interp", us_k,
                 f"hbm_bytes={fused_bytes}"))
    rows.append(("kernels/confidence_ref_xla", us_r,
                 f"hbm_bytes~={naive_bytes}"))
    # rmsnorm
    R, d = 256, 4096
    xr = jnp.asarray(rng.standard_normal((R, d)), jnp.float32)
    w = jnp.ones((d,), jnp.float32)
    rows.append(("kernels/rmsnorm_fused_interp", _time(rmsnorm, xr, w),
                 f"rows={R};d={d}"))
    rows.append(("kernels/rmsnorm_ref_xla",
                 _time(jax.jit(ref_rmsnorm), xr, w), f"rows={R};d={d}"))
    return rows
