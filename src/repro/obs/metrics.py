"""Metrics registry + Prometheus text / JSON exposition.

Dependency-free (stdlib only): a scrape builds a fresh
:class:`MetricsRegistry` from an engine's ``stats()`` snapshot and its
flight recorder's reservoirs, renders it, and throws it away — there is
no background thread and no sampling loop, so metrics cost nothing
between scrapes.  ``engine_metrics_into`` is duck-typed over anything
with ``stats()`` / ``queued_count()`` / ``free_slot_count()`` (the
engine and the fleet members alike); the fleet's ``scrape()`` calls it
once per member with a ``member=`` label and once more with the merged
reservoirs.

``parse_prometheus`` round-trips the text format (used by the tests and
the serve CLI's scrape self-check).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.obs.recorder import quantiles

_QUANTILES = (0.5, 0.95, 0.99)


def _label_str(labels: Optional[dict]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        '%s="%s"' % (k, str(v).replace("\\", "\\\\").replace('"', '\\"'))
        for k, v in sorted(labels.items()))
    return "{%s}" % inner


class MetricsRegistry:
    """name → (type, help, samples).  Counters/gauges hold one value per
    label-set; summaries hold a raw value list per label-set and render
    quantiles + ``_sum``/``_count`` at exposition time."""

    def __init__(self):
        # name -> {"type", "help", "samples": {labelkey: (labels, value)}}
        self._m: Dict[str, dict] = {}

    def _slot(self, name: str, typ: str, help_: str) -> dict:
        m = self._m.setdefault(
            name, {"type": typ, "help": help_, "samples": {}})
        if m["type"] != typ:
            raise ValueError(
                f"metric {name} registered as {m['type']}, now {typ}")
        return m

    @staticmethod
    def _key(labels: Optional[dict]) -> Tuple:
        return tuple(sorted((labels or {}).items()))

    def counter(self, name: str, help_: str, value: float,
                labels: Optional[dict] = None):
        m = self._slot(name, "counter", help_)
        k = self._key(labels)
        prev = m["samples"].get(k, (labels, 0.0))[1]
        m["samples"][k] = (dict(labels or {}), prev + float(value))

    def gauge(self, name: str, help_: str, value: float,
              labels: Optional[dict] = None):
        m = self._slot(name, "gauge", help_)
        m["samples"][self._key(labels)] = (dict(labels or {}), float(value))

    def summary(self, name: str, help_: str, values,
                labels: Optional[dict] = None,
                count: Optional[int] = None, total: Optional[float] = None):
        """Register a raw sample list; quantiles are computed at render.
        ``count``/``total`` override the lifetime count/sum when the list
        is a bounded reservoir of a longer stream."""
        m = self._slot(name, "summary", help_)
        k = self._key(labels)
        if k in m["samples"]:
            old = m["samples"][k][1]
            old["values"] = list(old["values"]) + list(values)
            if count is not None:
                old["count"] = (old.get("count") or 0) + count
            if total is not None:
                old["total"] = (old.get("total") or 0.0) + total
        else:
            m["samples"][k] = (dict(labels or {}),
                               {"values": list(values), "count": count,
                                "total": total})

    # -- rendering --------------------------------------------------------
    def render_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: List[str] = []
        for name in sorted(self._m):
            m = self._m[name]
            lines.append(f"# HELP {name} {m['help']}")
            lines.append(f"# TYPE {name} {m['type']}")
            for _, (labels, val) in sorted(m["samples"].items()):
                if m["type"] == "summary":
                    vals = val["values"]
                    q = quantiles(vals, _QUANTILES) or {}
                    for qq in _QUANTILES:
                        v = q.get(f"p{int(qq * 100)}")
                        if v is None:
                            continue
                        lq = dict(labels)
                        lq["quantile"] = repr(qq) if qq != 0.5 else "0.5"
                        lines.append(
                            f"{name}{_label_str(lq)} {v:.9g}")
                    cnt = val["count"] if val["count"] is not None \
                        else len(vals)
                    tot = val["total"] if val["total"] is not None \
                        else float(sum(vals))
                    lines.append(
                        f"{name}_sum{_label_str(labels)} {tot:.9g}")
                    lines.append(
                        f"{name}_count{_label_str(labels)} {cnt}")
                else:
                    lines.append(
                        f"{name}{_label_str(labels)} {val:.9g}")
        return "\n".join(lines) + "\n"

    def render_json(self) -> dict:
        out = {}
        for name, m in self._m.items():
            samples = []
            for _, (labels, val) in sorted(m["samples"].items()):
                if m["type"] == "summary":
                    s = quantiles(val["values"], _QUANTILES) or {}
                    if val["count"] is not None:
                        s["count"] = val["count"]
                    if val["total"] is not None:
                        s["sum"] = val["total"]
                    samples.append({"labels": labels, "summary": s})
                else:
                    samples.append({"labels": labels, "value": val})
            out[name] = {"type": m["type"], "help": m["help"],
                         "samples": samples}
        return out


def parse_prometheus(text: str) -> List[dict]:
    """Parse the text exposition format back into samples —
    ``[{"name", "labels", "value"}, ...]``.  Raises ValueError on a
    malformed line, so the tests/CI can assert the scrape parses."""
    samples = []
    for ln in text.splitlines():
        ln = ln.strip()
        if not ln or ln.startswith("#"):
            continue
        # NAME{l="v",...} VALUE   |   NAME VALUE
        if "{" in ln:
            name, rest = ln.split("{", 1)
            if "}" not in rest:
                raise ValueError(f"unclosed label set: {ln!r}")
            labelstr, valstr = rest.rsplit("}", 1)
            labels = {}
            # labels never contain escaped quotes in our output; keep the
            # parser simple and strict
            for pair in filter(None, labelstr.split(",")):
                if "=" not in pair:
                    raise ValueError(f"bad label pair {pair!r} in {ln!r}")
                k, v = pair.split("=", 1)
                if not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"unquoted label value in {ln!r}")
                labels[k.strip()] = v[1:-1]
        else:
            parts = ln.split(None, 1)
            if len(parts) != 2:
                raise ValueError(f"malformed sample line: {ln!r}")
            name, valstr = parts
            labels = {}
        name = name.strip()
        if not name.replace("_", "").replace(":", "").isalnum():
            raise ValueError(f"bad metric name {name!r}")
        samples.append({"name": name, "labels": labels,
                        "value": float(valstr)})
    return samples


def engine_metrics_into(reg: MetricsRegistry, engine,
                        labels: Optional[dict] = None) -> MetricsRegistry:
    """Map one engine's ``stats()`` snapshot + flight recorder onto the
    registry.  Works with the recorder disabled (counter/gauge metrics
    come straight from ``stats()``; recorder-fed summaries are skipped).
    """
    st = engine.stats()
    reg.counter("repro_requests_finished_total",
                "Requests finished (exit, budget, escalate or migrate).",
                st.get("requests_finished", 0), labels)
    if hasattr(engine, "queued_count"):
        reg.gauge("repro_queue_depth",
                  "Requests queued, not yet admitted.",
                  engine.queued_count(), labels)
    if hasattr(engine, "free_slot_count"):
        reg.gauge("repro_free_slots", "Free decode slots across lanes.",
                  engine.free_slot_count(), labels)
    if st.get("analytic_speedup") is not None:
        reg.gauge("repro_analytic_speedup",
                  "Analytic MAC speedup vs full-depth decode (§6.2).",
                  st["analytic_speedup"], labels)
    if st.get("cond_batch_skip_rate") is not None:
        reg.gauge("repro_cond_batch_skip_rate",
                  "Realized fraction of skippable segment-steps skipped.",
                  st["cond_batch_skip_rate"], labels)
    wc = st.get("wallclock_us_per_token")
    if wc is not None:
        reg.gauge("repro_wallclock_us_per_token",
                  "Measured decode wall-clock per token (us).", wc, labels)
    hist = st.get("exit_histogram")
    if hist:
        for comp, n in enumerate(hist):
            lc = dict(labels or {})
            lc["component"] = str(comp)
            reg.counter("repro_exit_component_total",
                        "Generated tokens by exit component.", n, lc)
    mem = st.get("memory") or {}
    for kind in ("exit", "retire"):
        v = mem.get(f"reclaimed_by_{kind}" if kind == "exit"
                    else "reclaimed_at_retire")
        if v is not None:
            lk = dict(labels or {})
            lk["kind"] = kind
            reg.counter("repro_blocks_reclaimed_total",
                        "KV cache blocks reclaimed (paged layout).", v, lk)
    esc = st.get("escalation") or {}
    for key, kind in (("escalated_requests_admitted", "admitted"),
                      ("cancelled_for_escalation", "cancelled")):
        lk = dict(labels or {})
        lk["kind"] = kind
        reg.counter("repro_escalations_total",
                    "Requests escalated through the model cascade tier.",
                    esc.get(key, 0), lk)
    waits = st.get("admission_wait_ticks") or []
    reg.summary("repro_admission_wait_ticks",
                "Engine ticks between submit and admission.",
                waits, labels)
    flight = getattr(engine, "flight", None)
    if flight is not None:
        reg.counter("repro_threshold_push_total",
                    "Live threshold vectors pushed into decode state.",
                    flight.events.counts.get("threshold_push", 0), labels)
        res = flight.reservoirs
        reg.summary("repro_request_latency_seconds",
                    "Submit-to-finalize latency per request.",
                    res["e2e_seconds"].values(), labels,
                    count=res["e2e_seconds"].count,
                    total=res["e2e_seconds"].total)
        reg.summary("repro_token_latency_seconds",
                    "Decode wall-clock attributed per generated token.",
                    res["per_token_seconds"].values(), labels,
                    count=res["per_token_seconds"].count,
                    total=res["per_token_seconds"].total)
        reg.summary("repro_macs_per_request",
                    "Analytic decode MACs spent per finished request.",
                    res["macs_per_request"].values(), labels,
                    count=res["macs_per_request"].count,
                    total=res["macs_per_request"].total)
    return reg
