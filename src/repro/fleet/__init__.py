"""Fleet tier: one scheduler over N serving engines (ROADMAP "fleet
tier" item — the layer above engines and escalation tiers).

* :mod:`repro.fleet.scheduler` — :class:`FleetScheduler`: depth/load/
  block-aware placement (the DepthCompactor prior lifted one level up),
  drain with committed-prefix migration (PR 7's replay path), failure
  rescue.
* :mod:`repro.fleet.aggregator` — :class:`TelemetryAggregator`: the
  ThresholdController run against the whole fleet through the same
  three-method surface an engine exposes; fixed-bin histograms merge by
  addition, so one merged solve equals the pooled-sample solve and warms
  up K-fold faster than any member alone.
* :mod:`repro.fleet.health` — :class:`EngineHealth`: heartbeat probes,
  consecutive-failure counting, bounded exponential backoff.
"""
from repro.fleet.aggregator import TelemetryAggregator
from repro.fleet.health import EngineHealth, HealthState
from repro.fleet.scheduler import FleetScheduler

__all__ = [
    "EngineHealth",
    "FleetScheduler",
    "HealthState",
    "TelemetryAggregator",
]
