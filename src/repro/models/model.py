"""CascadeModel — the unified early-exit model over all architecture families.

The backbone is the per-layer kind sequence from blocks.layer_kinds(cfg),
split into ``n_components`` segments at the cascade exit boundaries.  Within a
segment, consecutive layers of the same kind form a *stage* executed with
``lax.scan`` over stacked parameters (HLO size O(#stages), not O(#layers)).

Exit heads (the paper's intermediate classifiers, adapted to LM heads) branch
after every segment but the last; the final head is the standard
norm + unembedding.  Each intermediate head is
``norm → [enhancement MLP] → unembed`` where the enhancement implements the
paper's classifier widening and the unembedding is shared with the final head
by default (cascade.share_unembed).

Public entry points:
  init(key)                                      -> params
  forward_train(params, tokens, extra)           -> (exit_logits, aux)
  init_cache(batch, cache_len, dtype)            -> cache
  prefill(params, tokens, cache, extra)          -> (exit_logits_last, cache)
  decode_step(params, token, t, cache, extra)    -> (exit_logits, cache)
  decode(params, token, cache, state, extra)     -> (decision, cache, state)

``decode_step`` is the dense reference: it computes every segment and returns
every exit's logits (what the prefill/decode consistency tests pin).  The
*staged* decode — ``cfg.cascade.exit_mode`` "select" | "cond_batch", carrying
a :class:`repro.core.exec.DecodeState` and skipping exited segments' compute —
is ``decode`` / :class:`repro.core.exec.StagedExecutor`, built from the
segment primitives exposed here (``begin_decode`` / ``run_segment`` /
``backfill_segment`` / ``exit_logits`` / ``commit_decode``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import nn
from repro.models.blocks import BLOCKS, layer_kinds
from repro.models.layers import attn_init, mlp_init, norm_apply, norm_init
from repro.utils import dtype_of


def _runs(kinds: List[str]) -> List[Tuple[str, int]]:
    runs = []
    for k in kinds:
        if runs and runs[-1][0] == k:
            runs[-1][1] += 1
        else:
            runs.append([k, 1])
    return [(k, n) for k, n in runs]


class CascadeModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        kinds = layer_kinds(cfg)
        assert len(kinds) == cfg.n_layers
        self.segment_runs: List[List[Tuple[str, int]]] = []
        for (start, end) in cfg.segments:
            self.segment_runs.append(_runs(kinds[start:end]))
        self.n_exits = cfg.cascade.n_components
        self.param_dtype = dtype_of(cfg.dtype)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def init(self, key) -> Dict[str, Any]:
        cfg = self.cfg
        dt = self.param_dtype
        keys = iter(jax.random.split(key, 64))
        p: Dict[str, Any] = {}
        p["embed"] = nn.embed_init(next(keys), (cfg.vocab_size, cfg.d_model), dt)
        if cfg.family == "audio" or cfg.rope_theta <= 0:
            p["pos_embed"] = nn.embed_init(
                next(keys), (cfg.max_seq_len, cfg.d_model), dt)
        segs = []
        for runs in self.segment_runs:
            stages = []
            for kind, n in runs:
                block = BLOCKS[kind]
                init_one = lambda k, _kind=kind: jax.tree_util.tree_map(
                    lambda x: x.astype(
                        dt if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype),
                    BLOCKS[_kind].init(k, cfg))
                stages.append(nn.stack_init(init_one, next(keys), n))
            segs.append(stages)
        p["segments"] = segs
        if cfg.family == "hybrid":
            ka, km = jax.random.split(next(keys))
            shared = {"attn": attn_init(ka, cfg), "mlp": mlp_init(km, cfg)}
            p["shared"] = jax.tree_util.tree_map(
                lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating)
                else x, shared)
        if cfg.family == "audio":
            p["encoder"] = self._init_encoder(next(keys))
        # exit heads
        exits = []
        for m in range(self.n_exits - 1):
            e: Dict[str, Any] = {"norm": norm_init(next(keys), cfg)}
            if cfg.cascade.enhance_dim:
                k1, k2 = jax.random.split(next(keys))
                e["enh_w1"] = nn.dense_init(
                    k1, (cfg.d_model, cfg.cascade.enhance_dim), dt)
                e["enh_w2"] = nn.zeros_init(
                    k2, (cfg.cascade.enhance_dim, cfg.d_model), dt)
            if not cfg.cascade.share_unembed:
                e["head"] = nn.dense_init(
                    next(keys), (cfg.d_model, cfg.vocab_size), dt)
            exits.append(e)
        p["exits"] = exits
        p["final_norm"] = norm_init(next(keys), cfg)
        if not cfg.tie_embeddings:
            p["lm_head"] = nn.dense_init(
                next(keys), (cfg.d_model, cfg.vocab_size), dt)
        return p

    def _init_encoder(self, key):
        cfg = self.cfg
        dt = self.param_dtype
        k1, k2, k3 = jax.random.split(key, 3)
        enc_block = lambda k: jax.tree_util.tree_map(
            lambda x: x.astype(dt) if jnp.issubdtype(x.dtype, jnp.floating)
            else x, BLOCKS["enc"].init(k, cfg))
        return {
            "stages": nn.stack_init(enc_block, k1, cfg.encoder_layers),
            "norm": norm_init(k2, cfg),
            "pos_embed": nn.embed_init(k3, (cfg.n_audio_frames, cfg.d_model), dt),
        }

    # ------------------------------------------------------------------
    # stages
    # ------------------------------------------------------------------
    def _unroll(self, stacked):
        if not self.cfg.scan_unroll:
            return 1
        n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
        return int(n)

    def _run_stage(self, kind, stacked, h, ctx, stacked_cache, remat=False):
        block = BLOCKS[kind]
        has_cache = stacked_cache is not None

        unroll = self._unroll(stacked)
        if has_cache:
            def body(h, xs):
                pa, ca = xs
                h2, c2, aux = block.apply(self.cfg, pa, h, ctx, ca)
                return h2, (c2, aux)
            h, (new_cache, auxs) = lax.scan(body, h, (stacked, stacked_cache),
                                            unroll=unroll)
            return h, new_cache, jnp.sum(auxs)
        else:
            def body(h, pa):
                h2, _, aux = block.apply(self.cfg, pa, h, ctx, None)
                return h2, aux
            if remat:
                if self.cfg.remat_policy == "dots":
                    body_fn = jax.checkpoint(
                        body, policy=jax.checkpoint_policies
                        .dots_with_no_batch_dims_saveable)
                else:
                    body_fn = jax.checkpoint(body)
            else:
                body_fn = body
            h, auxs = lax.scan(body_fn, h, stacked, unroll=unroll)
            return h, None, jnp.sum(auxs)

    def _run_segment(self, si, params, h, ctx, seg_cache, remat=False):
        if ctx.get("block_tables") is not None:
            # paged layout: each segment addresses the shared store through
            # its OWN table row block (B, nblk) — exit depth m frees rows
            # m+1.. while shallower components keep theirs
            ctx = {**ctx, "block_table": ctx["block_tables"][si]}
        new_caches = []
        aux = jnp.zeros((), jnp.float32)
        for pi, (kind, n) in enumerate(self.segment_runs[si]):
            cache_i = seg_cache[pi] if seg_cache is not None else None
            h, nc, a = self._run_stage(kind, params["segments"][si][pi], h,
                                       ctx, cache_i, remat)
            new_caches.append(nc)
            aux = aux + a
        return h, (new_caches if seg_cache is not None else None), aux

    def _backfill_segment(self, si, params, h, ctx, seg_cache):
        """Cheap path: update caches of segment si from the exit hidden state
        without computing the segment output (cascade state backfill)."""
        if ctx.get("block_tables") is not None:
            ctx = {**ctx, "block_table": ctx["block_tables"][si]}
        new_caches = []
        for pi, (kind, n) in enumerate(self.segment_runs[si]):
            block = BLOCKS[kind]
            stacked = params["segments"][si][pi]
            cache_i = seg_cache[pi]

            def body(h_const, xs):
                pa, ca = xs
                c2 = block.backfill(self.cfg, pa, h_const, ctx, ca)
                return h_const, c2
            _, nc = lax.scan(body, h, (stacked, cache_i),
                             unroll=self._unroll(stacked))
            new_caches.append(nc)
        return new_caches

    # public segment primitives for the staged executor (core/exec.py)
    def run_segment(self, si, params, h, ctx, seg_cache):
        """Compute segment ``si``: (h', new_seg_cache, aux)."""
        return self._run_segment(si, params, h, ctx, seg_cache)

    def backfill_segment(self, si, params, h, ctx, seg_cache):
        """Write segment ``si``'s caches from the exit hidden state without
        computing the segment (the skip path's cache-coherence write)."""
        return self._backfill_segment(si, params, h, ctx, seg_cache)

    # ------------------------------------------------------------------
    # heads
    # ------------------------------------------------------------------
    def _unembed(self, params):
        if self.cfg.tie_embeddings:
            return params["embed"].T
        return params["lm_head"]

    def exit_logits(self, params, m: int, h):
        """Exit head m (m < n_exits-1: intermediate; else final)."""
        cfg = self.cfg
        if m >= self.n_exits - 1:
            x = norm_apply(params["final_norm"], cfg, h)
            return x @ self._unembed(params).astype(x.dtype)
        e = params["exits"][m]
        x = norm_apply(e["norm"], cfg, h)
        if "enh_w1" in e:
            x = x + jax.nn.gelu(x @ e["enh_w1"].astype(x.dtype)) \
                @ e["enh_w2"].astype(x.dtype)
        head = e["head"] if "head" in e else self._unembed(params)
        return x @ head.astype(x.dtype)

    def exit_head_params(self, params, m: int):
        """``(norm_w, head)`` when exit head ``m`` is megakernel-eligible.

        The per-segment megakernel (:mod:`repro.kernels.megakernel`) fuses
        exactly rmsnorm + one unembed matmul + exit update; heads with a
        layernorm bias or an enhancement MLP between norm and unembed do
        not fit that shape, so they return ``None`` and the caller falls
        back to ``exit_logits`` + the fused exit-update kernel.
        """
        if m >= self.n_exits - 1:
            norm = params["final_norm"]
            if "b" in norm:
                return None
            return norm["w"], self._unembed(params)
        e = params["exits"][m]
        if "b" in e["norm"] or "enh_w1" in e:
            return None
        head = e["head"] if "head" in e else self._unembed(params)
        return e["norm"]["w"], head

    # ------------------------------------------------------------------
    # embedding & extras
    # ------------------------------------------------------------------
    def _embed(self, params, tokens, positions=None):
        h = params["embed"][tokens]
        if "pos_embed" in params:
            if positions is None:
                positions = jnp.arange(tokens.shape[1])
            h = h + params["pos_embed"][positions]
        return h

    def _encode_audio(self, params, audio_embeds):
        """Whisper encoder over stubbed frame embeddings (B, T, d)."""
        cfg = self.cfg
        enc = params["encoder"]
        h = audio_embeds.astype(self.param_dtype) + enc["pos_embed"][None]
        ctx = {"mode": "full", "positions": jnp.arange(h.shape[1]),
               "write_slots": None, "cross": None, "shared": None}
        def body(h, pa):
            h2, _, _ = BLOCKS["enc"].apply(cfg, pa, h, ctx, None)
            return h2, ()
        h, _ = lax.scan(body, h, enc["stages"])
        return norm_apply(enc["norm"], cfg, h)

    def _make_cross(self, params, extra, mode):
        cfg = self.cfg
        if cfg.family == "vlm":
            return extra["image_embeds"].astype(self.param_dtype)
        if cfg.family == "audio":
            if mode == "decode":
                return None  # decode uses the cross K/V cache
            return self._encode_audio(params, extra["audio_embeds"])
        return None

    # ------------------------------------------------------------------
    # training / full-sequence forward
    # ------------------------------------------------------------------
    def forward_train(self, params, tokens, extra=None):
        """tokens: (B, S).  Returns ([exit logits (B,S,V)] * n_exits, aux)."""
        cfg = self.cfg
        S = tokens.shape[1]
        positions = jnp.arange(S)
        h = self._embed(params, tokens, positions)
        ctx = {"mode": "full", "positions": positions, "write_slots": None,
               "cross": self._make_cross(params, extra or {}, "full"),
               "shared": params.get("shared"), "kpos": None}
        logits, aux = [], jnp.zeros((), jnp.float32)
        stride = max(1, cfg.cascade.exit_loss_stride)
        for si in range(self.n_exits):
            h, _, a = self._run_segment(si, params, h, ctx, None,
                                        remat=cfg.remat)
            aux = aux + a
            if si < self.n_exits - 1:
                logits.append(self.exit_logits(params, si, h[:, ::stride]))
        logits.append(self.exit_logits(params, self.n_exits - 1, h))
        return logits, aux

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def cache_capacity(self, cache_len: int) -> int:
        w = self.cfg.attn_window
        return min(w, cache_len) if w else cache_len

    def init_cache(self, batch: int, cache_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or self.param_dtype
        W = self.cache_capacity(cache_len)
        segs = []
        for si, runs in enumerate(self.segment_runs):
            stages = []
            for kind, n in runs:
                one = BLOCKS[kind].init_cache(cfg, batch, W, dtype)
                stacked = jax.tree_util.tree_map(
                    lambda x: jnp.broadcast_to(x, (n,) + x.shape), one)
                stages.append(stacked)
            segs.append(stages)
        return {"kpos": jnp.full((W,), -1, jnp.int32), "segments": segs}

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params, tokens, cache, extra=None, block_tables=None):
        """Full-sequence forward writing KV/state caches.

        Returns ([exit logits at last position (B,V)] * n_exits, new cache).
        ``block_tables`` ((n_components, B, nblk) int32) switches the cache
        writes to the paged layout; the returned ``kpos`` is then the
        per-slot (B, W) ring instead of the lane-wide (W,).
        """
        cfg = self.cfg
        B, S = tokens.shape
        W = cache["kpos"].shape[-1]
        positions = jnp.arange(S)
        # per-slot gather index == the absolute position held by the slot
        write_slots = jnp.asarray(_prefill_kpos(S, W))
        h = self._embed(params, tokens, positions)
        ctx = {"mode": "full", "positions": positions,
               "write_slots": write_slots,
               "cross": self._make_cross(params, extra or {}, "full"),
               "shared": params.get("shared"), "kpos": cache["kpos"]}
        if block_tables is not None:
            ctx["block_tables"] = jnp.asarray(block_tables, jnp.int32)
        logits = []
        new_segs = []
        for si in range(self.n_exits):
            h, nc, _ = self._run_segment(si, params, h, ctx,
                                         cache["segments"][si])
            new_segs.append(nc)
            logits.append(self.exit_logits(params, si, h[:, -1:, :])[:, 0, :])
        kpos = jnp.asarray(_prefill_kpos(S, W))
        if cache["kpos"].ndim == 2:
            kpos = jnp.broadcast_to(kpos, (B, W))
        return logits, {"kpos": kpos, "segments": new_segs}

    def prefill_into(self, params, tokens, cache, positions, write_slots,
                     block_tables, extra=None):
        """Single-request prefill at OFFSET positions into an occupied
        paged lane (continuous-batching admission).

        tokens: (1, S); ``positions`` (S,) the absolute positions the lane
        cursor will have covered when the slot starts decoding;
        ``write_slots`` (W,) the per-ring-slot absolute position to keep
        (-1 = slot unwritten), computed by the engine; ``block_tables``
        (n_components, 1, nblk) the admitted slot's table rows.  Writes go
        through the slot's own blocks, so the rest of the lane's cache is
        untouched.  Returns ([exit logits at last position (1, V)] *
        n_exits, new segment stores).
        """
        positions = jnp.asarray(positions, jnp.int32)
        h = self._embed(params, tokens, positions)
        ctx = {"mode": "full", "positions": positions,
               "write_slots": jnp.asarray(write_slots, jnp.int32),
               "cross": self._make_cross(params, extra or {}, "full"),
               "shared": params.get("shared"), "kpos": None,
               "block_tables": jnp.asarray(block_tables, jnp.int32)}
        logits, new_segs = [], []
        for si in range(self.n_exits):
            h, nc, _ = self._run_segment(si, params, h, ctx,
                                         cache["segments"][si])
            new_segs.append(nc)
            logits.append(self.exit_logits(params, si, h[:, -1:, :])[:, 0, :])
        return logits, new_segs

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def begin_decode(self, params, token, t, cache, extra=None):
        """Embed one decode token and build the step context.

        token: (B,1) int32; t: scalar int32 position.  Returns (h, ctx) for
        the segment primitives (``run_segment`` / ``backfill_segment``).
        """
        W = cache["kpos"].shape[-1]
        slot = jnp.asarray(t, jnp.int32) % W
        h = self._embed(params, token,
                        jnp.asarray(t, jnp.int32)[None] if "pos_embed" in params
                        else None)
        ctx = {"mode": "decode", "t": jnp.asarray(t, jnp.int32), "slot": slot,
               "kpos": cache["kpos"], "positions": None, "write_slots": None,
               "cross": self._make_cross(params, extra or {}, "decode"),
               "shared": params.get("shared")}
        return h, ctx

    def commit_decode(self, cache, new_segs, t):
        """Finish a decode step: record position t in the kpos ring (the
        lane-wide (W,) ring, or every slot's row of the paged per-slot
        (B, W) ring — dead slots' rows are masked by the kernels' live
        mask and re-planned at admission, so the broadcast is safe)."""
        W = cache["kpos"].shape[-1]
        slot = jnp.asarray(t, jnp.int32) % W
        kpos = cache["kpos"].at[..., slot].set(jnp.asarray(t, jnp.int32))
        return {"kpos": kpos, "segments": new_segs}

    def decode_step(self, params, token, t, cache, extra=None):
        """One DENSE decode step: every segment computes, every exit's
        logits are returned (list of (B,V)), caches get the true deep
        features.  This is the reference path the consistency tests pin.

        Early-exit execution — segment skipping under ``lax.cond``, carried
        :class:`~repro.core.exec.DecodeState`, identical ``select`` /
        ``cond_batch`` semantics — lives in :meth:`decode` /
        :class:`repro.core.exec.StagedExecutor`.
        """
        h, ctx = self.begin_decode(params, token, t, cache, extra)
        logits: List[jnp.ndarray] = []
        new_segs: List[Any] = []
        for si in range(self.n_exits):
            h, nc, _ = self._run_segment(si, params, h, ctx,
                                         cache["segments"][si])
            new_segs.append(nc)
            logits.append(self.exit_logits(params, si, h)[:, 0, :])
        return logits, self.commit_decode(cache, new_segs, t)

    def decode(self, params, token, cache, state, extra=None, decider=None):
        """Staged decode step honoring ``cfg.cascade.exit_mode``.

        token: (B,1) int32; state: :class:`repro.core.exec.DecodeState`
        (carries the position cursor, active mask and measure state).
        Returns (ExitDecision, new_cache, new_state).  In ``cond_batch``
        mode segments nobody needs are skipped (backfill-only).
        """
        from repro.core.exec import StagedExecutor
        if decider is not None:
            executor = StagedExecutor(self, self.cfg, decider)
        else:
            executor = getattr(self, "_staged_executor", None)
            if executor is None:
                executor = self._staged_executor = StagedExecutor(self,
                                                                  self.cfg)
        return executor.decode_step(params, token, cache, state, extra)


def _prefill_kpos(S: int, W: int) -> np.ndarray:
    s = np.arange(W)
    if S >= W:
        kpos = S - 1 - ((S - 1 - s) % W)
    else:
        kpos = np.where(s < S, s, -1)
    return kpos.astype(np.int32)


def build_model(cfg: ModelConfig) -> CascadeModel:
    return CascadeModel(cfg)


def extra_input_shapes(cfg: ModelConfig, batch: int):
    """Shapes of the stubbed modality-frontend inputs, if any."""
    if cfg.family == "vlm":
        return {"image_embeds": (batch, cfg.n_image_tokens, cfg.d_model)}
    if cfg.family == "audio":
        return {"audio_embeds": (batch, cfg.n_audio_frames, cfg.d_model)}
    return {}
