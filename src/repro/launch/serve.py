"""Serving launcher: cascade early-exit decode through the serving engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --smoke \
        --requests 8 --max-new 8 --threshold 0.5
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request
from repro.utils import get_logger

log = get_logger("serve")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.5)
    ap.add_argument("--confidence", default=None,
                    help="confidence-measure registry spec (softmax_max, "
                         "entropy, margin, patience@k[:base], ...)")
    ap.add_argument("--exit-mode", default="select",
                    choices=["select", "cond_batch"])
    ap.add_argument("--runtime", default="host",
                    choices=["host", "device"],
                    help="host: one dispatch per token; device: K-token "
                         "lax.while_loop chunks (DeviceDecodeLoop)")
    ap.add_argument("--chunk", type=int, default=8,
                    help="device-runtime tokens per dispatch (K)")
    ap.add_argument("--cohorts", type=int, default=1,
                    help="cohort-split skip granularity (cascade.n_cohorts)")
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--lane-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--cache-layout", default="dense",
                    choices=["dense", "paged"],
                    help="dense: per-lane worst-case KV slabs; paged: "
                         "shared block pool + per-slot block tables with "
                         "exit-triggered reclamation and continuous "
                         "single-slot admission (repro.serving.paged)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="paged layout: ring positions per KV block (must "
                         "divide the cache capacity)")
    ap.add_argument("--num-blocks", type=int, default=0,
                    help="paged layout: total pool blocks; 0 sizes the "
                         "pool to the dense-equivalent footprint (+1 "
                         "trash block) — set lower to serve more slots "
                         "than dense could in the same memory")
    ap.add_argument("--autotune", action="store_true",
                    help="enable online exit telemetry + a "
                         "ThresholdController that periodically re-solves "
                         "thresholds from live traffic and pushes them "
                         "into the engine without retracing "
                         "(repro.autotune)")
    ap.add_argument("--epsilon", type=float, default=0.05,
                    help="autotune target accuracy degradation ε: the "
                         "solver picks per-component thresholds whose "
                         "cascade agreement with the full-depth model "
                         "stays within ε (ignored when --budget-macs "
                         "is set)")
    ap.add_argument("--budget-macs", type=float, default=0.0,
                    help="autotune target average MACs/token: the solver "
                         "maximizes accuracy subject to this budget "
                         "(> 0 overrides --epsilon as the direction)")
    ap.add_argument("--artifacts", default=None,
                    help="autotune artifact directory: warm-start "
                         "thresholds from a matching config-hash-keyed "
                         "artifact and persist new resolutions there")
    ap.add_argument("--escalate-layers", type=int, default=0,
                    help="> 0 serves a 2-stage escalation tier "
                         "(repro.escalate): stage 0 is --arch as "
                         "configured, stage 1 the same arch with this "
                         "many layers (same vocab/family, so committed "
                         "prefixes replay as prefill)")
    ap.add_argument("--escalate-arch", default=None,
                    help="stage-1 arch id for the escalation tier "
                         "(overrides the same-arch default; must share "
                         "the prompt vocab)")
    ap.add_argument("--escalate-threshold", type=float, default=0.5,
                    help="stage-0 escalation threshold: final-component "
                         "answers below it defer to stage 1 (0.0 never, "
                         "1.1 always)")
    ap.add_argument("--fleet", type=int, default=1,
                    help="> 1 serves a FleetScheduler over this many "
                         "engine replicas (repro.fleet): depth/load-aware "
                         "placement, and with --autotune one "
                         "TelemetryAggregator solving merged fleet "
                         "telemetry instead of per-engine controllers")
    ap.add_argument("--drain", action="store_true",
                    help="fleet demo: drain engine 0 (mode=migrate) a few "
                         "ticks into the run — queued work requeues, "
                         "in-flight committed prefixes replay into "
                         "siblings, and the run must still finish every "
                         "request")
    ap.add_argument("--obs", action="store_true",
                    help="enable the flight recorder (repro.obs): a "
                         "bounded per-request span tree assembled at the "
                         "existing host-sync boundaries — zero extra "
                         "device syncs, zero retraces")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus text), /metrics.json, "
                         "/flights and /trace on 127.0.0.1:<port> and "
                         "round-trip one scrape before exiting (0 picks a "
                         "free port); implies --obs")
    ap.add_argument("--flight-dump", type=int, default=None, metavar="RID",
                    help="after the run, dump this request's recorded "
                         "span tree as JSON; implies --obs")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="after the run, export the recording as Chrome "
                         "trace-event JSON (load in Perfetto or "
                         "chrome://tracing); implies --obs")
    args = ap.parse_args()
    if (args.metrics_port is not None or args.flight_dump is not None
            or args.trace_out):
        args.obs = True

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    n = cfg.cascade.n_components
    ths = tuple([args.threshold] * (n - 1) + [0.0])
    cfg = cfg.with_cascade(thresholds=ths, exit_mode=args.exit_mode,
                           n_cohorts=args.cohorts)
    if args.confidence:
        cfg = cfg.with_cascade(confidence=args.confidence)
    escalate = bool(args.escalate_layers > 0 or args.escalate_arch)
    if args.autotune:
        # under a tier the escalation threshold is solved over stage 0's
        # final-component confidence axis — route_final telemetry
        cfg = cfg.with_autotune(enabled=True, epsilon=args.epsilon,
                                mac_budget=args.budget_macs,
                                route_final=escalate)
    if args.cache_layout == "paged":
        cfg = cfg.with_paged_cache(layout="paged",
                                   block_size=args.block_size,
                                   num_blocks=args.num_blocks)
    if args.obs:
        cfg = cfg.with_obs()
    if escalate:
        if args.fleet > 1:
            raise SystemExit("--fleet combines with plain engines; to "
                             "fleet escalation tiers build them "
                             "programmatically (repro.fleet)")
        return _serve_tier(args, cfg)
    if args.fleet > 1 or args.drain:
        return _serve_fleet(args, cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    controller = None
    if args.autotune:
        from repro.autotune import ThresholdController
        from repro.core.macs import segment_macs_per_token
        controller = ThresholdController(
            cfg, segment_macs_per_token(cfg, args.cache_len),
            artifact_dir=args.artifacts)
    engine = CascadeServingEngine(cfg, model, params,
                                  lane_batch=args.lane_batch,
                                  n_lanes=args.lanes,
                                  cache_len=args.cache_len,
                                  runtime=args.runtime,
                                  chunk=args.chunk,
                                  autotune=controller)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    engine.run()
    stats = engine.stats()
    log.info("stats: %s", json.dumps(stats, indent=2))
    if args.autotune:
        log.info("autotune: live thresholds %s, controller %s",
                 engine.current_thresholds(), engine.controller.stats())
    if args.exit_mode == "cond_batch":
        log.info("real skip rate %.3f (opportunity %.3f), %.1f us/token "
                 "(%s runtime, compile %.2fs)",
                 stats["cond_batch_skip_rate"],
                 stats["skip_opportunity_rate"],
                 stats["wallclock_us_per_token"] or 0.0,
                 stats["runtime"], stats["compile_seconds"])
    if args.cache_layout == "paged":
        mem = stats["memory"]
        log.info("paged pool: peak %d/%d blocks (%.1f%% of the dense "
                 "slab), reclaimed by exit %d / at retire %d, mean "
                 "admission wait %.2f ticks",
                 mem["peak_blocks_used"], mem["num_blocks"],
                 100.0 * mem["peak_cache_bytes"]
                 / max(1, mem["dense_slab_bytes"]),
                 mem["reclaimed_by_exit"], mem["reclaimed_at_retire"],
                 stats["admission_wait_mean"] or 0.0)
    if args.obs:
        lat = stats["latency"]
        log.info("latency: admission %s ticks, e2e %s s",
                 json.dumps(lat["admission_wait_ticks"]),
                 json.dumps(lat["e2e_seconds"]))
        _obs_wrapup(args, scrape_text=engine.scrape,
                    scrape_json=engine.scrape_json,
                    recorders=[("engine", engine.flight)],
                    dump=engine.dump_flight, flights=engine.flights)
    assert stats["requests_finished"] == args.requests


def _obs_wrapup(args, *, scrape_text, scrape_json=None, recorders=(),
                extra_events=None, dump=None, flights=None):
    """Shared --metrics-port / --trace-out / --flight-dump epilogue.

    The metrics server round-trips one scrape through a real socket (the
    CI obs-smoke lane pins that the text parses back), the trace export
    validates against the Chrome trace-event schema before writing, and
    the flight dump prints one request's span tree."""
    if args.metrics_port is not None:
        from urllib.request import urlopen

        from repro.obs import MetricsServer, parse_prometheus, trace_events
        with MetricsServer(args.metrics_port, scrape_text,
                           scrape_json=scrape_json,
                           flights=flights, flight=dump,
                           trace=(lambda: trace_events(
                               recorders, extra_events=extra_events))
                           if recorders else None) as srv:
            body = urlopen(f"http://127.0.0.1:{srv.port}/metrics",
                           timeout=10).read().decode()
            samples = parse_prometheus(body)
            log.info("metrics: %d samples served on port %d "
                     "(scrape round-trip OK)", len(samples), srv.port)
    if args.trace_out:
        recs = [(n, r) for n, r in recorders if r is not None]
        if recs or extra_events:
            from repro.obs import export_trace
            doc = export_trace(args.trace_out, recs,
                               extra_events=extra_events)
            log.info("trace: %d events -> %s",
                     len(doc["traceEvents"]), args.trace_out)
        else:
            log.warning("trace: nothing recorded (pass --obs)")
    if args.flight_dump is not None and dump is not None:
        fl = dump(args.flight_dump)
        if fl is None:
            log.warning("flight %d: not recorded (evicted, or recorder "
                        "off)", args.flight_dump)
        else:
            log.info("flight %d: %s", args.flight_dump,
                     json.dumps(fl, indent=2, default=str))


def _serve_fleet(args, cfg):
    """N-engine fleet (repro.fleet): one scheduler, one merged solve.

    The replicas share ONE parameter init — fleet placement moves
    requests between engines, so migrated streams are only bit-exact when
    every member computes the same function (the production analogue:
    replicas serving the same checkpoint)."""
    from repro.core.macs import segment_macs_per_token
    from repro.fleet import FleetScheduler, TelemetryAggregator

    n_engines = max(2, args.fleet)
    cfg = cfg.with_fleet(n_engines=n_engines, drain_mode="migrate")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    members = [CascadeServingEngine(cfg, model, params,
                                    lane_batch=args.lane_batch,
                                    n_lanes=args.lanes,
                                    cache_len=args.cache_len,
                                    runtime=args.runtime,
                                    chunk=args.chunk)
               for _ in range(n_engines)]
    aggregator = None
    if args.autotune:
        aggregator = TelemetryAggregator(
            cfg, segment_macs_per_token(cfg, args.cache_len),
            # smoke runs are dozens of ticks — resolve early so the lane
            # exercises the merged solve + fan-out push path
            resolve_every=8 if args.smoke else None,
            min_shadow=4 if args.smoke else None,
            artifact_dir=args.artifacts)
    fleet = FleetScheduler(members, aggregator=aggregator)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        fleet.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    if args.drain:
        for _ in range(3):
            fleet.step()
        summary = fleet.drain(0, mode="migrate")
        log.info("drain(0): %s", json.dumps(summary))
    fleet.run()
    stats = fleet.stats()
    log.info("fleet: %d members, %d finished (%d placements, %d "
             "migrations, %d requeues, %d tokens discarded), drained %s",
             stats["n_members"], stats["requests_finished"],
             stats["placements"], stats["migrations"], stats["requeues"],
             stats["discarded_tokens"], stats["drained"])
    for i, ms in enumerate(stats["members"]):
        log.info("member %d: %s", i, json.dumps(ms, default=str))
    if args.autotune:
        log.info("aggregator: thresholds %s, %s",
                 fleet.current_thresholds(),
                 json.dumps(stats["aggregator"], default=str))
    if args.obs:
        log.info("fleet events: %s", json.dumps(stats["events"]))
        _obs_wrapup(args, scrape_text=fleet.scrape,
                    scrape_json=fleet.scrape_json,
                    recorders=fleet._recorders(),
                    extra_events=fleet.events.snapshot(),
                    dump=fleet.dump_flight)
    assert stats["requests_finished"] == args.requests, stats
    assert stats["discarded_tokens"] == 0, \
        "same-config migration must replay, never discard"


def _serve_tier(args, cfg0):
    """Two-stage cross-model escalation (repro.escalate)."""
    from repro.escalate import ModelCascadeTier, TierThresholdController

    cfg0 = cfg0.with_escalation(enabled=True,
                                threshold=args.escalate_threshold)
    if args.escalate_arch:
        cfg1 = get_config(args.escalate_arch)
        if args.smoke:
            cfg1 = reduced(cfg1)
        cfg1 = cfg1.replace(dtype=cfg0.dtype)
        if args.escalate_layers > 0:
            cfg1 = cfg1.replace(n_layers=args.escalate_layers)
        cfg1 = cfg1.with_cascade(exit_mode=args.exit_mode,
                                 n_cohorts=args.cohorts)
        if args.confidence:
            cfg1 = cfg1.with_cascade(confidence=args.confidence)
    else:
        cfg1 = cfg0.replace(n_layers=args.escalate_layers) \
            .with_escalation(enabled=False)
    n1 = cfg1.cascade.n_components
    cfg1 = cfg1.with_cascade(
        thresholds=tuple([args.threshold] * (n1 - 1) + [0.0]))
    if args.autotune:
        # stage 1 carries ordinary telemetry; only stage 0 routes on its
        # final confidence (the escalation axis)
        cfg1 = cfg1.with_autotune(enabled=True, epsilon=args.epsilon,
                                  mac_budget=args.budget_macs,
                                  route_final=False)
    if args.cache_layout == "paged":
        cfg1 = cfg1.with_paged_cache(layout="paged",
                                     block_size=args.block_size,
                                     num_blocks=args.num_blocks)
    if args.obs:
        cfg1 = cfg1.with_obs()

    engines = []
    for s, cfg in enumerate((cfg0, cfg1)):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(s))
        engines.append(CascadeServingEngine(
            cfg, model, params, lane_batch=args.lane_batch,
            n_lanes=args.lanes, cache_len=args.cache_len,
            runtime=args.runtime, chunk=args.chunk))
    controller = None
    if args.autotune:
        controller = TierThresholdController(
            epsilon=None if args.budget_macs > 0 else args.epsilon,
            mac_budget=args.budget_macs if args.budget_macs > 0 else None,
            # smoke runs are dozens of ticks — solve early so the lane
            # exercises the full solve-split-push path
            interval=8 if args.smoke else 64,
            min_shadow=4.0 if args.smoke else 64.0,
            min_escalations=2 if args.smoke else 8)
    tier = ModelCascadeTier(engines, controller=controller)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        tier.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg0.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    tier.run()
    stats = tier.stats()
    log.info("tier: %d finished, %d escalations, final-stage histogram "
             "%s, %d draft tokens discarded",
             stats["requests_finished"], stats["escalations_total"],
             stats["final_stage_histogram"],
             stats["discarded_draft_tokens"])
    log.info("router: %s", json.dumps(stats["router"]))
    for s, es in enumerate(stats["stages"]):
        esc = es["escalation"]
        log.info("stage %d: speedup %.2fx, %d replayed / %d fresh "
                 "prefill positions, %d escalated admissions",
                 s, es["analytic_speedup"],
                 esc["prefill_positions_replayed"],
                 esc["prefill_positions_fresh"],
                 esc["escalated_requests_admitted"])
    if args.autotune:
        log.info("tier controller: %s",
                 json.dumps(stats["controller"], default=str))
    if args.obs:
        from repro.obs import MetricsRegistry, engine_metrics_into

        def _tier_scrape(as_json=False):
            reg = MetricsRegistry()
            for s, e in enumerate(tier.engines):
                engine_metrics_into(reg, e, {"stage": str(s)})
            return reg.render_json() if as_json else reg.render_text()

        _obs_wrapup(args, scrape_text=_tier_scrape,
                    scrape_json=lambda: _tier_scrape(as_json=True),
                    recorders=[(f"stage{s}", e.flight)
                               for s, e in enumerate(tier.engines)
                               if e.flight is not None],
                    dump=tier.dump_flight)
    assert stats["requests_finished"] == args.requests


if __name__ == "__main__":
    main()
