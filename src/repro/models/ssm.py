"""Mamba2 (SSD — state-space duality) block, in the chunked TPU-friendly form.

Full-sequence forward uses the chunked SSD algorithm: within-chunk quadratic
attention-like einsums (MXU-aligned) + a ``lax.scan`` over chunks carrying the
(heads, head_dim, state) recurrent state.  Decode is the single-step
recurrence.  ngroups = 1 (B/C shared across heads), as in the Mamba2 paper's
default.

Cache layout (per layer): ``conv`` (B, conv_w-1, conv_ch) rolling input
window, ``state`` (B, n_heads, head_dim, ssm_state).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn
from repro.models.layers import norm_init, rmsnorm


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_state  # x, B, C share the conv
    return d_inner, n_heads, conv_ch


def ssm_init(key, cfg):
    d = cfg.d_model
    d_inner, n_heads, conv_ch = dims(cfg)
    kin, kconv, kdt, kA, kout, kn, kng = nn.split_keys(key, 7)
    in_dim = 2 * d_inner + 2 * cfg.ssm_state + n_heads  # z, x, B, C, dt
    return {
        "in_proj": nn.dense_init(kin, (d, in_dim)),
        "conv_w": (jax.random.normal(kconv, (cfg.ssm_conv, conv_ch))
                   * (1.0 / math.sqrt(cfg.ssm_conv))).astype(jnp.float32),
        "conv_b": jnp.zeros((conv_ch,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)),
        "D": jnp.ones((n_heads,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(kdt, (n_heads,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "out_proj": nn.dense_init(kout, (d_inner, d)),
        "norm": norm_init(kn, cfg, d),
        "gate_norm_w": jnp.ones((d_inner,), jnp.float32),
    }


def _split_in(cfg, zxbcdt):
    d_inner, n_heads, _ = dims(cfg)
    n = cfg.ssm_state
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * n]
    dt = zxbcdt[..., 2 * d_inner + 2 * n:]
    return z, xBC, dt


def _causal_conv_full(xBC, conv_w, conv_b, conv_cache=None):
    """Depthwise causal conv over the sequence dim.  xBC: (B, S, C)."""
    W = conv_w.shape[0]
    if conv_cache is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = conv_cache.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)           # (B, S+W-1, C)
    out = jnp.zeros_like(xBC, dtype=jnp.float32)
    for i in range(W):
        out = out + conv_w[i].astype(jnp.float32) * xp[
            :, i:i + xBC.shape[1]].astype(jnp.float32)
    out = out + conv_b
    new_cache = xp[:, -(W - 1):] if W > 1 else xp[:, :0]
    return jax.nn.silu(out).astype(xBC.dtype), new_cache


def ssd_chunked(x, dt, A, Bmat, Cmat, chunk: int,
                init_state: Optional[jnp.ndarray] = None):
    """Chunked SSD scan.

    x: (B, S, h, p) — already the conv'd input path;
    dt: (B, S, h) — softplus'd;  A: (h,) negative;
    Bmat, Cmat: (B, S, n) (ngroups=1).
    Returns (y (B,S,h,p), final_state (B,h,p,n)).
    """
    Bsz, S, h, p = x.shape
    n = Bmat.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    xd = (x * dt[..., None]).astype(jnp.float32)        # dt-scaled input
    dA = (dt * A).astype(jnp.float32)                   # (B,S,h), negative

    def r(t):  # (B, S, ...) -> (nc, B, chunk, ...)
        return t.reshape(Bsz, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    xc, dAc = r(xd), r(dA)
    Bc, Cc = r(Bmat.astype(jnp.float32)), r(Cmat.astype(jnp.float32))

    def body(state, xs):
        xj, dAj, Bj, Cj = xs                            # (B,chunk,...)
        a = jnp.cumsum(dAj, axis=1)                     # (B,Q,h) within-chunk
        # intra-chunk: L[t,s] = exp(a_t - a_s) for s<=t.  Mask BEFORE exp:
        # the upper triangle holds large positive values (a is decreasing),
        # and where(mask, exp(inf), 0) propagates NaN through the backward.
        seg = a[:, :, None, :] - a[:, None, :, :]       # (B,Q,Q,h)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        seg = jnp.where(tri[None, :, :, None], seg, -jnp.inf)
        L = jnp.exp(seg)
        G = jnp.einsum("btn,bsn->bts", Cj, Bj)          # (B,Q,Q)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", G, L, xj)
        # inter-chunk: contribution of carried state
        decay_in = jnp.exp(a)                           # (B,Q,h)
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", Cj, state, decay_in)
        # state update: state' = exp(sum dA) * state + sum_s exp(a_Q - a_s) B_s x_s
        tot = a[:, -1:, :]                              # (B,1,h)
        decay_state = jnp.exp(tot - a)                  # (B,Q,h)
        chunk_state = jnp.einsum("bsn,bsh,bshp->bhpn", Bj, decay_state, xj)
        state = jnp.exp(tot[:, 0, :])[:, :, None, None] * state + chunk_state
        return state, y_intra + y_inter

    state0 = (jnp.zeros((Bsz, h, p, n), jnp.float32) if init_state is None
              else init_state.astype(jnp.float32))
    final_state, yc = lax.scan(body, state0, (xc, dAc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bsz, S, h, p)
    return y.astype(x.dtype), final_state


def ssm_forward_full(params, cfg, x, cache=None):
    """Full-sequence Mamba2 sublayer (residual + norm handled by caller).

    Returns (y (B,S,d), new_cache) — cache carries conv window + SSD state.
    """
    d_inner, n_heads, conv_ch = dims(cfg)
    p = cfg.ssm_head_dim
    B_, S, _ = x.shape
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xBC, dt_pre = _split_in(cfg, zxbcdt)
    conv_cache = cache["conv"] if cache is not None else None
    xBC, new_conv = _causal_conv_full(xBC, params["conv_w"], params["conv_b"],
                                      conv_cache)
    xin = xBC[..., :d_inner].reshape(B_, S, n_heads, p)
    Bmat = xBC[..., d_inner:d_inner + cfg.ssm_state]
    Cmat = xBC[..., d_inner + cfg.ssm_state:]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32)
                         + params["dt_bias"])           # (B,S,h)
    A = -jnp.exp(params["A_log"])                       # (h,)
    init_state = cache["state"] if cache is not None else None
    chunk = min(cfg.ssm_chunk, S)
    if S % chunk:  # pad to chunk multiple (masked by dt=0 ⇒ identity updates)
        pad = chunk - S % chunk
        xin = jnp.pad(xin, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Bmat = jnp.pad(Bmat, ((0, 0), (0, pad), (0, 0)))
        Cmat = jnp.pad(Cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y, state = ssd_chunked(xin, dt, A, Bmat, Cmat, chunk, init_state)
        y = y[:, :S]
    else:
        y, state = ssd_chunked(xin, dt, A, Bmat, Cmat, chunk, init_state)
    y = y + params["D"].astype(y.dtype)[:, None] * xin[:, :S]
    y = y.reshape(B_, S, d_inner)
    # gated RMSNorm (Mamba2): norm(y * silu(z))
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm_w"].astype(y.dtype),
                cfg.norm_eps)
    out = y @ params["out_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "state": state.astype(cache["state"].dtype)}
    return out, new_cache


def ssm_decode_step(params, cfg, x, cache):
    """Single-token recurrence.  x: (B, 1, d)."""
    d_inner, n_heads, conv_ch = dims(cfg)
    p = cfg.ssm_head_dim
    B_ = x.shape[0]
    zxbcdt = x[:, 0] @ params["in_proj"].astype(x.dtype)
    z, xBC, dt_pre = _split_in(cfg, zxbcdt)
    # conv: rolling window
    window = jnp.concatenate([cache["conv"].astype(x.dtype),
                              xBC[:, None, :]], axis=1)   # (B, W, C)
    conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                          params["conv_w"]) + params["conv_b"]
    xBC = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv = window[:, 1:]
    xin = xBC[..., :d_inner].reshape(B_, n_heads, p)
    Bmat = xBC[..., d_inner:d_inner + cfg.ssm_state].astype(jnp.float32)
    Cmat = xBC[..., d_inner + cfg.ssm_state:].astype(jnp.float32)
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt * A)                                  # (B,h)
    state = cache["state"].astype(jnp.float32)
    dBx = jnp.einsum("bh,bhp,bn->bhpn", dt, xin.astype(jnp.float32), Bmat)
    state = state * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cmat, state)
    y = y + params["D"][:, None] * xin.astype(jnp.float32)
    y = y.reshape(B_, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), params["gate_norm_w"].astype(y.dtype),
                cfg.norm_eps)
    out = (y @ params["out_proj"].astype(x.dtype))[:, None, :]
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "state": state.astype(cache["state"].dtype)}


def ssm_init_cache(cfg, batch: int, dtype):
    d_inner, n_heads, conv_ch = dims(cfg)
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
        "state": jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                           jnp.float32),
    }
