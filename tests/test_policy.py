"""The unified exit-policy layer: registries, measures, policies,
calibrators, and equivalence of the single ExitDecider against the legacy
per-site implementations it replaced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.cascade import cascade_evaluate, cascade_infer_sequential
from repro.core.confidence import softmax_outputs
from repro.core.policy import (BudgetPolicy, ExitDecider, ThresholdPolicy,
                               available_calibrators, available_measures,
                               available_policies, get_calibrator,
                               get_measure, get_policy, register_measure,
                               ConfidenceMeasure)


def _random_logits(n_exits=3, batch=8, classes=32, seed=0, scale=(1, 3, 8)):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal((batch, classes)) * s,
                        jnp.float32) for s in scale[:n_exits]]


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

def test_registry_contents():
    assert {"softmax_max", "entropy", "margin",
            "patience"} <= set(available_measures())
    assert {"threshold", "budget"} <= set(available_policies())
    assert {"self", "final"} <= set(available_calibrators())


def test_registry_roundtrip_from_config_strings():
    cfg = reduced(get_config("qwen2.5-3b")).with_cascade(
        confidence="margin", policy="threshold", calibrator="final")
    dec = ExitDecider.from_config(cfg)
    assert dec.measure.name == "margin"
    assert dec.policy.name == "threshold"
    assert dec.thresholds == cfg.cascade.thresholds
    assert get_calibrator(cfg.cascade.calibrator).name == "final"
    # configs stay frozen/hashable with the new fields
    hash(cfg)


def test_unknown_names_raise():
    with pytest.raises(KeyError):
        get_measure("no_such_measure")
    with pytest.raises(KeyError):
        get_policy("no_such_policy")
    with pytest.raises(KeyError):
        get_calibrator("no_such_rule")


def test_custom_measure_registration():
    @register_measure("always_sure")
    class AlwaysSure(ConfidenceMeasure):
        name = "always_sure"

        def __init__(self, arg=""):
            pass

        def __call__(self, logits):
            out = jnp.argmax(logits, axis=-1)
            return out, jnp.ones(logits.shape[:-1], jnp.float32)

    dec = ExitDecider("always_sure", thresholds=(0.99, 0.99, 0.0))
    d = dec.decide(_random_logits())
    assert int(np.max(np.asarray(d.exit_index))) == 0


# ---------------------------------------------------------------------------
# measure semantics
# ---------------------------------------------------------------------------

def test_margin_semantics():
    m = get_measure("margin")
    peaked = jnp.asarray([[8.0, 0.0, 0.0]])
    close = jnp.asarray([[1.0, 0.98, -5.0]])
    out_p, c_p = m(peaked)
    out_c, c_c = m(close)
    assert int(out_p[0]) == 0 and int(out_c[0]) == 0
    assert float(c_p[0]) > float(c_c[0])
    # margin = p1 - p2 exactly
    p = np.asarray(jax.nn.softmax(close, -1))[0]
    top = np.sort(p)[-2:]
    assert float(c_c[0]) == pytest.approx(top[1] - top[0], rel=1e-5)


def test_entropy_measure_in_unit_interval_and_ordering():
    m = get_measure("entropy")
    peaked = jnp.asarray([[10.0, 0.0, 0.0, 0.0]])
    flat = jnp.asarray([[0.1, 0.0, 0.05, 0.02]])
    _, c_p = m(peaked)
    _, c_f = m(flat)
    assert 0.0 < float(c_f[0]) < float(c_p[0]) <= 1.0


def test_patience_requires_k_consecutive_confident_steps():
    dec = ExitDecider("patience@3", thresholds=(0.0, 0.0, 0.0))
    logits = _random_logits()
    state = dec.init_state(8)
    exits = []
    for _ in range(4):
        d = dec.decide(logits, state=state)
        state = d.state
        exits.append(int(np.max(np.asarray(d.exit_index))))
    # steps 1-2: streak < 3 -> last component answers; step 3 on: exit 0
    assert exits == [2, 2, 0, 0]


def test_patience_streak_resets_when_gate_closes():
    dec = ExitDecider("patience@2", thresholds=(0.9, 0.0, 0.0))
    confident = [jnp.asarray([[12.0, 0.0]]), jnp.asarray([[12.0, 0.0]]),
                 jnp.asarray([[12.0, 0.0]])]
    unsure = [jnp.asarray([[0.1, 0.0]]), jnp.asarray([[12.0, 0.0]]),
              jnp.asarray([[12.0, 0.0]])]
    state = dec.init_state(1)
    d = dec.decide(confident, state=state)          # streak 1 -> no early
    assert int(d.exit_index[0]) != 0
    d = dec.decide(unsure, state=d.state)           # gate closed -> reset
    d = dec.decide(confident, state=d.state)        # streak 1 again
    assert int(d.exit_index[0]) != 0
    d = dec.decide(confident, state=d.state)        # streak 2 -> exit 0
    assert int(d.exit_index[0]) == 0


def test_fused_kernel_path_matches_reference():
    logits = _random_logits(batch=5, classes=300)
    ref = ExitDecider("softmax_max", thresholds=(0.5, 0.5, 0.0))
    fused = ExitDecider("softmax_max", thresholds=(0.5, 0.5, 0.0),
                        use_kernels=True)
    a = ref.decide(logits)
    b = fused.decide(logits)
    np.testing.assert_array_equal(np.asarray(a.prediction),
                                  np.asarray(b.prediction))
    np.testing.assert_array_equal(np.asarray(a.exit_index),
                                  np.asarray(b.exit_index))
    np.testing.assert_allclose(np.asarray(a.confidence),
                               np.asarray(b.confidence), rtol=1e-5)


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------

def test_threshold_policy_last_gate_always_open():
    pol = ThresholdPolicy()
    confs = jnp.zeros((3, 4))
    gates = pol.gates(confs, (0.9, 0.9, 0.9))
    assert bool(jnp.all(gates[-1]))
    assert not bool(jnp.any(gates[:-1]))


def test_budget_policy_hits_mac_budget():
    rng = np.random.default_rng(3)
    confs = [rng.random(4000) for _ in range(3)]
    mac_prefix = [1.0, 2.0, 4.0]
    for budget in (1.3, 2.0, 3.1):
        pol = BudgetPolicy("")
        pol.fit(confs, mac_prefix, mac_budget=budget)
        dec = ExitDecider("softmax_max", policy=pol)
        idx = dec.exit_indices(confs)
        realized = float(np.asarray(mac_prefix)[idx].mean())
        assert realized == pytest.approx(budget, rel=0.05)
    # infeasible budgets clamp to the cascade's range
    pol = BudgetPolicy("")
    pol.fit(confs, mac_prefix, mac_budget=100.0)
    idx = dec_idx = ExitDecider("softmax_max", policy=pol).exit_indices(confs)
    assert float(np.asarray(mac_prefix)[idx].mean()) <= mac_prefix[-1]


def test_budget_policy_spec_string():
    pol = get_policy("budget@2.5")
    assert pol.mac_budget == 2.5
    with pytest.raises(RuntimeError):
        pol.resolve_thresholds((0.5, 0.0))   # must fit() first


# ---------------------------------------------------------------------------
# equivalence against the legacy implementations
# ---------------------------------------------------------------------------

def _legacy_select_exit(logits_list, thresholds):
    """The serving engine's deleted select_exit, verbatim (reference pin)."""
    n = len(logits_list)
    token = exit_idx = conf_sel = taken = None
    for m, lg in enumerate(logits_list):
        out, delta = softmax_outputs(lg)
        ok = (delta >= thresholds[m]) if m < n - 1 else jnp.ones_like(
            delta, bool)
        if token is None:
            token, conf_sel, taken = out, delta, ok
            exit_idx = jnp.zeros_like(out, dtype=jnp.int32)
        else:
            fresh = jnp.logical_and(ok, jnp.logical_not(taken))
            token = jnp.where(fresh, out, token)
            exit_idx = jnp.where(fresh, m, exit_idx)
            conf_sel = jnp.where(fresh, delta, conf_sel)
            taken = jnp.logical_or(taken, ok)
    return token, exit_idx, conf_sel


def test_exit_decider_matches_legacy_select_exit():
    for seed in range(5):
        logits = _random_logits(seed=seed, scale=(1, 2, 6))
        ths = (0.3, 0.5, 0.0)
        tok, idx, conf = _legacy_select_exit(logits, ths)
        d = ExitDecider("softmax_max", thresholds=ths).decide(logits)
        np.testing.assert_array_equal(np.asarray(tok),
                                      np.asarray(d.prediction))
        np.testing.assert_array_equal(np.asarray(idx),
                                      np.asarray(d.exit_index))
        np.testing.assert_allclose(np.asarray(conf),
                                   np.asarray(d.confidence), rtol=1e-6)


def test_sequential_inference_matches_legacy_batch_uniform_semantics():
    """cascade_infer_sequential keeps the old batch-uniform behaviour: a
    component answers only when ALL samples clear its threshold."""
    c0 = jnp.asarray([[10.0, 0.0], [0.1, 0.0]])       # sample 1 unsure
    c1 = jnp.asarray([[0.0, 10.0], [0.0, 10.0]])      # all confident
    c2 = jnp.asarray([[5.0, 0.0], [5.0, 0.0]])
    fns = [lambda x, s, lg=lg: (lg, s) for lg in (c0, c1, c2)]
    out, conf = cascade_infer_sequential(fns, (0.9, 0.9, 0.0),
                                         jnp.zeros((2, 4)))
    # component 0 is blocked by sample 1 -> everyone answers at component 1
    np.testing.assert_array_equal(np.asarray(out), [1, 1])
    _, d1 = softmax_outputs(c1)
    np.testing.assert_allclose(np.asarray(conf), np.asarray(d1), rtol=1e-6)


def test_cascade_evaluate_forces_last_threshold_zero():
    """A nonzero final threshold must not change the exit accounting (the
    final component always answers), matching cascade_infer_sequential."""
    N = 4
    labels = np.zeros(N, np.int64)
    conf = [np.array([.95, .1, .1, .1]), np.array([.1, .95, .1, .1]),
            np.full(N, 0.5)]                      # final conf BELOW 0.9
    preds = [labels.copy()] * 3
    res = cascade_evaluate(conf, preds, labels, [1.0, 2.0, 3.0],
                           (0.9, 0.9, 0.9))
    np.testing.assert_allclose(res.exit_fractions, [1 / 4, 1 / 4, 2 / 4])
    assert res.thresholds[-1] == 0.0


def test_eval_and_decide_paths_agree():
    """The two ExitDecider entry points (logits vs precomputed confidences)
    pick identical exits."""
    logits = _random_logits(seed=7)
    ths = (0.4, 0.6, 0.0)
    dec = ExitDecider("softmax_max", thresholds=ths)
    d = dec.decide(logits)
    confs = [np.asarray(softmax_outputs(lg)[1]) for lg in logits]
    idx = dec.exit_indices(confs, ths)
    np.testing.assert_array_equal(np.asarray(d.exit_index), idx)


# ---------------------------------------------------------------------------
# engine integration: depth-compacted admission
# ---------------------------------------------------------------------------

def test_depth_compactor_routes_admission_by_predicted_depth():
    from repro.models.model import build_model
    from repro.serving import CascadeServingEngine, Request

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=2,
                               cache_len=32)
    rng = np.random.default_rng(0)
    # lane 0 targets shallow traffic (band center 0.5), lane 1 deep (1.5)
    for rid, depth in ((0, 0.2), (1, 1.8), (2, 0.2), (3, 1.8)):
        eng.submit(Request(rid=rid, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=3,
            extra={"predicted_depth": depth}))
    eng.run(100)
    assert len(eng.finished) == 4
    lanes = {rid: r["lane"] for rid, r in eng.finished.items()}
    assert lanes[0] == lanes[2] and lanes[1] == lanes[3]
    assert lanes[0] != lanes[1]


def test_mid_flight_admission_preserves_live_sequence():
    """Admitting into a lane re-prefills it; in-flight slots must continue
    from their FULL context (prompt + generated), so their greedy decode is
    identical to an undisturbed run."""
    from repro.models.model import build_model
    from repro.serving import CascadeServingEngine, Request

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompt0 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    prompt1 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)

    def run(disturb):
        eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                   n_lanes=1, cache_len=48)
        eng.submit(Request(rid=0, prompt=prompt0.copy(), max_new_tokens=8))
        for _ in range(4):
            eng.step()
        if disturb:
            eng.submit(Request(rid=1, prompt=prompt1.copy(),
                               max_new_tokens=2))
        eng.run(100)
        return eng.finished[0]["tokens"]

    solo = run(disturb=False)
    disturbed = run(disturb=True)
    assert len(solo) == 8
    assert solo == disturbed


def test_admission_at_token_limit_respects_max_new_tokens():
    """A lane re-prefill appends one token to in-flight slots; a slot that
    reaches max_new_tokens on that tick must finish there, not overshoot."""
    from repro.models.model import build_model
    from repro.serving import CascadeServingEngine, Request

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=1,
                               cache_len=48)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=3))
    eng.step()   # prefill -> token 1
    eng.step()   # decode  -> token 2
    eng.submit(Request(rid=1, prompt=rng.integers(
        0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=2))
    eng.run(50)  # admission re-prefill appends rid 0's 3rd (= last) token
    assert len(eng.finished[0]["tokens"]) == 3
    assert len(eng.finished[1]["tokens"]) == 2


def test_engine_patience_measure_decodes():
    from repro.models.model import build_model
    from repro.serving import CascadeServingEngine, Request

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(confidence="patience@2", thresholds=(0.0, 0.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=1,
                               cache_len=32)
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=4))
    out = eng.run(100)
    assert 0 in out and len(out[0]["tokens"]) == 4
    # threshold 0 gates are always open, so after the first decode step the
    # streak is satisfied and every later step exits at component 0
    assert out[0]["exit_depths"][-1] == 0
