from repro.configs.base import (AutotuneConfig, CascadeConfig,
                                EscalationConfig, InputShape, INPUT_SHAPES,
                                KernelTuneConfig, ModelConfig, ObsConfig,
                                PagedCacheConfig, default_exit_boundaries,
                                get_config, list_configs, reduced, register)

__all__ = [
    "AutotuneConfig", "CascadeConfig", "EscalationConfig", "InputShape",
    "INPUT_SHAPES", "KernelTuneConfig", "ModelConfig", "ObsConfig",
    "PagedCacheConfig", "default_exit_boundaries", "get_config",
    "list_configs", "reduced", "register",
]
