"""Paged KV cache: dense-vs-paged bit-identity, pool backpressure,
exit-triggered reclamation accounting, and the continuous-admission path.

The acceptance contract is the first block: for lanes admitted by
whole-lane prefill, ``cache_layout="paged"`` must produce the SAME token /
exit-depth streams as the dense slab across measures x exit modes x
kernels x runtimes — the layout is an addressing scheme, not a semantics.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request
from repro.serving.paged import TRASH_BLOCK, BlockPool, PagedCascadeCache


def _cfg(paged=False, block_size=8, num_blocks=0, **cascade):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(**cascade)
    if paged:
        cfg = cfg.with_paged_cache(layout="paged", block_size=block_size,
                                   num_blocks=num_blocks)
    return cfg


@pytest.fixture(scope="module")
def tiny_params():
    cfg = _cfg()
    model = build_model(cfg)
    return model.init(jax.random.PRNGKey(0))


def _engine(cfg, params, **kw):
    kw.setdefault("lane_batch", 2)
    kw.setdefault("n_lanes", 2)
    kw.setdefault("cache_len", 32)
    model = build_model(cfg)
    return CascadeServingEngine(cfg, model, params, **kw)


def _requests(n, seed=0, max_new=4, plen=(2, 7)):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(1, 50, size=rng.integers(*plen))
                    .astype(np.int32),
                    max_new_tokens=max_new)
            for i in range(n)]


def _run(eng, reqs, max_ticks=200):
    for r in reqs:
        eng.submit(r)
    return eng.run(max_ticks=max_ticks)


def _assert_identical(fin_a, fin_b):
    assert set(fin_a) == set(fin_b)
    for rid in fin_a:
        assert fin_a[rid]["tokens"] == fin_b[rid]["tokens"], rid
        assert fin_a[rid]["exit_depths"] == fin_b[rid]["exit_depths"], rid


# ---------------------------------------------------------------------------
# bit-identity: dense vs paged, measures x exit modes x kernels x runtimes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("measure,exit_mode,kernels", [
    ("softmax_max", "select", False),
    ("softmax_max", "cond_batch", False),
    ("patience@2", "select", False),
    ("patience@2", "cond_batch", False),
    ("softmax_max", "cond_batch", True),
    ("patience@2", "select", True),
])
def test_paged_streams_bit_identical(tiny_params, measure, exit_mode,
                                     kernels):
    """At-capacity traffic (every request admitted by whole-lane prefill):
    token and exit streams must match the dense layout bit for bit."""
    cascade = dict(thresholds=(0.6, 0.0), confidence=measure,
                   exit_mode=exit_mode, n_cohorts=2)
    fins = {}
    for paged in (False, True):
        cfg = _cfg(paged=paged, **cascade)
        if kernels:
            cfg = cfg.replace(use_kernels=True, kernel_interpret=True)
        fins[paged] = _run(_engine(cfg, tiny_params),
                           _requests(4, seed=3))
    assert len(fins[True]) == 4
    _assert_identical(fins[False], fins[True])


def test_paged_device_runtime_matches_dense(tiny_params):
    """Same contract through the device decode loop (block tables ride the
    while_loop carry as data)."""
    cascade = dict(thresholds=(0.6, 0.0), exit_mode="cond_batch",
                   n_cohorts=2)
    fins = {}
    for paged in (False, True):
        cfg = _cfg(paged=paged, **cascade)
        fins[paged] = _run(_engine(cfg, tiny_params, runtime="device",
                                   chunk=4),
                           _requests(4, seed=5))
    assert len(fins[True]) == 4
    _assert_identical(fins[False], fins[True])


def test_paged_segments_run_match_dense(tiny_params):
    """cond_batch skip accounting is layout-independent: the executed
    segment counters agree between the layouts."""
    cascade = dict(thresholds=(0.3, 0.0), exit_mode="cond_batch")
    runs = {}
    for paged in (False, True):
        eng = _engine(_cfg(paged=paged, **cascade), tiny_params)
        _run(eng, _requests(4, seed=7))
        runs[paged] = eng.stats()["segments_run"]
    assert runs[False] == runs[True]


# ---------------------------------------------------------------------------
# pool exhaustion -> admission backpressure (never corruption)
# ---------------------------------------------------------------------------

def test_pool_exhaustion_backpressures_admission(tiny_params):
    """A pool too small for all slots at once delays admission (nonzero
    waits) but every request still completes with its full budget — no
    partial grants, no corrupted streams."""
    cfg = _cfg(paged=True, num_blocks=2 * 2 * 4 + 1,  # half the slots
               thresholds=(0.6, 0.0), exit_mode="cond_batch")
    eng = _engine(cfg, tiny_params, lane_batch=2, n_lanes=2, cache_len=32)
    fin = _run(eng, _requests(8, seed=2, max_new=4), max_ticks=400)
    assert len(fin) == 8
    for rid, r in fin.items():
        assert len(r["tokens"]) == 4, rid
    st = eng.stats()
    assert st["memory"]["blocks_used"] == 0          # all returned
    assert max(st["admission_wait_ticks"]) > 0       # somebody queued
    # backpressure never over-admitted: the pool peak respects the cap
    assert st["memory"]["peak_blocks_used"] <= cfg.paged_cache.num_blocks - 1


def test_infeasible_request_raises(tiny_params):
    """A request that could never fit even an empty pool is an error, not
    a silent deadlock."""
    cfg = _cfg(paged=True, num_blocks=5, thresholds=(0.6, 0.0))
    eng = _engine(cfg, tiny_params)
    # spans the whole 32-position ring: 4 blocks x 2 components = 8 > 4
    eng.submit(Request(rid=0, prompt=np.arange(1, 5, dtype=np.int32),
                       max_new_tokens=40))
    with pytest.raises(ValueError, match="never fit"):
        eng.run(10)


# ---------------------------------------------------------------------------
# skip-aware reclamation accounting
# ---------------------------------------------------------------------------

def test_exit_reclamation_exceeds_whole_lane_accounting(tiny_params):
    """Easy traffic (threshold ~0: everything exits at component 0) must
    reclaim deep-component blocks as ``reclaimed_by_exit`` — strictly more
    than whole-lane accounting (which would book every block at retire)
    ever could."""
    cfg = _cfg(paged=True, thresholds=(0.02, 0.0), exit_mode="cond_batch")
    eng = _engine(cfg, tiny_params)
    fin = _run(eng, _requests(6, seed=4))
    assert len(fin) == 6
    mem = eng.stats()["memory"]
    assert mem["reclaimed_by_exit"] > 0
    assert mem["blocks_used"] == 0
    # conservation: everything claimed came back through one of the two
    # counters (allocations churned by lane re-prefills included)
    assert mem["blocks_free"] == mem["num_blocks"] - 1
    # hard traffic never books exit reclamation (max depth = K-1)
    cfg_hard = _cfg(paged=True, thresholds=(1.1, 0.0),
                    exit_mode="cond_batch")
    eng_hard = _engine(cfg_hard, tiny_params)
    _run(eng_hard, _requests(4, seed=4))
    assert eng_hard.stats()["memory"]["reclaimed_by_exit"] == 0


def test_chunk_reclaim_telemetry(tiny_params):
    """stats() surfaces per-chunk reclaim counts and they sum to the total
    reclaimed (over the recorded window)."""
    cfg = _cfg(paged=True, thresholds=(0.02, 0.0), exit_mode="cond_batch")
    eng = _engine(cfg, tiny_params)
    _run(eng, _requests(4, seed=6))
    pool = eng.pcache.pool
    assert sum(pool.chunk_reclaims) <= (pool.reclaimed_by_exit
                                        + pool.reclaimed_at_retire)
    assert any(c > 0 for c in pool.chunk_reclaims)


# ---------------------------------------------------------------------------
# continuous (single-slot) admission
# ---------------------------------------------------------------------------

def test_continuous_admission_into_live_lane(tiny_params):
    """Over-capacity traffic admits into freed slots of LIVE lanes between
    chunks: everything finishes with its full budget and the late arrivals
    waited less than a full lane drain (the dense layout's only option)."""
    cascade = dict(thresholds=(0.6, 0.0), exit_mode="cond_batch")
    reqs = _requests(12, seed=1, max_new=4, plen=(2, 4))
    eng_p = _engine(_cfg(paged=True, **cascade), tiny_params,
                    lane_batch=2, n_lanes=2, cache_len=64)
    fin_p = _run(eng_p, [Request(r.rid, r.prompt.copy(), r.max_new_tokens)
                         for r in reqs], max_ticks=400)
    eng_d = _engine(_cfg(paged=False, **cascade), tiny_params,
                    lane_batch=2, n_lanes=2, cache_len=64)
    fin_d = _run(eng_d, reqs, max_ticks=400)
    assert len(fin_p) == len(fin_d) == 12
    for rid, r in fin_p.items():
        assert len(r["tokens"]) == 4, rid
    wp = eng_p.stats()["admission_wait_mean"]
    wd = eng_d.stats()["admission_wait_mean"]
    assert wp is not None and wd is not None
    assert wp <= wd


def test_continuous_admission_preserves_sibling_streams(tiny_params):
    """Admitting into a live lane must not perturb the co-resident
    streams: run the same first-wave requests alone, then with a late
    arrival; the first wave's tokens are unchanged (no whole-lane
    re-prefill happened)."""
    cascade = dict(thresholds=(0.6, 0.0), exit_mode="cond_batch")
    first = _requests(4, seed=9, max_new=6, plen=(2, 4))

    def run(extra_req):
        eng = _engine(_cfg(paged=True, **cascade), tiny_params,
                      lane_batch=2, n_lanes=2, cache_len=64)
        for r in first:
            eng.submit(Request(r.rid, r.prompt.copy(), r.max_new_tokens))
        eng.step()                     # admit + prefill the first wave
        eng.step()                     # decode one token everywhere
        if extra_req:
            eng.submit(Request(rid=99, prompt=np.array([7, 8], np.int32),
                               max_new_tokens=2))
        eng.run(200)
        return eng.finished

    alone = run(False)
    mixed = run(True)
    assert 99 in mixed
    for r in first:
        assert alone[r.rid]["tokens"] == mixed[r.rid]["tokens"], r.rid


# ---------------------------------------------------------------------------
# config / construction validation
# ---------------------------------------------------------------------------

def test_paged_config_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="layout"):
        cfg.with_paged_cache(layout="ragged")
    with pytest.raises(ValueError, match="block_size"):
        cfg.with_paged_cache(layout="paged", block_size=0)
    # block size must divide the ring capacity
    bad = cfg.with_paged_cache(layout="paged", block_size=7)
    model = build_model(bad)
    with pytest.raises(ValueError, match="divide"):
        PagedCascadeCache(model, bad, lane_batch=2, n_lanes=1, cache_len=32)


def test_paged_rejects_moe():
    cfg = reduced(get_config("mixtral-8x7b")).replace(dtype="float32")
    cfg = cfg.with_paged_cache(layout="paged", block_size=8)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="MoE"):
        PagedCascadeCache(model, cfg, lane_batch=2, n_lanes=1, cache_len=32)


# ---------------------------------------------------------------------------
# BlockPool unit behavior
# ---------------------------------------------------------------------------

def test_block_pool_contract():
    pool = BlockPool(num_blocks=5, block_size=8, block_bytes=100)
    assert pool.free_blocks == 4                     # trash never in list
    ids = pool.alloc(3)
    assert ids is not None and TRASH_BLOCK not in ids
    assert pool.alloc(2) is None                     # no partial grants
    assert pool.used == 3 and pool.peak_used == 3
    pool.free(ids[:2], by_exit=True)
    pool.free(ids[2:])
    assert pool.reclaimed_by_exit == 2
    assert pool.reclaimed_at_retire == 1
    assert pool.used == 0 and pool.peak_used == 3
    assert pool.stats()["peak_cache_bytes"] == 300
    with pytest.raises(ValueError):
        pool.free([TRASH_BLOCK])
    with pytest.raises(ValueError):
        BlockPool(num_blocks=1, block_size=8)


def test_block_pool_chunk_window():
    pool = BlockPool(num_blocks=8, block_size=4)
    ids = pool.alloc(4)
    pool.begin_chunk()
    pool.free(ids[:3], by_exit=True)
    assert pool.end_chunk() == 3
    pool.begin_chunk()
    assert pool.end_chunk() == 0
    assert pool.chunk_reclaims == [3, 0]


# ---------------------------------------------------------------------------
# sharding rules accept the paged pytrees
# ---------------------------------------------------------------------------

def test_shard_rules_cover_paged_leaves(tiny_params):
    from jax.sharding import Mesh, PartitionSpec as P
    from repro.launch.shard_rules import cache_spec, decode_state_spec
    cfg = _cfg(paged=True)
    model = build_model(cfg)
    pc = PagedCascadeCache(model, cfg, lane_batch=2, n_lanes=1,
                           cache_len=32)
    cache = pc.lane_cache(pc.fresh_kpos())
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1, 1),
                ("pod", "data", "model"))
    specs = cache_spec(cache, cfg, mesh, batch=2)
    leaves = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: isinstance(x, P))
    assert leaves                                 # every leaf got a spec
    from repro.core.exec import StagedExecutor
    st = StagedExecutor(model, cfg).init_state(
        2, block_tables=pc.device_tables(0))
    sspecs = decode_state_spec(st, cfg, mesh, batch=2)
    assert isinstance(sspecs.block_tables, P)
