"""Observability benchmark: flight-recorder overhead + trace validity.

Two measurements, persisted to ``BENCH_serving.json`` (under ``obs``)
by ``benchmarks/run.py`` and gated by
``scripts/check_bench_serving.py::check_obs``:

* **recorder overhead** — the serving engine (device runtime,
  cond_batch, kernels on, a genuinely mixed-exit operating point)
  decodes identical traffic with ``cfg.obs.enabled`` on vs off, measured
  in interleaved waves at TICK granularity like the autotune telemetry
  bench.  The gate requires tokens/s with the recorder within 3%
  (median of per-wave paired ratios), token streams bit-identical, and
  the device loop's host-sync discipline unchanged: exactly ONE
  ``jax.device_get`` per decode chunk, recorder on or off (counted, not
  assumed) — the recorder only reads data the chunk sync already
  fetched, plus ``perf_counter`` stamps.

* **fleet trace** — a 2-member device-runtime fleet with recorders on
  serves a workload through a mid-run ``drain(0, mode="migrate")``; the
  Perfetto/Chrome trace-event export must validate against the schema
  with the ``drain`` instant present, and a migrated request's flight
  dump must show BOTH members (terminal ``migrate`` on the source,
  ``exit`` on the target).
"""
import os
import tempfile

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

LANE_BATCH = 2
CHUNK = 8

# set by run(): machine-readable summary merged into BENCH_serving.json
LAST_OBS_SUMMARY = None


def _base_cfg():
    # mirror the autotune overhead bench's MIXED-exit operating point —
    # the streams gate is only meaningful where exits span depths
    return reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32", use_kernels=True).with_cascade(
            n_components=3, exit_boundaries=(1, 2), exit_mode="cond_batch",
            thresholds=(0.021, 0.021, 0.0))


def _recorder_overhead(quick):
    """tokens/s with the flight recorder on vs off over identical
    interleaved traffic, plus the per-chunk host-sync count (must be
    exactly 1 either way — recording happens at the existing sync)."""
    base = _base_cfg()
    cfg_on = base.with_obs()
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(1))
    n_req = 2 * LANE_BATCH
    max_new = 12 if quick else 16
    waves = 4 if quick else 8

    sync_counts = {}
    engines = {}
    for name, cfg in (("off", base), ("on", cfg_on)):
        eng = CascadeServingEngine(cfg, model, params,
                                   lane_batch=LANE_BATCH, n_lanes=2,
                                   cache_len=128, runtime="device",
                                   chunk=CHUNK)
        counts = {"get": 0, "chunks": 0}
        real_run = eng.loop.run_chunk

        def wrap_run(*a, _real=real_run, _c=counts, **k):
            _c["chunks"] += 1
            real_get = jax.device_get
            try:
                def wg(x):
                    _c["get"] += 1
                    return real_get(x)
                jax.device_get = wg
                return _real(*a, **k)
            finally:
                jax.device_get = real_get
        eng.loop.run_chunk = wrap_run
        sync_counts[name] = counts
        engines[name] = eng

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab_size, 8).astype(np.int32)
               for _ in range((waves + 1) * n_req)]
    # warm-up wave per engine (pays jit)
    for eng in engines.values():
        for i in range(n_req):
            eng.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=max_new))
        eng.run(300)
        eng.reset_metrics()
    # measured waves, interleaved at TICK granularity; the reported ratio
    # is the MEDIAN of per-wave paired ratios (robust to one noisy wave)
    wave_ratios = []
    for w in range(1, waves + 1):
        for eng in engines.values():
            eng.reset_metrics()
            for i in range(w * n_req, (w + 1) * n_req):
                eng.submit(Request(rid=i, prompt=prompts[i],
                                   max_new_tokens=max_new))
        for _ in range(300):
            busy = False
            for eng in engines.values():
                if eng.queue or any(not s.done for ln in eng.lanes
                                    for s in ln["slots"]):
                    eng.step()
                    busy = True
            if not busy:
                break
        w_on = engines["on"].stats()["wallclock_us_per_token"]
        w_off = engines["off"].stats()["wallclock_us_per_token"]
        if w_on and w_off:
            wave_ratios.append(w_off / w_on)

    us_on = engines["on"].stats()["wallclock_us_per_token"]
    us_off = engines["off"].stats()["wallclock_us_per_token"]
    ratio = float(np.median(wave_ratios)) if wave_ratios else 1.0
    extra = {name: c["get"] - c["chunks"] for name, c in sync_counts.items()}
    streams_equal = (
        {r: tuple(v["tokens"]) for r, v in engines["on"].finished.items()}
        == {r: tuple(v["tokens"]) for r, v in engines["off"].finished.items()})
    on = engines["on"]
    exit_hist = [int(c) for c in on.stats()["exit_histogram"]]
    flights = on.flight.stats()
    return {
        "recorder_on_us_per_token": us_on,
        "recorder_off_us_per_token": us_off,
        "tokens_per_s_ratio": ratio,          # on/off throughput; 1.0 = free
        "extra_host_syncs_per_chunk_on": extra["on"],
        "extra_host_syncs_per_chunk_off": extra["off"],
        "streams_identical": streams_equal,
        "flights_recorded": flights["flights_done"] +
        flights["flights_evicted"],
        "flights_evicted": flights["flights_evicted"],
        "max_flights": cfg_on.obs.max_flights,
        "exit_histogram": exit_hist,
        # the streams gate is vacuous unless exits actually span depths
        "mixed_exits": bool(exit_hist[0] > 0 and sum(exit_hist[1:]) > 0),
    }


def _fleet_trace(quick):
    """Fleet run with one mid-decode drain/migration; the exported trace
    must validate with the drain visible, and the migrated request's
    flight must span both members."""
    from repro.fleet import FleetScheduler
    from repro.obs import export_trace, validate_trace_events

    cfg = _base_cfg().with_obs().with_fleet(n_engines=2,
                                            drain_mode="migrate")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    members = [CascadeServingEngine(cfg, model, params,
                                    lane_batch=LANE_BATCH, n_lanes=2,
                                    cache_len=128, runtime="device",
                                    chunk=2)
               for _ in range(2)]
    fleet = FleetScheduler(members)
    rng = np.random.default_rng(0)
    n_req = 6
    for i in range(n_req):
        fleet.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            max_new_tokens=8))
    for _ in range(2):
        fleet.step()
    drain = fleet.drain(0, mode="migrate")
    fleet.run(500)
    st = fleet.stats()

    fd, path = tempfile.mkstemp(suffix=".json", prefix="obs_trace_")
    os.close(fd)
    try:
        doc = export_trace(path, fleet._recorders(),
                           extra_events=fleet.events.snapshot())
        evs = doc["traceEvents"]
        validate_trace_events(evs, require_names=("drain",))
        trace_valid = True
        trace_bytes = os.path.getsize(path)
    finally:
        os.unlink(path)

    migrated = list(drain.get("migrated") or [])
    both = False
    for rid in migrated:
        fl = fleet.dump_flight(rid)
        memb = {m["member"] for m in (fl or {}).get("members", [])}
        kinds = {m.get("terminal") for m in (fl or {}).get("members", [])}
        if len(memb) >= 2 and {"migrate", "exit"} <= kinds:
            both = True
            break
    return {
        "submitted": n_req,
        "finished": st["requests_finished"],
        "migrated": len(migrated),
        "discarded_tokens": st["discarded_tokens"],
        "trace_valid": trace_valid,
        "trace_events": len(evs),
        "trace_bytes": trace_bytes,
        "drain_visible": True,   # validate() raised otherwise
        "migrated_shows_both_members": both,
        "fleet_events": dict(fleet.events.counts),
    }


def run(quick: bool = False):
    global LAST_OBS_SUMMARY
    overhead = _recorder_overhead(quick)
    trace = _fleet_trace(quick)
    rows = [
        ("obs/recorder_overhead",
         overhead["recorder_on_us_per_token"] or 0.0,
         f"ratio={overhead['tokens_per_s_ratio']:.3f};"
         f"extra_syncs={overhead['extra_host_syncs_per_chunk_on']};"
         f"streams_identical={overhead['streams_identical']}"),
        ("obs/fleet_trace", 0.0,
         f"events={trace['trace_events']};"
         f"migrated={trace['migrated']};"
         f"both_members={trace['migrated_shows_both_members']}"),
    ]
    LAST_OBS_SUMMARY = {
        "quick": bool(quick),
        "overhead": overhead,
        "trace": trace,
    }
    return rows
