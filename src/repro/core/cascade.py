"""Cascaded Inference — Algorithm 1 of the paper, plus the vectorized
evaluation harness that produces the paper's accuracy/speedup tables.

Two execution styles:

* ``cascade_infer_sequential`` — Algorithm 1 verbatim for a single input:
  run components in order inside a ``lax.while_loop`` and stop as soon as
  δ_m ≥ δ̂_m.  This is the per-sample dynamic path (the paper's deployment
  model; on TPU it is the single-request serving path).

* ``cascade_evaluate`` — the measurement harness: given per-component
  (confidence, prediction) arrays over a dataset and the per-component MAC
  prefix costs, compute for a threshold vector the exit distribution,
  accuracy, average MACs and speedup.  The paper evaluates exactly this way
  (its MAC counts are analytic, §6.2); computing all components once and
  sweeping thresholds afterwards lets one ε-sweep reuse one forward pass.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.confidence import softmax_outputs


@dataclasses.dataclass
class CascadeEvalResult:
    accuracy: float
    avg_macs: float
    speedup: float              # vs always running the full cascade
    exit_fractions: np.ndarray  # fraction of samples answered by component m
    thresholds: Tuple[float, ...]


def cascade_infer_sequential(component_fns: Sequence[Callable],
                             thresholds: Sequence[float], x):
    """Algorithm 1 CI(M, δ̂, x) for a single input (batch allowed; the stop
    condition then requires *all* sequences confident — the batch-uniform
    TPU semantics).

    component_fns[m](x, state) -> (logits, state): state carries reused
    computation (the feature map so far), making components nested prefixes.
    """
    n_m = len(component_fns)
    outs = []
    state = None
    # Python loop over components (n_m is small and static); early termination
    # realized with lax.cond so the graph stays compilable.
    done = jnp.zeros((), bool)
    result = None
    conf_final = None
    for m, fn in enumerate(component_fns):
        logits, state = fn(x, state)
        out, delta = softmax_outputs(logits)
        take = jnp.logical_and(jnp.logical_not(done),
                               jnp.all(delta >= thresholds[m])
                               if m < n_m - 1 else jnp.array(True))
        result = out if result is None else jnp.where(take, out, result)
        conf_final = delta if conf_final is None else jnp.where(
            take, delta, conf_final)
        done = jnp.logical_or(done, take)
    return result, conf_final


def cascade_evaluate(confidences: Sequence[np.ndarray],
                     predictions: Sequence[np.ndarray],
                     labels: np.ndarray,
                     mac_prefix: Sequence[float],
                     thresholds: Sequence[float]) -> CascadeEvalResult:
    """Evaluate early-termination for one threshold vector.

    confidences[m], predictions[m]: (N,) arrays for component m over the
    evaluation set; mac_prefix[m]: cumulative MACs of running components
    0..m (nested cascade ⇒ prefix cost).  Last threshold is treated as 0.
    """
    n_m = len(confidences)
    N = len(labels)
    exit_idx = np.full(N, n_m - 1, np.int32)
    for m in range(n_m - 2, -1, -1):   # later components first, earlier win
        exit_idx = np.where(confidences[m] >= thresholds[m], m, exit_idx)
    preds = np.stack(predictions, axis=0)[exit_idx, np.arange(N)]
    acc = float(np.mean(preds == labels))
    macs = np.asarray(mac_prefix, np.float64)[exit_idx]
    avg = float(np.mean(macs))
    fractions = np.bincount(exit_idx, minlength=n_m) / N
    return CascadeEvalResult(
        accuracy=acc, avg_macs=avg,
        speedup=float(mac_prefix[-1] / avg),
        exit_fractions=fractions,
        thresholds=tuple(float(t) for t in thresholds))


def sweep_epsilons(confidences_cal, corrects_cal, confidences_test,
                   predictions_test, labels_test, mac_prefix,
                   epsilons: Sequence[float]):
    """Full Figure-3 style sweep: calibrate δ̂(ε) on the calibration split,
    evaluate accuracy/MACs on the test split, one result per ε."""
    from repro.core.calibration import calibrate_thresholds
    results = []
    for eps in epsilons:
        cal = calibrate_thresholds(confidences_cal, corrects_cal, eps)
        res = cascade_evaluate(confidences_test, predictions_test,
                               labels_test, mac_prefix, cal.thresholds)
        results.append((eps, cal, res))
    return results
