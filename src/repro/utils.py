"""Small shared helpers: pytree utilities, dtype mapping, logging."""
from __future__ import annotations

import logging
import sys
import time
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp
import numpy as np


def get_logger(name: str) -> logging.Logger:
    logger = logging.getLogger(name)
    if not logger.handlers:
        h = logging.StreamHandler(sys.stderr)
        h.setFormatter(logging.Formatter(
            "%(asctime)s %(name)s %(levelname)s: %(message)s", "%H:%M:%S"))
        logger.addHandler(h)
        logger.setLevel(logging.INFO)
    return logger


DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}


def dtype_of(name: str):
    return DTYPES[name]


def tree_size(tree) -> int:
    """Total number of elements in a pytree of arrays."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree) -> int:
    return sum(int(np.prod(x.shape)) * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def tree_cast(tree, dtype):
    return jax.tree_util.tree_map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree)


def assert_finite(tree, name: str = "tree"):
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            if not bool(jnp.all(jnp.isfinite(leaf))):
                raise AssertionError(
                    f"non-finite values in {name} at {jax.tree_util.keystr(path)}")


def path_str(path) -> str:
    """Render a tree_map_with_path key path as 'a/b/0/c'."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


class Timer:
    """Wall-clock context timer (CPU benches only; TPU numbers are analytic)."""

    def __init__(self):
        self.elapsed = 0.0

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed = time.perf_counter() - self._t0


def human_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024:
            return f"{n:.2f}{unit}"
        n /= 1024
    return f"{n:.2f}PiB"


def human_count(n: float) -> str:
    for unit in ("", "K", "M", "G", "T", "P"):
        if abs(n) < 1000:
            return f"{n:.3g}{unit}"
        n /= 1000
    return f"{n:.3g}E"
