"""xLSTM blocks [arXiv:2405.04517]: mLSTM (matrix memory, parallel/chunked)
and sLSTM (scalar memory, sequential scan).

mLSTM full-sequence forward uses a *chunkwise* formulation (the TPU
adaptation): within-chunk quadratic einsums + a ``lax.scan`` carrying the
stabilized (C, n, m) state across chunks.  This is exact (validated against
the sequential recurrence in tests) and keeps memory O(S·chunk) so the 32k
shapes compile.

Recurrences (stabilized, per head; q scaled by 1/sqrt(p)):
    m_t = max(f̃_t + m_{t-1}, ĩ_t)
    C_t = e^{f̃_t + m_{t-1} - m_t} C_{t-1} + e^{ĩ_t - m_t} k_t v_tᵀ
    n_t = e^{f̃_t + m_{t-1} - m_t} n_{t-1} + e^{ĩ_t - m_t} k_t
    h_t = (q_tᵀ C_t) / max(|q_tᵀ n_t|, e^{-m_t})

Cache layout (mLSTM): C (B,h,p,p) f32, n (B,h,p) f32, m (B,h) f32, plus the
conv rolling window.  sLSTM cache: (c, n, m, h) each (B, d_inner).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn
from repro.models.layers import norm_init, rmsnorm

CONV_W = 4


def mlstm_dims(cfg):
    d_inner = 2 * cfg.d_model          # pre-up-projection factor 2
    n_heads = cfg.n_heads
    p = d_inner // n_heads
    return d_inner, n_heads, p


def mlstm_init(key, cfg):
    d = cfg.d_model
    d_inner, h, p = mlstm_dims(cfg)
    ku, kq, kk, kv, ki, kf, ko, kc, kn, ks = nn.split_keys(key, 10)
    return {
        "up_proj": nn.dense_init(ku, (d, 2 * d_inner)),   # -> (u, z)
        "conv_w": (jax.random.normal(kc, (CONV_W, d_inner))
                   / math.sqrt(CONV_W)).astype(jnp.float32),
        "conv_b": jnp.zeros((d_inner,), jnp.float32),
        "wq": nn.dense_init(kq, (d_inner, d_inner)),
        "wk": nn.dense_init(kk, (d_inner, d_inner)),
        "wv": nn.dense_init(kv, (d_inner, d_inner)),
        "w_i": nn.dense_init(ki, (d_inner, h)),
        "w_f": nn.dense_init(kf, (d_inner, h)),
        "b_i": jnp.zeros((h,), jnp.float32),
        "b_f": jnp.full((h,), 3.0, jnp.float32),   # forget-gate bias init
        "out_norm_w": jnp.ones((d_inner,), jnp.float32),
        "down_proj": nn.dense_init(ko, (d_inner, d)),
        "norm": norm_init(kn, cfg, d),
    }


def _mlstm_qkvif(params, cfg, u, conv_cache=None):
    """u: (B,S,d_inner) -> q,k,v (B,S,h,p), i,f pre-activations (B,S,h)."""
    d_inner, h, p = mlstm_dims(cfg)
    B, S, _ = u.shape
    W = CONV_W
    if conv_cache is None:
        padc = jnp.zeros((B, W - 1, d_inner), u.dtype)
    else:
        padc = conv_cache.astype(u.dtype)
    up = jnp.concatenate([padc, u], axis=1)
    c = jnp.zeros_like(u, dtype=jnp.float32)
    for i in range(W):
        c = c + params["conv_w"][i] * up[:, i:i + S].astype(jnp.float32)
    c = jax.nn.silu(c + params["conv_b"]).astype(u.dtype)
    new_conv = up[:, -(W - 1):]
    q = (c @ params["wq"].astype(u.dtype)).reshape(B, S, h, p)
    k = (c @ params["wk"].astype(u.dtype)).reshape(B, S, h, p)
    v = (u @ params["wv"].astype(u.dtype)).reshape(B, S, h, p)
    i_pre = (c.astype(jnp.float32) @ params["w_i"]) + params["b_i"]
    f_pre = (c.astype(jnp.float32) @ params["w_f"]) + params["b_f"]
    return q, k, v, i_pre, f_pre, new_conv


def mlstm_chunked(q, k, v, i_pre, f_pre, chunk: int, init=None):
    """Chunkwise stabilized mLSTM.  q,k,v: (B,S,h,p); i,f: (B,S,h) f32.

    Returns (hidden (B,S,h,p), (C,n,m) final)."""
    B, S, h, p = q.shape
    assert S % chunk == 0
    nc = S // chunk
    scale = 1.0 / math.sqrt(p)
    logf = jax.nn.log_sigmoid(f_pre)                        # (B,S,h)

    def r(t):
        return t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = r(q.astype(jnp.float32) * scale), r(k.astype(jnp.float32)), \
        r(v.astype(jnp.float32))
    ic, fc = r(i_pre), r(logf)

    if init is None:
        C0 = jnp.zeros((B, h, p, p), jnp.float32)
        n0 = jnp.zeros((B, h, p), jnp.float32)
        m0 = jnp.full((B, h), -1e30, jnp.float32)
    else:
        C0, n0, m0 = init

    def body(carry, xs):
        C, n, m = carry
        qj, kj, vj, ij, fj = xs                             # (B,Q,...)
        b = jnp.cumsum(fj, axis=1)                          # (B,Q,h)
        # per-step stabilizer: m_t = max(m_prev + b_t, max_{s<=t}(b_t - b_s + i_s))
        g = b[:, :, None, :] - b[:, None, :, :] + ij[:, None, :, :]  # (B,t,s,h)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        g = jnp.where(tri[None, :, :, None], g, -jnp.inf)
        m_intra = jnp.max(g, axis=2)                        # (B,Q,h)
        m_t = jnp.maximum(m[:, None, :] + b, m_intra)       # (B,Q,h)
        # intra-chunk attention-like term
        D = jnp.exp(g - m_t[:, :, None, :])                 # (B,t,s,h)
        A = jnp.einsum("bthp,bshp->btsh", qj, kj) * D
        intra = jnp.einsum("btsh,bshp->bthp", A, vj)
        n_intra = jnp.einsum("btsh,bshp->bthp", D, kj * 1.0)  # Σ weights·k
        # inter-chunk from carried state
        w_prev = jnp.exp(m[:, None, :] + b - m_t)           # (B,Q,h)
        inter = jnp.einsum("bthp,bhpd,bth->bthd", qj, C, w_prev)
        qn_inter = jnp.einsum("bhp,bth->bthp", n, w_prev)
        hidden_num = intra + inter
        n_vec = n_intra + qn_inter                          # (B,t,h,p)
        qn = jnp.einsum("bthp,bthp->bth", qj, n_vec)
        denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
        hidden = hidden_num / denom[..., None]
        # carry update to end of chunk
        b_last = b[:, -1, :]                                # (B,h)
        m_new = m_t[:, -1, :]
        wC = jnp.exp(m + b_last - m_new)                    # (B,h)
        s_w = jnp.exp(b_last[:, None, :] - b + ij - m_new[:, None, :])  # (B,s,h)
        C_new = wC[:, :, None, None] * C + jnp.einsum(
            "bsh,bshp,bshd->bhpd", s_w, kj, vj)
        n_new = wC[:, :, None] * n + jnp.einsum("bsh,bshp->bhp", s_w, kj)
        return (C_new, n_new, m_new), hidden

    (C, n, m), hid = lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, fc))
    hidden = hid.swapaxes(0, 1).reshape(B, S, h, p)
    return hidden.astype(q.dtype), (C, n, m)


def mlstm_forward_full(params, cfg, x, cache=None):
    d_inner, h, p = mlstm_dims(cfg)
    B, S, _ = x.shape
    uz = x @ params["up_proj"].astype(x.dtype)
    u, z = uz[..., :d_inner], uz[..., d_inner:]
    conv_cache = cache["conv"] if cache is not None else None
    q, k, v, i_pre, f_pre, new_conv = _mlstm_qkvif(params, cfg, u, conv_cache)
    init = ((cache["C"], cache["n"], cache["m"]) if cache is not None else None)
    chunk = min(256, S)
    if S % chunk:
        pad = chunk - S % chunk
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_pre = jnp.pad(i_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e30)   # i=-inf ⇒ no state write
        f_pre = jnp.pad(f_pre, ((0, 0), (0, pad), (0, 0)),
                        constant_values=1e3)     # f=1 ⇒ identity decay
        hid, (C, n_, m_) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk, init)
        hid = hid[:, :S]
    else:
        hid, (C, n_, m_) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk, init)
    hid = hid.reshape(B, S, d_inner)
    hid = rmsnorm(hid, params["out_norm_w"].astype(hid.dtype), cfg.norm_eps)
    out = (hid * jax.nn.silu(z)) @ params["down_proj"].astype(x.dtype)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv.astype(cache["conv"].dtype),
                     "C": C, "n": n_, "m": m_}
    return out, new_cache


def mlstm_decode_step(params, cfg, x, cache):
    """x: (B,1,d) single-token recurrent update."""
    d_inner, h, p = mlstm_dims(cfg)
    B = x.shape[0]
    uz = x[:, 0] @ params["up_proj"].astype(x.dtype)
    u, z = uz[..., :d_inner], uz[..., d_inner:]
    window = jnp.concatenate([cache["conv"].astype(x.dtype), u[:, None, :]],
                             axis=1)
    c = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), params["conv_w"])
    c = jax.nn.silu(c + params["conv_b"]).astype(x.dtype)
    new_conv = window[:, 1:]
    scale = 1.0 / math.sqrt(p)
    q = (c @ params["wq"].astype(x.dtype)).reshape(B, h, p).astype(jnp.float32) * scale
    k = (c @ params["wk"].astype(x.dtype)).reshape(B, h, p).astype(jnp.float32)
    v = (u @ params["wv"].astype(x.dtype)).reshape(B, h, p).astype(jnp.float32)
    i_pre = c.astype(jnp.float32) @ params["w_i"] + params["b_i"]   # (B,h)
    f_pre = c.astype(jnp.float32) @ params["w_f"] + params["b_f"]
    logf = jax.nn.log_sigmoid(f_pre)
    C, n, m = cache["C"], cache["n"], cache["m"]
    m_new = jnp.maximum(logf + m, i_pre)
    wf = jnp.exp(logf + m - m_new)[:, :, None]
    wi = jnp.exp(i_pre - m_new)[:, :, None]
    C = wf[..., None] * C + wi[..., None] * jnp.einsum("bhp,bhd->bhpd", k, v)
    n = wf * n + wi * k
    hid_num = jnp.einsum("bhp,bhpd->bhd", q, C)
    qn = jnp.einsum("bhp,bhp->bh", q, n)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    hid = (hid_num / denom[..., None]).reshape(B, d_inner).astype(x.dtype)
    hid = rmsnorm(hid, params["out_norm_w"].astype(hid.dtype), cfg.norm_eps)
    out = ((hid * jax.nn.silu(z)) @ params["down_proj"].astype(x.dtype))[:, None]
    return out, {"conv": new_conv.astype(cache["conv"].dtype),
                 "C": C, "n": n, "m": m_new}


def mlstm_init_cache(cfg, batch: int, dtype):
    d_inner, h, p = mlstm_dims(cfg)
    return {"conv": jnp.zeros((batch, CONV_W - 1, d_inner), dtype),
            "C": jnp.zeros((batch, h, p, p), jnp.float32),
            "n": jnp.zeros((batch, h, p), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    kw, kr, kup, kdn, kn = nn.split_keys(key, 5)
    return {
        # fused input projections for (z, i, f, o)
        "w_in": nn.dense_init(kw, (d, 4 * d)),
        # head-wise recurrent matrices for (z, i, f, o): (4, h, p, p)
        "r": (jax.random.normal(kr, (4, h, p, p), jnp.float32)
              / math.sqrt(p)),
        "b": jnp.concatenate([jnp.zeros((2 * d,), jnp.float32),
                              jnp.full((d,), 3.0, jnp.float32),
                              jnp.zeros((d,), jnp.float32)]),
        # post-up-projection MLP (factor 4/3, GeLU) per the xLSTM paper
        "w_up": nn.dense_init(kup, (d, (4 * d) // 3)),
        "w_dn": nn.dense_init(kdn, ((4 * d) // 3, d)),
        "norm": norm_init(kn, cfg, d),
    }


def _slstm_cell(params, cfg, xt, state):
    """One timestep.  xt: (B, 4d) preprojected input; state: dict of (B,d)."""
    d = cfg.d_model
    h = cfg.n_heads
    p = d // h
    B = xt.shape[0]
    c, n, m, hprev = state["c"], state["n"], state["m"], state["h"]
    hh = hprev.reshape(B, h, p)
    rec = jnp.einsum("bhp,khpq->kbhq", hh, params["r"]).reshape(4, B, d)
    xt4 = xt.reshape(B, 4, d).transpose(1, 0, 2)            # (4,B,d)
    pre = xt4 + rec + params["b"].reshape(4, d)[:, None, :]
    z_pre, i_pre, f_pre, o_pre = pre[0], pre[1], pre[2], pre[3]
    z = jnp.tanh(z_pre)
    o = jax.nn.sigmoid(o_pre)
    logf = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(logf + m, i_pre)
    i_ = jnp.exp(i_pre - m_new)
    f_ = jnp.exp(logf + m - m_new)
    c_new = f_ * c + i_ * z
    n_new = f_ * n + i_
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return {"c": c_new, "n": n_new, "m": m_new, "h": h_new}


def slstm_forward_full(params, cfg, x, cache=None):
    B, S, d = x.shape
    xt = (x @ params["w_in"].astype(x.dtype)).astype(jnp.float32)
    state = cache["state"] if cache is not None else slstm_zero_state(cfg, B)

    def body(st, xt_t):
        st = _slstm_cell(params, cfg, xt_t, st)
        return st, st["h"]

    state, hs = lax.scan(body, state, xt.swapaxes(0, 1))
    hid = hs.swapaxes(0, 1).astype(x.dtype)                 # (B,S,d)
    up = jax.nn.gelu(hid @ params["w_up"].astype(x.dtype))
    out = up @ params["w_dn"].astype(x.dtype)
    new_cache = {"state": state} if cache is not None else None
    return out, new_cache


def slstm_decode_step(params, cfg, x, cache):
    xt = (x[:, 0] @ params["w_in"].astype(x.dtype)).astype(jnp.float32)
    state = _slstm_cell(params, cfg, xt, cache["state"])
    hid = state["h"].astype(x.dtype)[:, None, :]
    up = jax.nn.gelu(hid @ params["w_up"].astype(x.dtype))
    out = up @ params["w_dn"].astype(x.dtype)
    return out, {"state": state}


def slstm_zero_state(cfg, batch: int):
    d = cfg.d_model
    return {"c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "m": jnp.full((batch, d), -30.0, jnp.float32),
            "h": jnp.zeros((batch, d), jnp.float32)}


def slstm_init_cache(cfg, batch: int, dtype):
    del dtype
    return {"state": slstm_zero_state(cfg, batch)}
