"""Minimal stand-in for the ``hypothesis`` API used by this test suite.

The container image does not ship hypothesis and the repo cannot add
dependencies; conftest.py installs this module as ``hypothesis`` (and
``hypothesis.strategies``) into ``sys.modules`` only when the real package is
missing.  It supports exactly what the tests use: ``@settings(max_examples=,
deadline=)``, ``@given(...)`` with positional strategies, and the
``integers`` / ``floats`` / ``sampled_from`` strategies.  Examples are drawn
from a fixed-seed RNG so runs are deterministic.
"""
from __future__ import annotations

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def sampled_from(options):
    opts = list(options)
    return _Strategy(lambda rng: opts[int(rng.integers(len(opts)))])


def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, deadline=None,
             **_ignored):
    def deco(fn):
        fn._stub_max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def runner():
            n = getattr(runner, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(0)
            for _ in range(n):
                fn(*[s.draw(rng) for s in strategies])
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        runner.__module__ = fn.__module__
        return runner
    return deco
