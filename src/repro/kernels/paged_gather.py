"""Block-table gather Pallas kernel for the paged KV cache.

The paged layout stores KV in a shared block store ``(num_blocks,
block_size, kv, hd)``; a slot's logical ring view is the gather of its
block-table row ``table[b]`` (``nblk`` physical block ids, trash block 0
for ring ranges the slot doesn't own).  This kernel materializes that
``(B, W, kv, hd)`` view so the EXISTING dense decode-attention kernel
runs over it unchanged — deliberately so: re-tiling the attention to
block granularity would change the online-softmax accumulation order and
break the dense/paged bit-identity contract, while a gather is exact.

The block table rides as a scalar-prefetch operand
(:class:`pltpu.PrefetchScalarGridSpec`): the grid cell ``(b, j)`` DMAs
physical block ``table[b, j]`` straight from the store — the index map
reads the prefetched table, so the copy is one dynamic-source DMA per
cell with no gather scatter-ops in the kernel body.  Trash-block cells
copy garbage; the per-slot kpos ring masks those positions out of the
attention (masking, not zeroing — DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret


def _gather_kernel(table_ref, x_ref, o_ref):
    del table_ref  # consumed by the index map
    o_ref[0] = x_ref[...]


def paged_gather(store, table, *, interpret: "bool | None" = None):
    """store (num_blocks, bs, kv, hd) gathered through table (B, nblk)
    -> the slot-logical ring view (B, nblk * bs, kv, hd).

    ``interpret`` resolves OUTSIDE the jit boundary (env var / backend
    auto-detection re-consulted every call, not baked into the trace)."""
    return _paged_gather(store, table,
                         interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_gather(store, table, *, interpret):
    _NB, bs, kv, hd = store.shape
    B, nblk = table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nblk),
        in_specs=[
            pl.BlockSpec((1, bs, kv, hd),
                         lambda b, j, table: (table[b, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bs, kv, hd),
                               lambda b, j, table: (b, j, 0, 0, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, nblk, bs, kv, hd), store.dtype),
        interpret=interpret,
    )(jnp.asarray(table, jnp.int32), store)
    return out.reshape((B, nblk * bs, kv, hd))
