"""Fused per-segment exit-head megakernel: rmsnorm + shared-unembed matmul
+ softmax confidence + exit-update carry merge in ONE streaming pass.

Per decode step and cascade component, the staged executor's exit
evaluation is (a) the exit head's rmsnorm, (b) the ``(B, d) @ (d, V)``
unembedding, and (c) the exit-update scan step
(:mod:`repro.kernels.exit_update`).  Run separately, (b) materializes the
``(B, V)`` logits in HBM just for (c) to stream them back — at serving
vocab sizes the logits round-trip IS the exit head's bandwidth bill.
This kernel deletes it: grid ``(B/Bt, V/Vt)`` with the vocab axis
innermost, the normalized hidden block is computed once per row block
into VMEM scratch (at ``j == 0``), each grid cell multiplies it against
one ``(d, Vt)`` unembedding tile and feeds the logits tile straight into
the running (max, Σexp, argmax) scratch — logits never leave VMEM — and
the last vocab tile applies the full exit-update carry merge exactly as
:func:`repro.kernels.exit_update.exit_update` does.

**Fusion boundary.**  The megakernel fuses the *exit head*, not the
segment body: between decode attention and the exit head sit the
segment's remaining layers (qkv/wo/MLP matmuls under ``lax.scan``), so a
literal attention+head single kernel would have to inline entire
transformer layers.  Decode attention keeps its own exit-masked kernel
(:mod:`repro.kernels.decode_attention`); what this kernel adds is the
elimination of the O(B·V) logits intermediate — the largest tensor the
decode step touches.  Heads outside the boundary (layernorm bias,
enhancement MLP, non-rmsnorm) take the unfused path; callers route via
:meth:`repro.models.model.CascadeModel.exit_head_params`.

**Live-mask grid early-out.**  ``live`` is the per-slot exit mask
(``ctx["live"]`` = ``DecodeState.active``).  A grid cell whose whole
``Bt``-row block is dead skips the norm, the matmul and the softmax
update under ``pl.when`` — a fully-exited cohort's rows cost one
predicate per cell, the same contract as the decode-attention kernel's
per-slot early-out.  Dead rows pass their carry through unchanged (a
retired slot's outputs are never read and its lane re-prefills before
reuse, so pass-through is as good as the dense value at none of the
cost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = -1e30


def _megakernel(*refs, n_vtiles, vt, V, threshold, m, n_components,
                patience_k, ema_decay, dynamic, tel_bins, eps, lowp):
    # ref layout: [th_ref?] x w head live | ans pred exit conf streak ema
    #             act | outs (6 or 7) | scratch: m l a xn
    refs = list(refs)
    th_ref = refs.pop(0) if dynamic else None
    (x_ref, w_ref, head_ref, live_ref, ans_ref, pred_ref, exit_ref,
     conf_ref, streak_ref, ema_ref, act_ref) = refs[:11]
    outs = refs[11:-4]
    ans_o, pred_o, exit_o, conf_o, streak_o, ema_o = outs[:6]
    m_s, l_s, a_s, xn_s = refs[-4:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        a_s[...] = jnp.zeros_like(a_s[...])

    blk_live = jnp.any(live_ref[...] != 0)

    @pl.when(jnp.logical_and(blk_live, j == 0))
    def _norm():
        # the exit head's rmsnorm, once per row block (revisited scratch),
        # operand order bit-locked to kernels/rmsnorm.py
        xv = x_ref[...].astype(jnp.float32)
        var = jnp.mean(jnp.square(xv), axis=-1, keepdims=True)
        y = xv * jax.lax.rsqrt(var + eps)
        xn_s[...] = (y * w_ref[...].astype(jnp.float32)).astype(xn_s.dtype)

    @pl.when(blk_live)
    def _stream():
        lt = jnp.dot(xn_s[...], head_ref[...].astype(xn_s.dtype),
                     preferred_element_type=jnp.float32)
        if lowp:
            # low-precision models emit logits in the model dtype before
            # the f32 confidence math — same rounding as the unfused path
            lt = lt.astype(xn_s.dtype).astype(jnp.float32)
        # vocab pad columns (zero head columns) must never win the max
        col = j * vt + jax.lax.broadcasted_iota(jnp.int32, lt.shape, 1)
        lt = jnp.where(col < V, lt, NEG)
        tile_max = jnp.max(lt, axis=-1)                 # (Bt,)
        tile_arg = jnp.argmax(lt, axis=-1).astype(jnp.int32) + j * vt
        m_old = m_s[...]
        m_new = jnp.maximum(m_old, tile_max)
        l_s[...] = (l_s[...] * jnp.exp(m_old - m_new)
                    + jnp.sum(jnp.exp(lt - m_new[:, None]), axis=-1))
        a_s[...] = jnp.where(tile_max > m_old, tile_arg, a_s[...])
        m_s[...] = m_new

    @pl.when(j == n_vtiles - 1)
    def _update():
        # exit_update's carry merge, with dead rows passing through: every
        # update funnels through ``gate``/``fresh``, so masking the gate
        # with the live row mask is the whole pass-through story (plus the
        # streak and EMA rows, which update outside the gate)
        lv = live_ref[...] != 0
        conf = 1.0 / l_s[...]                # exp(m − lse); inf when dead
        pred = a_s[...]
        last = m >= n_components - 1
        thr = th_ref[0] if dynamic else threshold
        if last:
            gate = jnp.ones_like(conf, bool)
        else:
            gate = conf >= thr
        if patience_k > 0:
            row = jnp.where(jnp.logical_and(gate, lv), streak_ref[...] + 1, 0)
            row = jnp.where(lv, row, streak_ref[...])
            streak_o[...] = row
            gate = row >= patience_k
            if last:
                gate = jnp.ones_like(gate)
        else:
            streak_o[...] = streak_ref[...]
        gate = jnp.logical_and(gate, lv)
        answered = ans_ref[...] != 0
        fresh = jnp.logical_and(gate, jnp.logical_not(answered))
        ans_o[...] = jnp.logical_or(answered, gate).astype(jnp.int32)
        pred_o[...] = jnp.where(fresh, pred, pred_ref[...])
        exit_o[...] = jnp.where(fresh, jnp.int32(m), exit_ref[...])
        cf = jnp.where(fresh, conf, conf_ref[...])
        conf_o[...] = cf
        if ema_decay > 0.0:
            fold = ema_decay * ema_ref[...] + (1.0 - ema_decay) * cf
            ema_o[...] = jnp.where(
                jnp.logical_and(act_ref[...] != 0, lv), fold, ema_ref[...])
        else:
            ema_o[...] = ema_ref[...]
        if tel_bins:
            from repro.autotune.telemetry import pack_rider
            code_o = outs[6]
            cf_t = jnp.where(lv, conf, 0.0)   # no inf into the bin math
            code_o[...] = jnp.where(lv, pack_rider(pred, cf_t, tel_bins), 0)


def exit_head_update(h, norm_w, head, answered, pred, exit_idx, conf,
                     streak, ema, active, *, threshold, m: int,
                     n_components: int, patience_k: int = 0,
                     ema_decay: float = 0.0, tel_bins: int = 0, live=None,
                     eps: float = 1e-5, bt: int = 8, vt: int = 2048,
                     interpret: "bool | None" = None):
    """One fused exit-head component step: rmsnorm(h) @ head streamed over
    vocab tiles into the exit-update scan.

    h (B, d); norm_w (d,); head (d, V); carry vectors as
    :func:`repro.kernels.exit_update.exit_update`; ``live`` the per-slot
    exit mask ((B,) bool, None = all live).  Live rows return exactly what
    ``exit_update(rmsnorm(h) @ head, ...)`` returns; dead rows pass every
    carry through unchanged (their grid cells skip the matmul entirely).
    ``threshold`` folds into the body when a float or rides as an operand
    when a jax scalar (live-threshold pushes never retrace).
    """
    dynamic = isinstance(threshold, jax.Array)
    if dynamic:
        th_arr = jnp.asarray(threshold, jnp.float32).reshape(1)
        th_static = 0.0
    else:
        th_arr = jnp.zeros((1,), jnp.float32)
        th_static = float(threshold)
    return _exit_head_update(
        th_arr, h, norm_w, head, answered, pred, exit_idx, conf, streak,
        ema, active, live, threshold=th_static, dynamic=dynamic, m=m,
        n_components=n_components, patience_k=patience_k,
        ema_decay=ema_decay, tel_bins=int(tel_bins), eps=float(eps), bt=bt,
        vt=vt, interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "threshold", "dynamic", "m", "n_components", "patience_k", "ema_decay",
    "tel_bins", "eps", "bt", "vt", "interpret"))
def _exit_head_update(th_arr, h, norm_w, head, answered, pred, exit_idx,
                      conf, streak, ema, active, live, *, threshold,
                      dynamic, m, n_components, patience_k, ema_decay,
                      tel_bins, eps, bt, vt, interpret):
    B, d = h.shape
    V = head.shape[1]
    bt = min(bt, B)
    vt = min(vt, V)
    padB = (-B) % bt
    padV = (-V) % vt
    x = jnp.pad(h, ((0, padB), (0, 0))) if padB else h
    hd = jnp.pad(head, ((0, 0), (0, padV))) if padV else head
    live = (jnp.ones((B,), jnp.int32) if live is None
            else jnp.asarray(live).astype(jnp.int32))
    vecs = [live,
            jnp.asarray(answered).astype(jnp.int32),
            jnp.asarray(pred).astype(jnp.int32),
            jnp.asarray(exit_idx).astype(jnp.int32),
            jnp.asarray(conf).astype(jnp.float32),
            jnp.asarray(streak).astype(jnp.int32),
            jnp.asarray(ema).astype(jnp.float32),
            jnp.asarray(active).astype(jnp.int32)]
    if padB:
        vecs = [jnp.pad(v, (0, padB)) for v in vecs]
    Bp = B + padB
    n_vtiles = (V + padV) // vt
    kernel = functools.partial(
        _megakernel, n_vtiles=n_vtiles, vt=vt, V=V, threshold=threshold,
        m=int(m), n_components=int(n_components),
        patience_k=int(patience_k), ema_decay=float(ema_decay),
        dynamic=dynamic, tel_bins=tel_bins, eps=eps,
        lowp=(h.dtype != jnp.float32))
    vec_spec = pl.BlockSpec((bt,), lambda i, j: (i,))
    in_specs = ([pl.BlockSpec((1,), lambda i, j: (0,))] if dynamic else [])
    in_specs += [pl.BlockSpec((bt, d), lambda i, j: (i, 0)),
                 pl.BlockSpec((d,), lambda i, j: (0,)),
                 pl.BlockSpec((d, vt), lambda i, j: (0, j))]
    in_specs += [vec_spec] * 8
    out_specs = [vec_spec] * (7 if tel_bins else 6)
    out_shape = [jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.float32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.float32)]
    if tel_bins:
        out_shape += [jax.ShapeDtypeStruct((Bp,), jnp.int32)]
    args = ([th_arr] if dynamic else []) + [x, norm_w, hd] + vecs
    outs = pl.pallas_call(
        kernel,
        grid=(Bp // bt, n_vtiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.int32),
                        pltpu.VMEM((bt, d), h.dtype)],
        interpret=interpret,
    )(*args)
    outs = [o[:B] for o in outs]
    outs[0] = outs[0].astype(bool)
    return tuple(outs)
