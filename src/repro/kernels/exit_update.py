"""Fused exit-update Pallas kernel: softmax-max confidence + threshold gate
+ decision-scan carry update + DecodeState update in ONE pass over the
logits.

Per decode step and cascade component, the exit decision needs (Defs.
3.2/3.3 + Algorithm 1 + the PABEE patience rewrite + the DecodeState
telemetry):

1. δ = max softmax of the (B, V) exit logits, and its argmax;
2. the threshold gate ``δ >= δ̂_m`` (the final component always answers);
3. the patience-streak rewrite (``streak' = gate ? streak+1 : 0``, gate
   becomes ``streak' >= k``) when the measure is ``patience@k``;
4. the first-open-gate carry merge (answered / pred / exit / conf); and
5. on the final component, the per-slot confidence-EMA fold carried in
   :class:`repro.core.exec.DecodeState` (``ema' = d·ema + (1−d)·conf`` for
   active slots).

The dense path runs these as a softmax pass plus ~10 separate (B,)
elementwise ops per component per token.  This kernel streams vocab tiles
through VMEM carrying running (max, Σexp, argmax) scratch — the softmax is
never materialized — and applies ALL the (B,) updates in-register at the
last vocab tile: one HBM read of the logits, O(B) outputs, zero
intermediate traffic.  ``δ̂_m``, the component index and the patience k are
static (thresholds resolve to floats at trace time), so the comparisons
fold into the kernel body.

``DecodeState.segments_run`` is the one piece of state that stays outside:
it counts which ``lax.cond`` branches actually executed, which only the
cond structure in :meth:`repro.core.exec.StagedExecutor.decode_step` can
know.

Grid: (B/Bt, V/Vt), vocab axis innermost.  All (B,) carry vectors ride as
(Bt,) blocks revisited every vocab tile and written once at the last.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = -1e30


def _exit_update_kernel(*refs, n_vtiles, vt, threshold, m, n_components,
                        patience_k, ema_decay, dynamic, tel_bins):
    # ref layout: [th_ref?] x ans pred exit conf streak ema act |
    #             ans pred exit conf streak ema [tel_code]? | scratch×3
    refs = list(refs)
    th_ref = refs.pop(0) if dynamic else None
    (x_ref, ans_ref, pred_ref, exit_ref, conf_ref, streak_ref, ema_ref,
     act_ref) = refs[:8]
    outs = refs[8:-3]
    ans_o, pred_o, exit_o, conf_o, streak_o, ema_o = outs[:6]
    m_s, l_s, a_s = refs[-3:]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        a_s[...] = jnp.zeros_like(a_s[...])

    x = x_ref[...].astype(jnp.float32)              # (Bt, Vt)
    tile_max = jnp.max(x, axis=-1)                  # (Bt,)
    tile_arg = jnp.argmax(x, axis=-1).astype(jnp.int32) + j * vt
    m_old = m_s[...]
    m_new = jnp.maximum(m_old, tile_max)
    l_s[...] = (l_s[...] * jnp.exp(m_old - m_new)
                + jnp.sum(jnp.exp(x - m_new[:, None]), axis=-1))
    a_s[...] = jnp.where(tile_max > m_old, tile_arg, a_s[...])
    m_s[...] = m_new

    @pl.when(j == n_vtiles - 1)
    def _update():
        conf = 1.0 / l_s[...]                       # exp(m − lse) = 1/Σe^{x−m}
        pred = a_s[...]
        last = m >= n_components - 1
        # the final component's gate is open BEFORE the patience rewrite
        # (its streak row always advances), exactly like the dense
        # ThresholdPolicy.component_gate + scan_component order
        thr = th_ref[0] if dynamic else threshold
        if last:
            gate = jnp.ones_like(conf, bool)
        else:
            gate = conf >= thr
        if patience_k > 0:                          # patience@k rewrite
            row = jnp.where(gate, streak_ref[...] + 1, 0)
            streak_o[...] = row
            gate = row >= patience_k
            if last:
                gate = jnp.ones_like(gate)
        else:
            streak_o[...] = streak_ref[...]
        answered = ans_ref[...] != 0
        fresh = jnp.logical_and(gate, jnp.logical_not(answered))
        ans_o[...] = jnp.logical_or(answered, gate).astype(jnp.int32)
        pred_o[...] = jnp.where(fresh, pred, pred_ref[...])
        exit_o[...] = jnp.where(fresh, jnp.int32(m), exit_ref[...])
        cf = jnp.where(fresh, conf, conf_ref[...])
        conf_o[...] = cf
        if ema_decay > 0.0:                         # DecodeState EMA fold
            ema_o[...] = jnp.where(
                act_ref[...] != 0,
                ema_decay * ema_ref[...] + (1.0 - ema_decay) * cf,
                ema_ref[...])
        else:
            ema_o[...] = ema_ref[...]
        if tel_bins:
            # autotune telemetry rides the same streaming pass: the ONE
            # packed prediction/confidence-bin code, O(Bt) extra work at
            # the last vocab tile.  pack_rider is pure jnp, so calling it
            # here keeps the kernel bit-locked to the dense path by
            # construction, not by comment.
            from repro.autotune.telemetry import pack_rider
            code_o = outs[6]
            code_o[...] = pack_rider(pred, conf, tel_bins)


def exit_update(logits, answered, pred, exit_idx, conf, streak, ema, active,
                *, threshold, m: int, n_components: int,
                patience_k: int = 0, ema_decay: float = 0.0,
                tel_bins: int = 0, bt: int = 8, vt: int = 2048,
                interpret: "bool | None" = None):
    """One fused component step of the exit-decision scan.

    logits (B, V); answered/active (B,) bool; pred/exit_idx/streak (B,)
    int32; conf/ema (B,) f32.  Static: component ``m`` of
    ``n_components``, ``patience_k`` (0 = stateless measure), ``ema_decay``
    (0 = no EMA fold; pass the final component's decay), ``tel_bins``
    (> 0 additionally returns autotune telemetry computed in the same
    streaming pass).  ``threshold`` δ̂_m is a float (folded into the
    kernel body — the default) or a jax scalar (read as a kernel operand:
    the autotune live-threshold path, where a controller pushes new
    thresholds without retracing).

    Returns (answered', pred', exit', conf', streak', ema') with exactly
    :meth:`repro.core.policy.ExitDecider.scan_component` semantics (plus
    the :class:`~repro.core.exec.DecodeState` EMA fold when asked); with
    ``tel_bins`` one extra (B,) int32 output follows: the packed
    telemetry code ``raw_pred * tel_bins + conf_bin``.
    """
    dynamic = isinstance(threshold, jax.Array)
    if dynamic:
        th_arr = jnp.asarray(threshold, jnp.float32).reshape(1)
        th_static = 0.0
    else:
        th_arr = jnp.zeros((1,), jnp.float32)
        th_static = float(threshold)
    return _exit_update(th_arr, logits, answered, pred, exit_idx, conf,
                        streak, ema, active, threshold=th_static,
                        dynamic=dynamic, m=m, n_components=n_components,
                        patience_k=patience_k, ema_decay=ema_decay,
                        tel_bins=int(tel_bins), bt=bt, vt=vt,
                        interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=(
    "threshold", "dynamic", "m", "n_components", "patience_k", "ema_decay",
    "tel_bins", "bt", "vt", "interpret"))
def _exit_update(th_arr, logits, answered, pred, exit_idx, conf, streak,
                 ema, active, *, threshold, dynamic, m, n_components,
                 patience_k, ema_decay, tel_bins, bt, vt, interpret):
    B, V = logits.shape
    bt = min(bt, B)
    vt = min(vt, V)
    padB = (-B) % bt
    padV = (-V) % vt
    x = logits
    if padB or padV:
        x = jnp.pad(x, ((0, padB), (0, padV)), constant_values=NEG)
    vecs = [jnp.asarray(answered).astype(jnp.int32),
            jnp.asarray(pred).astype(jnp.int32),
            jnp.asarray(exit_idx).astype(jnp.int32),
            jnp.asarray(conf).astype(jnp.float32),
            jnp.asarray(streak).astype(jnp.int32),
            jnp.asarray(ema).astype(jnp.float32),
            jnp.asarray(active).astype(jnp.int32)]
    if padB:
        vecs = [jnp.pad(v, (0, padB)) for v in vecs]
    Bp, Vp = x.shape
    n_vtiles = Vp // vt
    kernel = functools.partial(
        _exit_update_kernel, n_vtiles=n_vtiles, vt=vt,
        threshold=threshold, m=int(m),
        n_components=int(n_components), patience_k=int(patience_k),
        ema_decay=float(ema_decay), dynamic=dynamic, tel_bins=tel_bins)
    vec_spec = pl.BlockSpec((bt,), lambda i, j: (i,))
    in_specs = ([pl.BlockSpec((1,), lambda i, j: (0,))] if dynamic else [])
    in_specs += [pl.BlockSpec((bt, vt), lambda i, j: (i, j))]
    in_specs += [vec_spec] * 7
    out_specs = [vec_spec] * (7 if tel_bins else 6)
    out_shape = [jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.float32),
                 jax.ShapeDtypeStruct((Bp,), jnp.int32),
                 jax.ShapeDtypeStruct((Bp,), jnp.float32)]
    if tel_bins:
        out_shape += [jax.ShapeDtypeStruct((Bp,), jnp.int32)]
    args = ([th_arr] if dynamic else []) + [x] + vecs
    outs = pl.pallas_call(
        kernel,
        grid=(Bp // bt, n_vtiles),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.int32)],
        interpret=interpret,
    )(*args)
    outs = [o[:B] for o in outs]
    outs[0] = outs[0].astype(bool)
    return tuple(outs)
