"""Fused RMSNorm Pallas kernel.

One pass per row tile: read (Rt, d) into VMEM, compute the f32 mean-square on
the VPU, scale, write back.  Saves the extra HBM round-trip XLA emits when
the variance reduction and the scale multiply don't fuse (observed in the
lowered HLO of the baseline dry-run).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)              # (Rt, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, w, *, rt: int = 8, eps: float = 1e-5,
            interpret: "bool | None" = None):
    """x: (R, d); w: (d,).  Rows tiled by rt; d kept whole in VMEM
    (d ≤ 8192 ⇒ (8, 8192) f32 tile = 256 KiB, well within VMEM).
    ``interpret`` resolves outside the jit boundary."""
    return _rmsnorm(x, w, rt=rt, eps=eps,
                    interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("rt", "eps", "interpret"))
def _rmsnorm(x, w, *, rt, eps, interpret):
    R, d = x.shape
    rt = min(rt, R)
    pad = (-R) % rt
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    Rp = xp.shape[0]
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(Rp // rt,),
        in_specs=[pl.BlockSpec((rt, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((rt, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Rp, d), x.dtype),
        interpret=interpret,
    )(xp, w)
    return out[:R]
