"""Cross-model escalation tier (repro.escalate): parity corners against the
single-engine baselines, the composed heterogeneous-cost solver, prefix
replay + accounting, soft-cap block donation, and the ``budget@:shared``
deprecation routing.
"""
import warnings

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.core.policy as policy_mod
from repro.autotune.solver import (ExitHistogram, compose_escalation,
                                   compose_mac_prefix,
                                   edges_from_thresholds, solve_epsilon,
                                   split_tier_thresholds,
                                   thresholds_from_edges)
from repro.autotune.telemetry import init_telemetry, n_cells
from repro.configs import get_config, reduced
from repro.core.policy import get_policy
from repro.escalate import (EscalationRouter, ModelCascadeTier,
                            TierThresholdController, build_replay,
                            prefix_compatible, resolve_share_prefix)
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request
from repro.serving.paged.pool import BlockPool


@pytest.fixture(scope="module")
def stack():
    """Two real reduced models sharing vocab + family: a 2-layer draft and
    a 4-layer authority (committed prefixes replay between them)."""
    cfg_s = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg_b = reduced(get_config("qwen2.5-3b"),
                    n_layers=4).replace(dtype="float32")
    m_s = build_model(cfg_s)
    p_s = m_s.init(jax.random.PRNGKey(0))
    m_b = build_model(cfg_b)
    p_b = m_b.init(jax.random.PRNGKey(1))
    return cfg_s, m_s, p_s, cfg_b, m_b, p_b


def _prompts(cfg, n=3, length=6, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, length).astype(np.int32)
            for _ in range(n)]


def _reqs(prompts, max_new=4):
    return [Request(rid=i, prompt=p.copy(), max_new_tokens=max_new)
            for i, p in enumerate(prompts)]


def _engine(cfg, model, params, runtime="host", **kw):
    kw.setdefault("lane_batch", 4)
    kw.setdefault("n_lanes", 1)
    kw.setdefault("cache_len", 32)
    kw.setdefault("chunk", 4)
    return CascadeServingEngine(cfg, model, params, runtime=runtime, **kw)


def _paged(cfg):
    return cfg.with_paged_cache(layout="paged", block_size=8)


# ---------------------------------------------------------------------------
# parity corners: the tier collapses bit-identically onto either engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime,layout", [
    ("host", "dense"), ("host", "paged"),
    ("device", "dense"), ("device", "paged")])
def test_escalate_never_is_small_engine(stack, runtime, layout):
    cfg_s, m_s, p_s, cfg_b, m_b, p_b = stack
    if layout == "paged":
        cfg_s, cfg_b = _paged(cfg_s), _paged(cfg_b)
    prompts = _prompts(cfg_s)

    small = _engine(cfg_s, m_s, p_s, runtime)
    for r in _reqs(prompts):
        small.submit(r)
    small.run(100)

    tier = ModelCascadeTier([
        _engine(cfg_s.with_escalation(enabled=True, threshold=0.0),
                m_s, p_s, runtime),
        _engine(cfg_b, m_b, p_b, runtime)])
    for r in _reqs(prompts):
        tier.submit(r)
    fin = tier.run(100)

    assert len(fin) == len(prompts)
    for i in range(len(prompts)):
        assert fin[i]["tokens"] == small.finished[i]["tokens"]
        assert fin[i]["exit_depths"] == small.finished[i]["exit_depths"]
        assert fin[i]["confs"] == small.finished[i]["confs"]
        assert fin[i]["escalations"] == 0
        assert fin[i]["final_stage"] == 0
    assert tier.stats()["escalations_total"] == 0


@pytest.mark.parametrize("runtime,layout", [
    ("host", "dense"), ("host", "paged"),
    ("device", "dense"), ("device", "paged")])
def test_escalate_always_is_big_engine(stack, runtime, layout):
    """Escalation threshold 1.1 + stage-0 intra thresholds at the 1.1
    never-exit sentinel: every request defers at its FIRST token (empty
    committed prefix), so stage 1 sees the exact original workload."""
    cfg_s, m_s, p_s, cfg_b, m_b, p_b = stack
    if layout == "paged":
        cfg_s, cfg_b = _paged(cfg_s), _paged(cfg_b)
    prompts = _prompts(cfg_s)

    big = _engine(cfg_b, m_b, p_b, runtime)
    for r in _reqs(prompts):
        big.submit(r)
    big.run(100)

    cfg_s1 = cfg_s.with_cascade(thresholds=(1.1, 0.0)).with_escalation(
        enabled=True, threshold=1.1)
    tier = ModelCascadeTier([_engine(cfg_s1, m_s, p_s, runtime),
                             _engine(cfg_b, m_b, p_b, runtime)])
    for r in _reqs(prompts):
        tier.submit(r)
    fin = tier.run(100)

    assert len(fin) == len(prompts)
    for i in range(len(prompts)):
        assert fin[i]["tokens"] == big.finished[i]["tokens"]
        assert fin[i]["exit_depths"] == big.finished[i]["exit_depths"]
        assert fin[i]["confs"] == big.finished[i]["confs"]
        assert fin[i]["escalations"] == 1
        assert fin[i]["final_stage"] == 1
    st_ = tier.stats()
    assert st_["escalations_total"] == len(prompts)
    esc1 = st_["stages"][1]["escalation"]
    assert esc1["escalated_requests_admitted"] == len(prompts)
    # empty committed prefix: nothing replayed
    assert esc1["prefill_positions_replayed"] == 0


def test_mid_threshold_defers_are_predictable(stack):
    """At an intermediate escalation threshold the tier's committed
    prefixes are exactly what the defer rule says on the small engine's
    standalone streams, and the replayed prefill positions land in the
    escalation accounting (not the fresh counter)."""
    cfg_s, m_s, p_s, cfg_b, m_b, p_b = stack
    prompts = _prompts(cfg_s, n=4)
    max_new = 6

    small = _engine(cfg_s, m_s, p_s)
    for r in _reqs(prompts, max_new):
        small.submit(r)
    small.run(100)

    # pick a threshold that splits the observed final-component
    # confidences so at least one request defers at a token > 0 and at
    # least one never defers
    n_m = cfg_s.cascade.n_components
    final_confs = sorted(
        c for rec in small.finished.values()
        for d, c in zip(rec["exit_depths"], rec["confs"]) if d == n_m - 1)
    assert final_confs, "stage 0 never answered at its final component"
    esc_th = final_confs[len(final_confs) // 2]

    def expected_defer(rec):
        for i, (d, c) in enumerate(zip(rec["exit_depths"], rec["confs"])):
            if d == n_m - 1 and c < esc_th:
                return i
        return None

    tier = ModelCascadeTier([
        _engine(cfg_s.with_escalation(enabled=True, threshold=esc_th),
                m_s, p_s),
        _engine(cfg_b, m_b, p_b)])
    for r in _reqs(prompts, max_new):
        tier.submit(r)
    fin = tier.run(200)

    n_deferred, replayed_total = 0, 0
    for i in range(len(prompts)):
        rec, d = small.finished[i], expected_defer(small.finished[i])
        assert len(fin[i]["tokens"]) == max_new
        if d is None:
            assert fin[i]["escalations"] == 0
            assert fin[i]["tokens"] == rec["tokens"]
        else:
            n_deferred += 1
            replayed_total += d
            assert fin[i]["escalations"] == 1
            assert fin[i]["final_stage"] == 1
            # committed prefix = the small engine's stream up to the defer
            assert fin[i]["tokens"][:d] == rec["tokens"][:d]
            assert fin[i]["confs"][:d] == rec["confs"][:d]
            assert fin[i]["spans"][0] == {"stage": 0, "n_tokens": d,
                                          "kept": True}
    assert n_deferred >= 1, "threshold deferred nothing — corner, not mid"
    esc1 = tier.stats()["stages"][1]["escalation"]
    assert esc1["prefill_positions_replayed"] == replayed_total
    assert esc1["escalated_requests_admitted"] == n_deferred
    if replayed_total:
        assert esc1["replay_prefill_macs"] > 0.0


# ---------------------------------------------------------------------------
# replay + router units
# ---------------------------------------------------------------------------

def test_prefix_compatibility_and_share_resolution(stack):
    cfg_s, _, _, cfg_b, _, _ = stack
    assert prefix_compatible(cfg_s, cfg_b)
    other = cfg_b.replace(family="moe")
    assert not prefix_compatible(cfg_s, other)
    assert resolve_share_prefix(cfg_s, cfg_b)
    assert not resolve_share_prefix(
        cfg_s.with_escalation(share_prefix=False), cfg_b)
    with pytest.raises(ValueError):
        resolve_share_prefix(
            cfg_s.with_escalation(share_prefix=True), other)


def test_build_replay():
    prompt = np.arange(5, dtype=np.int32)
    p, new, rep = build_replay(prompt, [7, 8], 6, share_prefix=True)
    assert p.tolist() == [0, 1, 2, 3, 4, 7, 8]
    assert (new, rep) == (4, 2)
    p, new, rep = build_replay(prompt, [7, 8], 6, share_prefix=False)
    assert p.tolist() == list(range(5)) and (new, rep) == (6, 0)
    with pytest.raises(ValueError):
        build_replay(prompt, [1] * 6, 6, share_prefix=True)


def test_router_defer_rule(stack):
    cfg_s, _, _, cfg_b, _, _ = stack
    router = EscalationRouter([
        cfg_s.with_escalation(enabled=True, threshold=0.6), cfg_b])
    n_m = cfg_s.cascade.n_components
    assert router.should_defer(0, n_m - 1, 0.5)
    assert not router.should_defer(0, n_m - 1, 0.7)
    assert not router.should_defer(0, 0, 0.1)     # early exits stand
    assert not router.should_defer(1, 99, 0.0)    # last stage: authority
    assert router.first_defer(0, [0, n_m - 1, n_m - 1],
                              [0.1, 0.9, 0.2]) == 2
    router.observe_regeneration(5, 5)
    router.observe_regeneration(5, 6)
    assert router.stage_agree(min_observations=2) == 0.5
    assert router.stage_agree(prior=0.9, min_observations=3) == 0.9


def test_router_rejects_mismatched_measure(stack):
    cfg_s, _, _, cfg_b, _, _ = stack
    bad = cfg_s.with_escalation(enabled=True, confidence="entropy")
    with pytest.raises(ValueError, match="decision-time confidence"):
        EscalationRouter([bad, cfg_b])


# ---------------------------------------------------------------------------
# heterogeneous-cost composition + solver
# ---------------------------------------------------------------------------

def test_compose_mac_prefix():
    got = compose_mac_prefix([[1.0, 3.0], [10.0, 40.0]], [2.0])
    # stage 1 entries carry stage 0's full depth + its replay overhead
    assert got == (1.0, 3.0, 15.0, 45.0)
    with pytest.raises(ValueError):
        compose_mac_prefix([[1.0], [2.0]], [0.5, 0.5])


def test_split_tier_thresholds():
    ths = (0.3, 0.7, 0.5, 0.0)
    s0, esc, s1 = split_tier_thresholds(ths, n_components0=2)
    assert s0 == (0.3, 0.0)
    assert esc == 0.7
    assert s1 == (0.5, 0.0)
    with pytest.raises(ValueError):
        split_tier_thresholds((0.3, 0.0), 2)


def _route_final_hist(bins, n0, rng, n=4000, agree_p=0.9):
    """A draft histogram with its final confidence as a routing axis:
    from_samples with an (n0, N) confidence matrix against an
    (n0 + 1)-entry mac prefix treats all n0 rows as routing axes."""
    conf = rng.random((n0, n))
    agr = (rng.random((n0, n)) < agree_p).astype(np.float64)
    macs = [float(2 ** i) for i in range(n0 + 1)]
    return ExitHistogram.from_samples(conf, agr, macs, bins)


def test_compose_escalation_marginals():
    rng = np.random.default_rng(7)
    bins, n0, n1 = 4, 2, 3
    h0 = _route_final_hist(bins, n0, rng)
    c1 = rng.random((n1 - 1, 5000))
    a1 = (rng.random((n1 - 1, 5000)) < 0.8).astype(np.float64)
    h1 = ExitHistogram.from_samples(c1, a1, [1.0, 2.0, 4.0], bins)
    sa = 0.7
    # per-stage prefixes are each stage's OWN K entries (the route-final
    # extra entry belongs to h0's standalone prefix, not the composition)
    mp = compose_mac_prefix([[1.0, 2.0], [10.0, 20.0, 40.0]])
    joint = compose_escalation(h0, h1, stage_agree=sa, mac_prefix=mp)

    r0, r1 = h0.n_routing, h1.n_routing
    assert joint.n_routing == r0 + r1
    assert joint.total == pytest.approx(h0.total)
    jc = joint.counts.reshape((bins,) * (r0 + r1))
    # stage-0 marginal: summing out the stage-1 axes recovers h0
    np.testing.assert_allclose(
        jc.sum(axis=tuple(range(r0, r0 + r1))), h0.counts)
    # stage-1 marginal: h1's distribution scaled to h0's mass
    np.testing.assert_allclose(
        jc.sum(axis=tuple(range(r0))),
        h0.total * h1.counts / h1.total)
    # stage-0 agree rows chain through stage_agree
    ja = joint.agree.reshape((r0 + r1,) + (bins,) * (r0 + r1))
    for m in range(r0):
        np.testing.assert_allclose(
            ja[m].sum(axis=tuple(range(r0, r0 + r1))),
            sa * h0.agree[m])
    # stage-1 agree rows: h1's agreement through h0's cell mass
    for j in range(r1):
        np.testing.assert_allclose(
            ja[r0 + j].sum(axis=tuple(range(r0))),
            h0.counts.sum() * h1.agree[j] / h1.total,
            rtol=1e-9)


def test_compose_escalation_solver_corners():
    """stage_agree=0 forces the solver off the draft entirely; a perfectly
    agreeing cheap draft absorbs everything."""
    rng = np.random.default_rng(3)
    bins, n0 = 4, 2
    c1 = rng.random((1, 4000))
    a1 = np.ones((1, 4000))
    h1 = ExitHistogram.from_samples(c1, a1, [100.0, 200.0], bins)

    h0_good = _route_final_hist(bins, n0, rng, agree_p=1.0)
    joint = compose_escalation(
        h0_good, h1, stage_agree=1.0,
        mac_prefix=compose_mac_prefix([[1.0, 2.0], [100.0, 200.0]]))
    res = solve_epsilon(joint, 0.05)
    assert res.feasible
    # a perfect draft answers everything at its first component
    assert res.avg_macs == pytest.approx(1.0)

    h0_bad = _route_final_hist(bins, n0, rng, agree_p=0.5)
    joint = compose_escalation(
        h0_bad, h1, stage_agree=0.0,
        mac_prefix=compose_mac_prefix([[1.0, 2.0], [100.0, 200.0]]))
    res = solve_epsilon(joint, 0.05)
    s0, esc, s1 = split_tier_thresholds(res.thresholds, n0)
    # nothing may answer on the draft: every draft gate at the sentinel
    assert all(t > 1.0 for t in s0[:-1])
    assert esc > 1.0
    assert res.avg_macs >= 100.0


def test_compose_escalation_starved_next_stage():
    """No stage-1 evidence: its factor degrades to uniform with zero
    intra agreement, so the solver leans on deferral (the proxy-perfect
    final), never on unobserved stage-1 intra exits."""
    rng = np.random.default_rng(5)
    bins, n0 = 4, 2
    h0 = _route_final_hist(bins, n0, rng)
    empty = ExitHistogram(
        counts=np.zeros((bins,)), agree=np.zeros((1, bins)),
        mac_prefix=np.asarray([10.0, 20.0]), bins=bins)
    joint = compose_escalation(
        h0, empty, stage_agree=0.9,
        mac_prefix=compose_mac_prefix([[1.0, 2.0], [10.0, 20.0]]))
    assert joint.total == pytest.approx(h0.total)
    ja = joint.agree.reshape((joint.n_routing,) + (bins,) * joint.n_routing)
    assert float(np.abs(ja[-1]).sum()) == 0.0


def test_route_final_telemetry_shapes():
    cfg = reduced(get_config("qwen2.5-3b")).with_autotune(
        enabled=True, epsilon=0.1, bins=8)
    n_m = cfg.cascade.n_components
    assert n_cells(n_m, 8) == 8 ** (n_m - 1)
    assert n_cells(n_m, 8, route_final=True) == 8 ** n_m
    tel = init_telemetry(n_m, 8, [1.0] * n_m)
    assert tel.shadow_agree.shape == (n_m - 1, 8 ** (n_m - 1))
    tel_rf = init_telemetry(n_m, 8, [1.0] * n_m, route_final=True)
    assert tel_rf.shadow_agree.shape == (n_m, 8 ** n_m)
    assert tel_rf.shadow_count.shape == (8 ** n_m,)


def test_route_final_streams_unchanged(stack):
    """route_final only widens telemetry — token/exit/conf streams are
    identical with it on and off."""
    cfg_s, m_s, p_s, *_ = stack
    prompts = _prompts(cfg_s)
    runs = {}
    for rf in (False, True):
        cfg = cfg_s.with_autotune(enabled=True, epsilon=0.1, bins=8,
                                  shadow_every=2, route_final=rf)
        eng = _engine(cfg, build_model(cfg), p_s)
        for r in _reqs(prompts):
            eng.submit(r)
        eng.run(100)
        runs[rf] = eng
    for i in range(len(prompts)):
        assert runs[True].finished[i]["tokens"] == \
            runs[False].finished[i]["tokens"]
        assert runs[True].finished[i]["confs"] == \
            runs[False].finished[i]["confs"]


def test_tier_controller_pushes_solved_thresholds(stack):
    cfg_s, _, p_s, cfg_b, _, p_b = stack
    cfg0 = cfg_s.with_autotune(enabled=True, epsilon=0.2, bins=8,
                               shadow_every=2, route_final=True) \
        .with_escalation(enabled=True, threshold=0.5)
    cfg1 = cfg_b.with_autotune(enabled=True, epsilon=0.2, bins=8,
                               shadow_every=2)
    e0 = _engine(cfg0, build_model(cfg0), p_s)
    e1 = _engine(cfg1, build_model(cfg1), p_b)
    ctl = TierThresholdController(epsilon=0.2, interval=8, min_shadow=4.0,
                                  min_escalations=2)
    tier = ModelCascadeTier([e0, e1], controller=ctl)
    prompts = _prompts(cfg_s, n=6)
    for r in _reqs(prompts, max_new=10):
        tier.submit(r)
    tier.run(400)
    assert ctl.solves >= 1
    ths0, esc, ths1 = ctl.last_thresholds
    assert e0.current_thresholds() == ths0
    assert e1.current_thresholds() == ths1
    assert tier.router.thresholds[0] == esc
    assert ths0[-1] == 0.0 and ths1[-1] == 0.0


def test_tier_controller_requires_route_final(stack):
    cfg_s, _, p_s, cfg_b, _, p_b = stack
    cfg0 = cfg_s.with_autotune(enabled=True, epsilon=0.2)
    cfg1 = cfg_b.with_autotune(enabled=True, epsilon=0.2)
    e0 = _engine(cfg0, build_model(cfg0), p_s)
    e1 = _engine(cfg1, build_model(cfg1), p_b)
    with pytest.raises(ValueError, match="route_final"):
        ModelCascadeTier([e0, e1],
                         controller=TierThresholdController(epsilon=0.2))


# ---------------------------------------------------------------------------
# soft-cap donation + metrics-window semantics
# ---------------------------------------------------------------------------

def test_block_pool_soft_cap():
    pool = BlockPool(num_blocks=9, block_size=4, block_bytes=128)
    assert pool.can_alloc(8)
    pool.set_soft_cap(3)
    assert not pool.can_alloc(4)
    ids = pool.alloc(3)
    assert len(ids) == 3
    assert pool.alloc(1) is None           # cap-bound, not free-list-bound
    pool.set_soft_cap(None)
    assert pool.can_alloc(5)
    assert pool.stats()["soft_cap"] is None
    pool.set_soft_cap(100)                 # clamps to physical (8)
    assert pool.soft_cap == 8
    with pytest.raises(ValueError):
        pool.set_soft_cap(-1)


def test_block_pool_reset_window_preserves_peak():
    pool = BlockPool(num_blocks=9, block_size=4)
    ids = pool.alloc(5)
    pool.free(ids[:3], by_exit=True)
    pool.begin_chunk()
    pool.free(ids[3:])
    pool.end_chunk()
    assert pool.chunk_reclaims == [2]
    pool.reset_window()
    assert pool.chunk_reclaims == []
    assert pool.peak_used == 5
    assert pool.reclaimed_by_exit == 3
    assert pool.reclaimed_at_retire == 2


def test_engine_reset_metrics_preserves_pool_peak(stack):
    cfg_s, m_s, p_s, *_ = stack
    eng = _engine(_paged(cfg_s), m_s, p_s)
    for r in _reqs(_prompts(cfg_s)):
        eng.submit(r)
    eng.run(100)
    peak = eng.pcache.pool.peak_used
    assert peak > 0
    assert eng.pcache.pool.chunk_reclaims
    eng.reset_metrics()
    assert eng.pcache.pool.peak_used == peak
    assert eng.pcache.pool.chunk_reclaims == []
    esc = eng.stats()["escalation"]
    assert esc["prefill_positions_fresh"] == 0
    assert esc["replay_prefill_macs"] == 0.0


def test_tier_block_donation(stack):
    cfg_s, m_s, p_s, cfg_b, m_b, p_b = stack
    e0 = _engine(_paged(cfg_s), m_s, p_s)
    e1 = _engine(_paged(cfg_b), m_b, p_b)
    tier = ModelCascadeTier([e0, e1])
    with pytest.raises(ValueError, match="soft caps"):
        tier.donate_blocks(0, 1, 2)
    p0, p1 = e0.pcache.pool, e1.pcache.pool
    p0.set_soft_cap(6)
    p1.set_soft_cap(6)
    cap0, cap1 = p0.soft_cap, p1.soft_cap
    gained = tier.donate_blocks(0, 1, 4)
    # byte-priced: the big stage's blocks cost more, so it gains at most
    # the byte-equivalent of 4 draft blocks (and the budget never grows)
    assert gained == (4 * p0.block_bytes) // p1.block_bytes
    assert p1.soft_cap == cap1 + gained
    charged = cap0 - p0.soft_cap
    assert 0 < charged <= 4
    assert charged * p0.block_bytes >= gained * p1.block_bytes
    assert tier.stats()["blocks_donated"] == gained


def test_donation_requires_matching_geometry(stack):
    cfg_s, m_s, p_s, cfg_b, m_b, p_b = stack
    e0 = _engine(_paged(cfg_s), m_s, p_s)
    e1 = _engine(cfg_b, m_b, p_b)             # dense: nothing to donate
    tier = ModelCascadeTier([e0, e1])
    with pytest.raises(ValueError, match="paged"):
        tier.donate_blocks(0, 1, 1)


# ---------------------------------------------------------------------------
# satellite: budget@macs:shared deprecation routing
# ---------------------------------------------------------------------------

def _budget_fixture():
    rng = np.random.default_rng(11)
    confs = [rng.random(3000) for _ in range(3)]
    corrects = [(rng.random(3000) < p).astype(np.float64)
                for p in (0.7, 0.8, 0.95)]
    return confs, corrects, [1.0, 2.0, 4.0]


def test_shared_alias_routes_through_solver():
    """budget@X:shared with correctness warns once and lands on the SAME
    thresholds as the solver spelling."""
    confs, corrects, macs = _budget_fixture()
    solver_pol = get_policy("budget@2.0")
    solver_pol.fit(confs, macs, corrects=corrects)

    policy_mod._SHARED_QUANTILE_WARNED = False
    shared_pol = get_policy("budget@2.0:shared")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        shared_pol.fit(confs, macs, corrects=corrects)
    dep = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(dep) == 1
    assert shared_pol.thresholds == solver_pol.thresholds
    assert shared_pol.fitted_avg_macs == solver_pol.fitted_avg_macs

    # the warning is one-time: a second fit stays silent
    with warnings.catch_warnings(record=True) as w2:
        warnings.simplefilter("always")
        get_policy("budget@2.0:shared").fit(confs, macs,
                                            corrects=corrects)
    assert not [x for x in w2
                if issubclass(x.category, DeprecationWarning)]
    policy_mod._SHARED_QUANTILE_WARNED = False


def test_budget_without_corrects_keeps_legacy_bisection():
    confs, _, macs = _budget_fixture()
    policy_mod._SHARED_QUANTILE_WARNED = False
    pol = get_policy("budget@2.0")
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        pol.fit(confs, macs)
    assert [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert pol.thresholds is not None
    policy_mod._SHARED_QUANTILE_WARNED = False


# ---------------------------------------------------------------------------
# threshold <-> edge round-trip (hypothesis)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(2, 64), st.integers(1, 4), st.integers(0, 10 ** 9))
def test_edges_thresholds_roundtrip(bins, n_routing, seed):
    rng = np.random.default_rng(seed)
    edges = tuple(int(rng.integers(0, bins + 1)) for _ in range(n_routing))
    ths = thresholds_from_edges(edges, bins)
    assert len(ths) == n_routing + 1 and ths[-1] == 0.0
    assert edges_from_thresholds(ths, bins) == edges
    # and a full double round-trip is a fixed point
    assert thresholds_from_edges(
        edges_from_thresholds(ths, bins), bins) == ths
