"""Mixture-of-Experts layer: top-k router + capacity-based GShard dispatch.

The einsum dispatch/combine formulation is the TPU-native mapping of the MoE
all-to-all: with expert weights sharded over the ``model`` mesh axis
(expert-parallel), XLA lowers the (token, expert, capacity) einsums to the
dispatch collectives.  When ``n_experts`` does not divide the model axis
(mixtral: 8 experts on a 16-way axis) the config falls back to tensor-parallel
expert FFNs (``d_ff`` sharding) — decided in launch/shard_rules.py.

Router load-balance auxiliary loss follows Switch/GShard:
``aux = E * Σ_e f_e · p_e`` with f = fraction of tokens dispatched to e and
p = mean router probability of e.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import nn
from repro.models.layers import norm_init


def moe_init(key, cfg):
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd, kn = nn.split_keys(key, 5)
    return {
        "router": nn.dense_init(kr, (d, E)),
        "w_gate": nn.dense_init(kg, (E, d, ff)),
        "w_up": nn.dense_init(ku, (E, d, ff)),
        "w_down": nn.dense_init(kd, (E, ff, d)),
        "norm": norm_init(kn, cfg, d),
    }


def capacity(n_tokens: int, n_experts: int, top_k: int,
             capacity_factor: float) -> int:
    c = int(math.ceil(top_k * n_tokens / n_experts * capacity_factor))
    return max(4, c)


def route_topk(router_logits, top_k: int, cap: int):
    """Compute dispatch/combine tensors.

    router_logits: (T, E).  Returns (dispatch (T,E,C) bool-ish float,
    combine (T,E,C) float, aux_loss scalar).
    """
    T, E = router_logits.shape
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, top_k)          # (T, k)
    # renormalize the chosen gates (mixtral-style)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # expert one-hots per slot: (k, T, E)
    onehots = jax.nn.one_hot(gate_idx.T, E, dtype=jnp.float32)
    # position of each (slot, token) within its expert queue: earlier slots
    # get priority, then token order.
    flat = onehots.reshape(top_k * T, E)
    pos_in_expert = jnp.cumsum(flat, axis=0) - flat        # (k*T, E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1)           # (k*T,)
    keep = (pos < cap) & (jnp.sum(flat, -1) > 0)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.float32) * keep[:, None]
    # dispatch (k*T, E, C) -> (T, E, C) summing slots
    disp = (flat[:, :, None] * pos_oh[:, None, :]).reshape(top_k, T, E, cap)
    dispatch = jnp.sum(disp, axis=0)
    gates_flat = gate_vals.T.reshape(top_k * T)            # (k*T,)
    comb = disp * gates_flat.reshape(top_k, T, 1, 1)
    combine = jnp.sum(comb, axis=0)

    # load-balance aux loss
    frac_dispatch = jnp.mean(jnp.sum(onehots, axis=0), axis=0)  # (E,)
    frac_prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_dispatch * frac_prob)
    return dispatch, combine, aux


GROUP_TOKENS = 4096  # routing-group size: bounds the (Tg, E, C) dispatch


def moe_apply(params, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out, aux_loss).

    Tokens are routed in *groups* of <= GROUP_TOKENS (the GShard/t5x layout):
    the dispatch one-hot is (G, Tg, E, C) with per-group capacity, so its size
    is linear — not quadratic — in total tokens.  On the mesh, G is sharded
    over the data axis and E over the model axis (expert parallelism); the
    dispatch/combine einsums are where XLA inserts the MoE all-to-alls.
    """
    B, S, d = x.shape
    T = B * S
    Tg = min(GROUP_TOKENS, T)
    pad = (-T) % Tg
    xt = x.reshape(T, d)
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    G = xt.shape[0] // Tg
    xg = xt.reshape(G, Tg, d)
    logits = jnp.einsum("gtd,de->gte", xg, params["router"].astype(x.dtype))
    cap = capacity(Tg, cfg.n_experts, cfg.top_k, cfg.capacity_factor)
    dispatch, combine, aux = jax.vmap(
        lambda lg: route_topk(lg, cfg.top_k, cap))(logits)  # (G,Tg,E,C)

    expert_in = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    up = jnp.einsum("gecd,edf->gecf", expert_in,
                    params["w_up"].astype(x.dtype))
    if cfg.act == "swiglu":
        gate = jnp.einsum("gecd,edf->gecf", expert_in,
                          params["w_gate"].astype(x.dtype))
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    expert_out = jnp.einsum("gecf,efd->gecd", h,
                            params["w_down"].astype(x.dtype))
    out = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), expert_out)
    out = out.reshape(-1, d)
    if pad:
        out = out[:T]
    return out.reshape(B, S, d), jnp.mean(aux).astype(jnp.float32)
