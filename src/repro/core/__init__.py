from repro.core.confidence import (entropy_confidence, softmax_confidence,
                                   softmax_outputs)
from repro.core.calibration import (accuracy_vs_confidence, calibrate_thresholds,
                                    CalibrationResult, threshold_for_epsilon)
from repro.core.policy import (BudgetPolicy, Calibrator, ConfidenceMeasure,
                               ExitDecider, ExitDecision, ExitPolicy,
                               ThresholdPolicy, available_calibrators,
                               available_measures, available_policies,
                               get_calibrator, get_measure, get_policy,
                               register_calibrator, register_measure,
                               register_policy)
from repro.core.cascade import (cascade_evaluate, cascade_infer_sequential,
                                CascadeEvalResult, sweep_epsilons)
from repro.core.exec import (DecodeState, StagedExecutor, init_decode_state)
from repro.core.training import (backtrack_training_plan, cascade_loss,
                                 trainability_mask)

__all__ = [
    "softmax_confidence", "softmax_outputs", "entropy_confidence",
    "calibrate_thresholds", "accuracy_vs_confidence", "CalibrationResult",
    "threshold_for_epsilon",
    "ConfidenceMeasure", "ExitPolicy", "ThresholdPolicy", "BudgetPolicy",
    "Calibrator", "ExitDecider", "ExitDecision",
    "get_measure", "get_policy", "get_calibrator",
    "register_measure", "register_policy", "register_calibrator",
    "available_measures", "available_policies", "available_calibrators",
    "cascade_evaluate", "cascade_infer_sequential", "CascadeEvalResult",
    "sweep_epsilons",
    "DecodeState", "StagedExecutor", "init_decode_state",
    "backtrack_training_plan", "cascade_loss", "trainability_mask",
]
