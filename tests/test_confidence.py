"""Defs 3.1-3.3 semantics + calibration (§5) properties, incl. hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.calibration import (accuracy_vs_confidence,
                                    calibrate_thresholds,
                                    threshold_for_epsilon)
from repro.core.confidence import (entropy_confidence, softmax_confidence,
                                   softmax_outputs)


def test_softmax_confidence_matches_naive():
    rng = np.random.default_rng(0)
    z = jnp.asarray(rng.standard_normal((32, 100)) * 5, jnp.float32)
    out, delta = softmax_outputs(z)
    probs = jax.nn.softmax(z, axis=-1)
    np.testing.assert_allclose(delta, jnp.max(probs, -1), rtol=1e-5)
    assert bool(jnp.all(out == jnp.argmax(z, -1)))


def test_confidence_bounds():
    rng = np.random.default_rng(1)
    z = jnp.asarray(rng.standard_normal((64, 10)) * 10, jnp.float32)
    _, d = softmax_outputs(z)
    assert bool(jnp.all(d >= 1.0 / 10 - 1e-6))
    assert bool(jnp.all(d <= 1.0))


def test_entropy_confidence_orders_like_uncertainty():
    # peaked logits must be more confident than flat ones
    peaked = jnp.asarray([[10.0, 0, 0, 0]])
    flat = jnp.asarray([[0.1, 0.0, 0.05, 0.02]])
    assert float(entropy_confidence(peaked)[0]) > float(
        entropy_confidence(flat)[0])


# ---------------------------------------------------------------------------
# calibration §5
# ---------------------------------------------------------------------------

def test_accuracy_vs_confidence_exact_small():
    conf = np.array([0.9, 0.8, 0.7, 0.6])
    correct = np.array([1.0, 1.0, 0.0, 1.0])
    grid, alpha = accuracy_vs_confidence(conf, correct)
    # at delta=0.6: acc 3/4; 0.7: 2/3; 0.8: 1.0; 0.9: 1.0
    np.testing.assert_allclose(grid, [0.6, 0.7, 0.8, 0.9])
    np.testing.assert_allclose(alpha, [0.75, 2 / 3, 1.0, 1.0])


def test_threshold_for_epsilon_definition():
    conf = np.array([0.9, 0.8, 0.7, 0.6])
    correct = np.array([1.0, 1.0, 0.0, 1.0])
    t, a_star = threshold_for_epsilon(conf, correct, 0.0)
    assert a_star == 1.0 and t == 0.8          # min delta with alpha >= 1.0
    t2, _ = threshold_for_epsilon(conf, correct, 0.30)
    assert t2 == 0.6                           # 0.75 >= 1.0 - 0.30


def test_last_component_threshold_zero():
    conf = [np.random.default_rng(2).random(100) for _ in range(3)]
    corr = [(np.random.default_rng(3).random(100) > 0.3).astype(float)
            for _ in range(3)]
    cal = calibrate_thresholds(conf, corr, 0.05)
    assert cal.thresholds[-1] == 0.0
    assert len(cal.thresholds) == 3


@settings(max_examples=50, deadline=None)
@given(st.integers(10, 200), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 0.3))
def test_threshold_monotone_in_epsilon(n, seed, eps):
    """Property: delta_m(eps) is non-increasing in eps, and alpha at the
    chosen threshold is >= alpha_star - eps (the paper's definition)."""
    rng = np.random.default_rng(seed)
    conf = rng.random(n)
    corr = (rng.random(n) < conf).astype(float)  # calibrated-ish classifier
    t0, a_star = threshold_for_epsilon(conf, corr, eps)
    t1, _ = threshold_for_epsilon(conf, corr, eps + 0.1)
    assert t1 <= t0 + 1e-12
    grid, alpha = accuracy_vs_confidence(conf, corr)
    a_at = alpha[np.searchsorted(grid, t0)]
    assert a_at >= a_star - eps - 1e-12


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(20, 100), st.integers(0, 2 ** 31 - 1))
def test_calibration_alpha_star_is_max(n_m, n, seed):
    rng = np.random.default_rng(seed)
    confs = [rng.random(n) for _ in range(n_m)]
    corrs = [(rng.random(n) > 0.4).astype(float) for _ in range(n_m)]
    cal = calibrate_thresholds(confs, corrs, 0.02)
    for m in range(n_m):
        grid, alpha = accuracy_vs_confidence(confs[m], corrs[m])
        assert abs(cal.alpha_star[m] - alpha.max()) < 1e-12


def test_calibration_relative_to_final_dominates_self():
    """Beyond-paper rule: targeting the final component's accuracy yields
    thresholds <= the paper's per-component rule (more early exits) whenever
    the early component's own alpha* exceeds the cascade's."""
    rng = np.random.default_rng(9)
    n = 400
    # component 0: same accuracy as final on most mass, but a tiny
    # ultra-confident perfect subset inflates its own alpha*
    conf0 = np.concatenate([np.full(10, 0.99), rng.uniform(0.4, 0.8, n - 10)])
    corr0 = np.concatenate([np.ones(10), (rng.random(n - 10) < 0.7)])
    conf_last = np.ones(n)
    corr_last = (rng.random(n) < 0.7).astype(float)
    cal_self = calibrate_thresholds([conf0, conf_last],
                                    [corr0, corr_last], 0.01,
                                    relative_to="self")
    cal_final = calibrate_thresholds([conf0, conf_last],
                                     [corr0, corr_last], 0.01,
                                     relative_to="final")
    assert cal_final.thresholds[0] <= cal_self.thresholds[0]
    assert cal_final.thresholds[0] < 0.9    # exits actually unlocked
