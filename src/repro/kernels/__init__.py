from repro.kernels import autotune, ref
from repro.kernels.backend import reset_backend_warnings, resolve_interpret
from repro.kernels.ops import (cohort_scatter, cohort_scatter_tree,
                               decode_attention_cache, exit_head_fused,
                               exit_update_fused, flash_attention_bshd,
                               paged_gather, rmsnorm_fused,
                               softmax_confidence_fused)

__all__ = ["autotune", "ref", "resolve_interpret", "reset_backend_warnings",
           "softmax_confidence_fused", "rmsnorm_fused",
           "flash_attention_bshd", "decode_attention_cache", "paged_gather",
           "exit_update_fused", "exit_head_fused", "cohort_scatter",
           "cohort_scatter_tree"]
