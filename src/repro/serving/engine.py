"""Cascade-aware serving engine: prefill + decode with confidence-thresholded
early exit (Algorithm 1 applied per generated token), KV/state backfill, and
depth-compacted lane batching.

Each lane carries one :class:`repro.core.exec.DecodeState` — position cursor,
active mask, stateful-measure streaks, confidence EMA, and per-segment
execution counters — through the :class:`~repro.core.exec.StagedExecutor`.
Under ``cascade.exit_mode == "cond_batch"`` exited segments genuinely skip
their compute (lax.cond), and the engine reports BOTH the paper's analytic
MAC speedup (§6.2) and the measured wall-clock per-token cost, plus the real
(executed) skip rate next to the scheduling *opportunity* rate.

Exit decisions route through the shared :class:`repro.core.policy.ExitDecider`
resolved from the config's ``cascade.confidence`` / ``cascade.policy``
registry strings — swapping the measure (entropy, margin, patience@k, a
custom registered one) requires no engine change.

Two execution runtimes (``runtime=`` at construction):

* ``"host"`` — one jitted decode step per token, synced to host every tick
  (simple, admission-responsive; dispatch overhead per token).
* ``"device"`` — a :class:`repro.serving.runtime.DeviceDecodeLoop` decodes
  up to ``chunk`` tokens per dispatch inside a ``lax.while_loop``; tokens /
  exit indices land in device buffers and sync once per chunk.  Per-token
  dispatch cost is amortized ~chunk-fold (the win at small lane batches).
  Pass ``mesh`` to shard the whole loop carry over devices (shard_rules
  layout).  Token streams are bit-identical to the host runtime for
  requests admitted at the same points — i.e. whenever nothing queues
  (offered load <= slot capacity).  QUEUED requests admit at chunk
  boundaries here (up to ``chunk`` tokens later than the host runtime),
  so a lane's re-prefill can land at a different generated length and
  its sequences legitimately diverge: an admission-latency trade, not an
  execution-semantics difference.

Both runtimes time the jit warm-up call separately and report it as
``compile_seconds`` in :meth:`stats` — ``wallclock_us_per_token`` never
includes compilation.
"""
from __future__ import annotations

import copy
import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.exec import CONF_EMA_DECAY, StagedExecutor, effective_cohorts
from repro.core.macs import segment_macs_per_token
from repro.models.model import CascadeModel, extra_input_shapes
from repro.serving.batching import DepthCompactor, cohort_capacity
from repro.serving.paged import PagedCascadeCache
from repro.serving.runtime import DeviceDecodeLoop, kernel_provenance
from repro.utils import get_logger

log = get_logger("serving")

# flight-recorder process naming (traceviz tracks / fleet scrape labels):
# engines number themselves in construction order
_ENGINE_SEQ = itertools.count()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    extra: Optional[dict] = None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    generated: Optional[List[int]] = None
    exit_depths: Optional[List[int]] = None
    confs: Optional[List[float]] = None
    pos: int = 0
    done: bool = True


def _escalation_extra(req: Request) -> Optional[dict]:
    """The tier's re-submission tag, set by ``repro.escalate`` when a
    deferred request is replayed into this engine (None for fresh
    traffic).  Carries ``replayed`` — how many of the prompt's trailing
    tokens are a prefix another stage already decoded — so the accounting
    can attribute that prefill to the escalated request instead of
    counting it as fresh traffic."""
    extra = req.extra or {}
    esc = extra.get("escalation")
    return esc if isinstance(esc, dict) else None


class CascadeServingEngine:
    """Multi-lane batched decode with cascade early exit.

    Each lane holds ``lane_batch`` sequences sharing one KV cache; lanes step
    independently so the DepthCompactor can group easy (shallow-exit) traffic
    away from hard traffic, letting ``cond_batch`` skips fire.
    """

    def __init__(self, cfg: ModelConfig, model: CascadeModel, params,
                 lane_batch: int = 4, n_lanes: int = 2,
                 cache_len: int = 256, runtime: str = "host",
                 chunk: int = 8, mesh=None, autotune=None):
        if runtime not in ("host", "device"):
            raise ValueError(
                f"runtime must be 'host' or 'device', got {runtime!r}")
        if mesh is not None and runtime != "device":
            raise ValueError(
                "mesh sharding is only applied by the device decode loop; "
                "the host per-token step runs unsharded — pass "
                "runtime='device' (or drop mesh=) rather than silently "
                "serving single-device")
        if autotune is not None and autotune is not False \
                and not cfg.autotune.enabled:
            raise ValueError(
                "autotune= needs telemetry in the decode graphs: build the "
                "model/engine with cfg.with_autotune(enabled=True) (plus "
                "epsilon= or mac_budget=) before passing a controller")
        self.cfg = cfg
        self.model = model
        self.params = params
        # one-time layout normalization at admission capacity: lanes are
        # sized to a cohort multiple so cohort-split skipping never
        # silently degrades (the extra slots are plain admission capacity)
        rounded = cohort_capacity(lane_batch, cfg.cascade.n_cohorts)
        if rounded != lane_batch:
            log.info("lane_batch %d rounded up to %d (cohort multiple of "
                     "n_cohorts=%d)", lane_batch, rounded,
                     cfg.cascade.n_cohorts)
        self.lane_batch = rounded
        lane_batch = rounded
        self.n_lanes = n_lanes
        self.cache_len = cache_len
        self.runtime = runtime
        self.chunk = chunk
        self.cohorts = effective_cohorts(cfg.cascade.n_cohorts, lane_batch,
                                         warn=True)
        self.compactor = DepthCompactor(n_lanes, cfg.cascade.n_components)
        # flight recorder (repro.obs): host-side span assembly at the
        # existing sync points — never touches a traced graph, so enabling
        # it can neither retrace nor change streams (tests/test_obs.py)
        self.flight = None
        self._provenance = None
        if cfg.obs.enabled:
            from repro.obs.recorder import FlightRecorder
            self.flight = FlightRecorder.from_config(
                cfg.obs, name=f"engine{next(_ENGINE_SEQ)}")
            self._provenance = kernel_provenance(cfg)
        # tuned kernel tiles install BEFORE anything traces (tiles are
        # static kernel params — installing later would retrace every lane)
        if cfg.kernel_tune.enabled:
            from repro.kernels.autotune import ensure_tuned
            ensure_tuned(cfg)
        self.executor = StagedExecutor(model, cfg)
        self.decider = self.executor.decider
        self.mac_prefix = segment_macs_per_token(cfg, cache_len)
        # paged KV layout: shared block stores + per-slot block tables.
        # Admission claims pool blocks for exactly the positions a request
        # will span; slot finish returns them at the next host sync (the
        # dense layout's always-resident worst-case slab is the ablation).
        self.paged = cfg.paged_cache.layout == "paged"
        self.pcache = (PagedCascadeCache(model, cfg, lane_batch, n_lanes,
                                         cache_len)
                       if self.paged else None)
        # dense-equivalent cache footprint (for the stats()/bench memory
        # comparison, in both layouts)
        tmpl = jax.eval_shape(
            lambda: model.init_cache(lane_batch, cache_len))
        self._dense_cache_bytes = n_lanes * int(sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree_util.tree_leaves(tmpl["segments"])))
        self.lanes = []
        for i in range(n_lanes):
            lane = {
                "slots": [_Slot() for _ in range(lane_batch)],
                "state": self.executor.init_state(
                    lane_batch, mac_weights=self.mac_prefix,
                    block_tables=(self.pcache.device_tables(i)
                                  if self.paged else None)),
            }
            if self.paged:
                lane["cache"] = None
                lane["kpos"] = self.pcache.fresh_kpos()
            else:
                lane["cache"] = model.init_cache(lane_batch, cache_len)
            self.lanes.append(lane)
        self.queue: List[Request] = []
        self.finished: Dict[int, dict] = {}
        # admission gate (fleet drain hook): False stops _admit() pulling
        # from the queue while in-flight slots keep decoding to exit or
        # budget — the "stop admitting, run to completion" half of a drain.
        # Plain host state; flipping it never touches device buffers.
        self.admitting = True
        # admission-latency accounting (ticks between submit and admit) and
        # lanes whose block tables changed since their state last synced
        self._tick = 0
        self._submit_tick: Dict[int, int] = {}
        self._tables_stale: set = set()
        # live thresholds (autotune): engine-wide vector pushed into every
        # lane's DecodeState as plain data — None until a controller (or a
        # caller) pushes one, in which case the config's static vector is
        # what the carried state was seeded with anyway
        self._live_thresholds = (tuple(cfg.cascade.thresholds)
                                 if cfg.autotune.enabled else None)
        # a ThresholdController (or True → build one from cfg.autotune)
        self.controller = None
        if autotune is True:
            from repro.autotune.controller import ThresholdController
            self.controller = ThresholdController(cfg, self.mac_prefix)
        elif autotune:
            self.controller = autotune
        # jit warm-up accounting: the first decode dispatch per runtime path
        # pays compilation and is reported as compile_seconds, never as
        # decode wall-clock (reset_metrics does NOT clear these — compile is
        # a one-time cost, not part of any measurement window)
        self._compile_seconds = 0.0
        self._decode_warm = False
        self.reset_metrics()
        # cache + DecodeState are donated: the engine never reuses the old
        # buffers, and in-place updates keep decode wall-clock honest
        self._prefill = jax.jit(self._prefill_impl, donate_argnums=(2, 3))
        self._decode = jax.jit(self._decode_impl, donate_argnums=(2, 3))
        # continuous (single-slot) admission prefill: only the shared block
        # stores are donated — the lane's kpos buffer stays live on the
        # host side, which is why this takes segments rather than a cache
        self._slot_prefill = jax.jit(self._slot_prefill_impl,
                                     donate_argnums=(2,))
        self.loop = (DeviceDecodeLoop(model, cfg, chunk=chunk,
                                      cache_len=cache_len, mesh=mesh)
                     if runtime == "device" else None)
        if self.controller is not None:
            self.controller.attach(self)

    def reset_metrics(self):
        """Zero the MAC / wall-clock / skip-rate accounting.  The
        compactor's learned depth EMAs survive (scheduler state); only its
        skip counters reset, so the MAC / wall-clock / skip rates in
        :meth:`stats` all cover the same step window.  ``compile_seconds``
        and the warm flags also survive: jit compilation is timed apart
        from decode automatically, so resetting after warm-up is no longer
        required for a clean ``wallclock_us_per_token``.  Per-request
        outputs (``finished``, and the ``requests_finished`` / exit-depth
        stats derived from them) are NOT cleared — they describe completed
        work, not a measurement window.  The warm-up dispatch (host: first
        step; device: first chunk) is excluded from EVERY window metric —
        MAC, skip, opportunity, wallclock — so they always describe the
        same steps.  Escalation accounting (replayed-prefix prefill
        tokens/MACs/seconds) is window accounting and resets with the
        rest; the paged pool's PEAK occupancy and lifetime reclaim
        counters survive (they describe high-water capacity, the same
        split that keeps ``compile_seconds`` out of the decode window) —
        only its per-chunk reclaim window clears."""
        self.compactor.reset_skip_counters()
        self._macs_spent = 0.0
        self._macs_dense = 0.0
        self._decode_seconds = 0.0
        self._decode_tokens = 0
        self._segments_run = np.zeros(self.cfg.cascade.n_components, np.int64)
        self._decode_steps = 0
        self._skip_opportunities = 0
        self._skip_opportunity_total = 0
        self._admit_waits: List[int] = []
        # escalation window: replayed-prefix prefill attributed to the
        # escalated requests that caused it, never to fresh traffic
        self._prefill_positions_fresh = 0
        self._prefill_positions_replayed = 0
        self._replay_prefill_macs = 0.0
        self._replay_prefill_seconds = 0.0
        self._escalated_admitted = 0
        self._cancelled_for_escalation = 0
        if getattr(self, "paged", False) and self.pcache is not None:
            self.pcache.pool.reset_window()

    # -- jitted cores ---------------------------------------------------
    def _prefill_impl(self, params, tokens, cache, state, extra):
        d, cache, state = self.executor.prefill(params, tokens, cache, extra,
                                                state=state)
        return d.prediction, d.exit_index, d.confidence, cache, state

    def _decode_impl(self, params, token, cache, state, extra):
        d, cache, state = self.executor.decode_step(params, token, cache,
                                                    state, extra)
        return d.prediction, d.exit_index, d.confidence, cache, state

    def _slot_prefill_impl(self, params, tokens, segments, positions,
                           write_slots, tables, extra):
        return self.model.prefill_into(
            params, tokens, {"segments": segments, "kpos": None},
            positions, write_slots, tables, extra)

    # -- cache layout plumbing -------------------------------------------
    def _lane_cache(self, lane):
        """The cache pytree a dispatch consumes: the lane's private slab
        (dense) or its kpos ring composed over the shared block stores
        (paged).  Lanes dispatch serially, so composing at dispatch time
        always picks up the stores adopted back from the previous lane."""
        if self.paged:
            return self.pcache.lane_cache(lane["kpos"])
        return lane["cache"]

    def _take_cache(self, lane, cache):
        """Adopt a dispatch's (donated-in, returned-out) cache."""
        if self.paged:
            lane["kpos"] = self.pcache.adopt(cache)
        else:
            lane["cache"] = cache

    def _sync_tables(self, lane, lane_id: int):
        """Push rebuilt block tables into the lane's DecodeState after
        release/alloc changed its rows — a data swap (same (K, B, nblk)
        int32 shape), never a retrace."""
        if self.paged and lane_id in self._tables_stale:
            lane["state"] = lane["state"].replace(
                block_tables=self.pcache.device_tables(lane_id))
            self._tables_stale.discard(lane_id)

    # -- public API -----------------------------------------------------
    def submit(self, req: Request):
        self._submit_tick.setdefault(req.rid, self._tick)
        self.queue.append(req)
        if self.flight is not None:
            self.flight.on_submit(req.rid, self._tick)

    # -- fleet surface ----------------------------------------------------
    def free_slot_count(self) -> int:
        """Slots a placement could admit into right now (all lanes)."""
        return sum(1 for ln in self.lanes for s in ln["slots"] if s.done)

    def queued_count(self) -> int:
        return len(self.queue)

    def live_rids(self) -> List[int]:
        """Rids currently decoding in a slot (admitted, not finished)."""
        return [s.request.rid for ln in self.lanes for s in ln["slots"]
                if not s.done and s.request is not None]

    def take_queue(self) -> List[Request]:
        """Drain hook: remove and return every still-queued request (FIFO
        order), clearing their submit-tick bookkeeping so a scheduler can
        requeue them to a sibling engine without this engine ever counting
        them as admitted or dropped."""
        taken, self.queue = self.queue, []
        for req in taken:
            self._submit_tick.pop(req.rid, None)
            if self.flight is not None:
                # the rid leaves this engine without ever being admitted;
                # finalize its flight so the recorder holds no dangling
                # live entry (the sibling that picks it up records anew)
                self.flight.on_finish(req.rid, "cancelled",
                                      {"queued": True, "reason": "requeue",
                                       "n_tokens": 0})
        return taken

    def _predict_depth(self, req: Request) -> float:
        """Expected exit depth for an incoming request: an explicit hint in
        ``req.extra["predicted_depth"]`` (e.g. from an earlier turn's prefill
        exit) wins; otherwise the compactor's population prior over observed
        prefill exits."""
        hint = (req.extra or {}).get("predicted_depth")
        return self.compactor.predict_depth(hint)

    def _record_admit(self, req: Request, lane_id: Optional[int] = None,
                      slot_idx: Optional[int] = None,
                      depth: Optional[float] = None):
        sub = self._submit_tick.pop(req.rid, self._tick)
        wait = self._tick - sub
        self._admit_waits.append(wait)
        esc = _escalation_extra(req)
        if esc is not None:
            self._escalated_admitted += 1
        if self.flight is not None:
            per = max(1, self.lane_batch // self.cohorts)
            attrs = dict(self._provenance or {})
            if esc is not None:
                attrs["escalated_from"] = esc.get("rid")
                attrs["replayed"] = esc.get("replayed")
                attrs["migrated"] = bool(esc.get("migrated"))
            self.flight.on_admit(
                req.rid, lane=lane_id, slot=slot_idx,
                cohort=(slot_idx // per if slot_idx is not None else None),
                predicted_depth=(float(depth) if depth is not None
                                 else None),
                wait_ticks=wait, tick=self._tick, attrs=attrs)

    def _replayed_len(self, req: Request) -> int:
        """Trailing prompt tokens another stage already decoded (0 for
        fresh traffic) — the prefill positions escalation accounting
        attributes to the escalated request."""
        esc = _escalation_extra(req)
        if esc is None:
            return 0
        return max(0, min(int(esc.get("replayed", 0)), len(req.prompt)))

    def _account_prefill(self, req: Request, seconds: float,
                         padded_positions: int):
        """Attribute one newly admitted request's prefill: its prompt
        positions split into fresh traffic vs a replayed prefix an earlier
        escalation stage already decoded.  Replayed positions are priced
        at the full-depth per-token MAC cost (prefill computes every
        component) and charged to the escalation window — NOT to the
        fresh prefill counter and never to the decode window, so
        ``wallclock_us_per_token`` keeps its decode-only meaning and the
        tier can account replay cost against the escalated request.
        ``seconds`` of a shared dispatch are attributed by the request's
        replayed share of the padded positions it rode in."""
        replayed = self._replayed_len(req)
        self._prefill_positions_fresh += len(req.prompt) - replayed
        self._prefill_positions_replayed += replayed
        if replayed:
            self._replay_prefill_macs += replayed * float(self.mac_prefix[-1])
            self._replay_prefill_seconds += seconds * (
                replayed / max(1, padded_positions))

    def _admit(self):
        if self.paged:
            return self._admit_paged()
        while self.queue:
            free = [i for i, lane in enumerate(self.lanes)
                    if any(s.done for s in lane["slots"])]
            if not free:
                break
            req = self.queue.pop(0)
            depth = self._predict_depth(req)
            lane_id = self.compactor.assign(depth, free)
            lane = self.lanes[lane_id]
            # within the lane, place the request in the cohort whose depth
            # band matches — cohort-split skip predicates (n_cohorts > 1)
            # only fire when a cohort's co-residents exit together
            free_slots = [i for i, s in enumerate(lane["slots"]) if s.done]
            slot_idx = self.compactor.pick_slot(
                depth, free_slots, self.lane_batch, self.cohorts)
            slot = lane["slots"][slot_idx]
            slot.request = req
            slot.generated = []
            slot.exit_depths = []
            slot.confs = []
            slot.done = False
            # cache is shared per-lane, so we prefill the whole lane
            # when admission changes (simple + correct).
            lane["dirty"] = True
            self._record_admit(req, lane_id, slot_idx, depth)

    # -- paged admission --------------------------------------------------
    def _free_per_cohort(self, lane) -> List[int]:
        per = self.lane_batch // self.cohorts
        return [sum(1 for i in range(c * per, (c + 1) * per)
                    if lane["slots"][i].done)
                for c in range(self.cohorts)]

    def _pad_prompt(self, n: int) -> int:
        """Continuous-admission prompts pad to a power of two (>= 2) so the
        B=1 slot-prefill jit compiles a bounded set of shapes."""
        return max(2, 1 << max(0, int(n - 1).bit_length()))

    def _continuous_feasible(self, lane_id: int, req: Request) -> bool:
        """Can ``req`` join this LIVE lane between chunks?  Needs a free
        slot, enough decoded history for the padded prompt's offset
        positions (P_pad <= t), and pool coverage for exactly the
        positions the slot will span."""
        lane = self.lanes[lane_id]
        if not any(s.done for s in lane["slots"]):
            return False
        t0 = int(np.asarray(lane["state"].t))
        P_pad = self._pad_prompt(len(req.prompt))
        if P_pad > t0:
            return False
        need = self.pcache.blocks_needed(t0 - P_pad,
                                         t0 + req.max_new_tokens)
        return self.pcache.can_admit(need)

    def _lane_plan_fits(self, lane_id: int, req: Request) -> bool:
        """Whole-lane path feasibility: would the lane's re-prefill plan
        (every live slot + ``req``, padded to the common context length)
        fit the pool once the lane's current reservations are released?
        Allocation itself happens at prefill time, when the true common
        length is known."""
        lane = self.lanes[lane_id]
        ctxs = [(len(s.request.prompt) + len(s.generated),
                 max(1, s.request.max_new_tokens - len(s.generated)))
                for s in lane["slots"] if not s.done]
        ctxs.append((len(req.prompt), req.max_new_tokens))
        S = max(2, max(c for c, _ in ctxs))
        need = sum(self.pcache.blocks_needed(0, S + rem)
                   for _, rem in ctxs)
        have = self.pcache.pool.free_blocks + sum(
            self.pcache.slot_blocks(lane_id, i)
            for i in range(self.lane_batch))
        return need <= have

    def _admit_paged(self):
        """Admission under the paged layout.  A request needs a free slot
        AND block coverage for the positions it will actually span — not a
        worst-case-length lane slot.  Two paths:

        * live lane → CONTINUOUS single-slot admission: blocks for
          ``[t - P_pad, t + budget)`` are claimed now and the prompt
          prefills into them between decode dispatches, leaving sibling
          streams untouched (no whole-lane re-prefill).
        * empty/dirty lane → the dense whole-lane path (bit-identity with
          the dense ablation for lanes admitted this way), feasibility-
          checked against the pool.

        Head-of-queue blocking: if the head fits nowhere the queue waits
        (FIFO — keeps exit accounting comparable with the dense ablation).
        Pool exhaustion therefore backpressures admission; it can never
        corrupt resident slots, because alloc_slot is all-or-nothing."""
        while self.queue:
            req = self.queue[0]
            if not self.pcache.fits_ever(
                    0, max(2, len(req.prompt)) + req.max_new_tokens):
                raise ValueError(
                    f"request rid={req.rid} can never fit: prompt + "
                    f"max_new_tokens spans more blocks than the pool owns; "
                    f"raise paged_cache.num_blocks or shrink the request")
            depth = self._predict_depth(req)
            whole = [i for i, ln in enumerate(self.lanes)
                     if (ln.get("dirty") or all(s.done for s in ln["slots"]))
                     and any(s.done for s in ln["slots"])]
            live = [i for i, ln in enumerate(self.lanes)
                    if i not in whole and any(s.done for s in ln["slots"])]
            cands = [i for i in live if self._continuous_feasible(i, req)]
            if cands:
                lane_id = self.compactor.assign(depth, cands)
                # _admit_continuous records the admit itself (it knows the
                # slot, and it may retire the request in the same call —
                # the flight's admit span must land before its terminal)
                self.queue.pop(0)
                self._admit_continuous(lane_id, req, depth)
            else:
                cands = [i for i in whole if self._lane_plan_fits(i, req)]
                if not cands:
                    break
                lane_id = self.compactor.assign(depth, cands)
                lane = self.lanes[lane_id]
                free_slots = [i for i, s in enumerate(lane["slots"])
                              if s.done]
                slot_idx = self.compactor.pick_slot(
                    depth, free_slots, self.lane_batch, self.cohorts,
                    free_per_cohort=self._free_per_cohort(lane))
                slot = lane["slots"][slot_idx]
                slot.request = req
                slot.generated = []
                slot.exit_depths = []
                slot.confs = []
                slot.done = False
                lane["dirty"] = True
                self.queue.pop(0)
                self._record_admit(req, lane_id, slot_idx, depth)

    def _admit_continuous(self, lane_id: int, req: Request, depth: float):
        """Prefill ``req`` into a single freed slot of a live lane.

        The prompt left-pads to ``P_pad`` and runs a B=1 full-mode forward
        at absolute positions ``[t - P_pad, t)`` writing ONLY through the
        slot's freshly allocated blocks; its kpos row masks everything it
        didn't write.  The sanctioned divergence from the dense ablation
        (which must re-prefill the whole lane and restart sibling
        alignment to a new common length): the admitted stream's history
        starts at an offset, so its token stream is its own — sibling
        streams are untouched, which is the point.  Telemetry shadow rows
        for this prefill are skipped (one B=1 decision; the decode-time
        telemetry picks the slot up on its first step)."""
        lane = self.lanes[lane_id]
        state = lane["state"]
        t0 = int(np.asarray(state.t))
        P = len(req.prompt)
        P_pad = self._pad_prompt(P)
        free_slots = [i for i, s in enumerate(lane["slots"]) if s.done]
        slot_idx = self.compactor.pick_slot(
            depth, free_slots, self.lane_batch, self.cohorts,
            free_per_cohort=self._free_per_cohort(lane))
        self._record_admit(req, lane_id, slot_idx, depth)
        ok = self.pcache.alloc_slot(lane_id, slot_idx, t0 - P_pad,
                                    t0 + req.max_new_tokens)
        assert ok, "continuous admission raced the feasibility check"
        start = t0 - P_pad
        toks = np.zeros((1, P_pad), np.int32)
        toks[0, P_pad - P:] = req.prompt
        W = self.pcache.W
        # ring slot -> (kept token index, kept absolute position): newest
        # position wins on ring wrap, everything unwritten stays masked
        write_slots = np.full((W,), -1, np.int32)
        krow = np.full((W,), -1, np.int32)
        for p in range(max(start, t0 - W), t0):
            write_slots[p % W] = p - start
            krow[p % W] = p
        tables = self.pcache.device_tables(lane_id)[
            :, slot_idx:slot_idx + 1, :]
        t_pre = time.perf_counter()
        logits, new_segs = self._slot_prefill(
            self.params, jnp.asarray(toks), self.pcache.segments,
            jnp.asarray(start + np.arange(P_pad, dtype=np.int32)),
            jnp.asarray(write_slots), tables, self._extra(1))
        jax.block_until_ready(logits)
        dt_pre = time.perf_counter() - t_pre
        self.pcache.segments = new_segs
        self._account_prefill(req, dt_pre, P_pad)
        if self.flight is not None:
            self.flight.on_prefill(lane_id, t_pre, dt_pre, [req.rid],
                                   [req.rid], P_pad)
        d, _ = self.decider.decide_with_carry(
            logits, thresholds=state.thresholds,
            state=self.decider.measure.init_state(
                self.cfg.cascade.n_components, 1),
            active=jnp.ones((1,), bool))
        # merge the B=1 prefill decision into the lane's carried state:
        # the prefill decision seeds the stateful-measure streak exactly
        # like whole-lane prefill does (exec._carry_forward)
        policy = state.policy
        if policy is not None and d.state is not None:
            policy = jax.tree_util.tree_map(
                lambda full, one: full.at[..., slot_idx].set(one[..., 0]),
                policy, d.state)
        conf = float(np.asarray(d.confidence)[0])
        ema = state.ema_conf.at[slot_idx].set(
            (1.0 - CONF_EMA_DECAY) * conf)
        lane["kpos"] = lane["kpos"].at[slot_idx].set(jnp.asarray(krow))
        s = lane["slots"][slot_idx]
        s.request = req
        s.generated = []
        s.exit_depths = []
        s.confs = []
        s.done = False
        lane["state"] = state.replace(
            active=jnp.asarray(self._live_mask(lane)),
            policy=policy, ema_conf=ema,
            block_tables=self.pcache.device_tables(lane_id))
        self._tables_stale.discard(lane_id)
        tok = int(np.asarray(d.prediction)[0])
        exit_idx = int(np.asarray(d.exit_index)[0])
        if not s.generated:
            self.compactor.observe_prefill_exit(float(exit_idx))
        s.generated.append(tok)
        s.exit_depths.append(exit_idx)
        s.confs.append(conf)
        self._finish_if_done(s, t0, lane_id, slot_idx)

    def _finish_if_done(self, s: _Slot, pos: int, lane_id: int,
                        slot_idx: int):
        if (len(s.generated) >= s.request.max_new_tokens
                or pos >= self.cache_len - 1):
            self._retire(s, lane_id, slot_idx)

    def _retire(self, s: _Slot, lane_id: int, slot_idx: int,
                escalated: bool = False, reason: str = "escalate"):
        s.done = True
        self.finished[s.request.rid] = {
            "tokens": list(s.generated),
            "exit_depths": list(s.exit_depths),
            "confs": list(s.confs),
            "lane": lane_id,
            "escalated": escalated,
        }
        if self.flight is not None:
            ds = np.asarray(s.exit_depths, np.int64)
            self.flight.on_finish(
                s.request.rid, reason if escalated else "exit", {
                    "n_tokens": len(s.generated),
                    "exit_component_last": (int(ds[-1]) if ds.size
                                            else None),
                    "mean_exit_depth": (float(ds.mean()) if ds.size
                                        else None),
                    "macs": (float(np.sum(
                        np.asarray(self.mac_prefix)[ds])) if ds.size
                        else 0.0),
                    "lane": lane_id,
                    "slot": slot_idx,
                })
        # retiring traffic decays the lane's depth EMA toward the
        # population prior so the lane doesn't keep repelling traffic
        # that no longer matches its drained residents
        self.compactor.observe_retire(lane_id)
        if self.paged:
            # skip-aware reclamation at the first host sync after the
            # slot finished (mid-chunk under the device runtime):
            # components the cascade never answered from release as
            # reclaimed_by_exit, the rest at retire (DESIGN.md)
            md = max(s.exit_depths) if s.exit_depths else None
            self.pcache.release_slot(lane_id, slot_idx,
                                     max_exit_depth=md)
            self._tables_stale.add(lane_id)

    def cancel(self, rid: int, keep: Optional[int] = None,
               reason: str = "escalate") -> Optional[dict]:
        """Escalation re-admission hook: retire a live request early,
        keeping only its first ``keep`` generated tokens (None = all).

        The tier calls this between engine ticks when a token finishes at
        the final component below the escalation threshold: the committed
        prefix stands, everything from the deferred token on is discarded
        (tokens past the defer point were decoded from a context the next
        stage re-answers — their compute is already in the MAC window,
        which is honest: it was spent).  Returns the finished record (its
        ``escalated`` flag set) or None if ``rid`` is not known.  A
        still-QUEUED request (submitted, never admitted) is removed from
        the queue and gets a well-formed empty record — no tokens, no
        lane, escalated=True — so drain-time requeue can treat "cancel
        then resubmit elsewhere" uniformly whether or not the request ever
        reached a slot.  Queue cancels do not count toward
        ``cancelled_for_escalation`` (nothing was decoded, so no
        escalation accounting applies) and never touch a lane.

        Safe between ticks in both runtimes: the slot's ``done`` flag
        drops it from the next dispatch's active mask, and the paged
        release path is the ordinary retire path (host-side bookkeeping
        only)."""
        for lane_id, lane in enumerate(self.lanes):
            for slot_idx, s in enumerate(lane["slots"]):
                if s.done or s.request is None or s.request.rid != rid:
                    continue
                if keep is not None:
                    s.generated = s.generated[:keep]
                    s.exit_depths = s.exit_depths[:keep]
                    s.confs = s.confs[:keep]
                self._cancelled_for_escalation += 1
                self._retire(s, lane_id, slot_idx, escalated=True,
                             reason=reason)
                return self.finished[rid]
        for qi, req in enumerate(self.queue):
            if req.rid != rid:
                continue
            self.queue.pop(qi)
            self._submit_tick.pop(rid, None)
            self.finished[rid] = {
                "tokens": [],
                "exit_depths": [],
                "confs": [],
                "lane": None,
                "escalated": True,
            }
            if self.flight is not None:
                # never admitted: terminal "cancelled" regardless of why —
                # no lane, no tokens, nothing to escalate or migrate
                self.flight.on_finish(rid, "cancelled",
                                      {"queued": True, "reason": reason,
                                       "n_tokens": 0})
            return self.finished[rid]
        return None

    def _live_mask(self, lane) -> np.ndarray:
        return np.array([not s.done for s in lane["slots"]])

    def _lane_prefill(self, lane, lane_id: int):
        """(Re)prefill a lane: pad contexts to a common length.

        In-flight slots re-prefill with their full context (prompt + tokens
        generated so far) so admission into a sibling slot never truncates a
        live sequence; the token predicted off that context is their normal
        next-step continuation."""
        slots = lane["slots"]
        prompts = [np.concatenate([s.request.prompt,
                                   np.asarray(s.generated, np.int32)])
                   if not s.done else np.zeros((1,), np.int32)
                   for s in slots]
        S = max(len(p) for p in prompts)
        S = max(S, 2)
        toks = np.zeros((self.lane_batch, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, -len(p):] = p          # left-pad (simplest alignment)
        if self.paged:
            # whole-lane re-prefill restarts every resident at the common
            # length: release ALL the lane's reservations (no slot keeps
            # coverage planned for the previous alignment), then claim
            # coverage for each live slot's full span at the new one.
            # Admission feasibility (_lane_plan_fits) guaranteed this fits.
            for i in range(self.lane_batch):
                self.pcache.release_slot(lane_id, i)
            for i, s in enumerate(slots):
                if s.done:
                    continue
                rem = max(1, s.request.max_new_tokens - len(s.generated))
                ok = self.pcache.alloc_slot(lane_id, i, 0, S + rem)
                assert ok, "lane prefill outgrew its admission plan"
            lane["kpos"] = self.pcache.fresh_kpos()
            cache_in = self.pcache.lane_cache(lane["kpos"])
            self._tables_stale.discard(lane_id)
        else:
            cache_in = self.model.init_cache(self.lane_batch, self.cache_len)
        extra = self._extra(self.lane_batch)
        # re-prefill restarts the lane's DecodeState (streaks, EMA, cursors);
        # the prefill decision itself counts as the streak's first step.
        # Autotune telemetry and live thresholds are LANE-lifetime, not
        # prefill-lifetime: carry them across the re-init (telemetry is
        # passed INTO init_state so no zeroed counters are allocated just
        # to be discarded).
        old = lane.get("state")
        state = self.executor.init_state(
            self.lane_batch, active=self._live_mask(lane),
            mac_weights=self.mac_prefix,
            telemetry=(old.tel if old is not None
                       else StagedExecutor._AUTO_TELEMETRY),
            block_tables=(self.pcache.device_tables(lane_id)
                          if self.paged else None))
        if old is not None and old.thresholds is not None:
            state = state.replace(thresholds=old.thresholds)
        fresh_admits = [s for s in slots if not s.done and not s.generated]
        t_pre = time.perf_counter()
        tok, exit_idx, conf, cache, state = self._prefill(
            self.params, jnp.asarray(toks), cache_in, state, extra)
        self._take_cache(lane, cache)
        lane["state"] = state
        tok = np.asarray(tok)
        dt_pre = time.perf_counter() - t_pre
        exit_idx = np.asarray(exit_idx)
        conf = np.asarray(conf)
        # attribute this shared dispatch's replayed-prefix share to the
        # newly admitted escalated requests riding in it (if any)
        for s in fresh_admits:
            self._account_prefill(s.request, dt_pre, self.lane_batch * S)
        if self.flight is not None:
            # before the slot loop below, which may retire flights
            self.flight.on_prefill(
                lane_id, t_pre, dt_pre,
                [s.request.rid for s in slots if not s.done],
                [s.request.rid for s in fresh_admits], S)
        for i, s in enumerate(slots):
            if not s.done:
                if not s.generated:
                    # warm the admission depth prior with the FIRST prefill
                    # exit only (re-prefills of in-flight slots don't
                    # re-count toward the prior)
                    self.compactor.observe_prefill_exit(float(exit_idx[i]))
                s.generated.append(int(tok[i]))
                s.exit_depths.append(int(exit_idx[i]))
                s.confs.append(float(conf[i]))
                # the prefill token counts toward max_new_tokens like any
                # decode tick — an in-flight slot near its limit may finish
                self._finish_if_done(s, S, lane_id, i)
        self._sync_tables(lane, lane_id)
        lane["dirty"] = False

    def _extra(self, batch):
        shapes = extra_input_shapes(self.cfg, batch)
        if not shapes:
            return None
        return {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}

    def step(self):
        """One engine tick: admit, prefill dirty lanes, then decode — one
        token per lane (``runtime="host"``) or up to ``chunk`` tokens per
        lane inside the device loop (``runtime="device"``).  With a
        ThresholdController attached, the tick ends with its (rarely
        firing) telemetry → solver → threshold-push check."""
        self._tick += 1
        if self.admitting:
            self._admit()
        for lane_id, lane in enumerate(self.lanes):
            if all(s.done for s in lane["slots"]):
                continue
            if lane.get("dirty"):
                self._lane_prefill(lane, lane_id)
                continue
            if self.runtime == "device":
                self._device_tick(lane, lane_id)
            else:
                self._host_tick(lane, lane_id)
        if self.controller is not None:
            self.controller.maybe_update(self)

    # -- autotune surface -------------------------------------------------
    def lane_telemetry(self) -> List:
        """The lanes' device-resident telemetry pytrees (lane order)."""
        return [lane["state"].tel for lane in self.lanes
                if lane["state"].tel is not None]

    def current_thresholds(self):
        """The live threshold vector lanes decode with, or None (static
        config thresholds)."""
        return self._live_thresholds

    def push_thresholds(self, thresholds) -> None:
        """Swap the live threshold vector in every lane's DecodeState.

        Thresholds are carry DATA — the replacement array has the shape
        and dtype of the one it replaces, so neither the host decode step
        nor the device while_loop retraces (pinned by
        ``tests/test_autotune.py``)."""
        pushed = tuple(float(t) for t in thresholds)
        ths = np.asarray(pushed, np.float32)
        n_m = self.cfg.cascade.n_components
        if ths.shape != (n_m,):
            raise ValueError(f"threshold vector shape {ths.shape} != "
                             f"({n_m},)")
        if not self.cfg.autotune.enabled:
            raise ValueError(
                "live threshold pushes need autotune-enabled decode graphs "
                "(cfg.with_autotune(enabled=True)); without them thresholds "
                "are static trace constants")
        for lane in self.lanes:
            # one device array PER lane: lane states are donated to the
            # jitted steps, so a buffer shared across lanes would be
            # invalidated for lane k+1 the moment lane k dispatches
            lane["state"] = lane["state"].replace(
                thresholds=jnp.array(ths))
        # report what the caller pushed, not its f32 quantization — the
        # controller/artifact values (e.g. the 1.1 never-exit sentinel)
        # must round-trip through current_thresholds() exactly
        self._live_thresholds = pushed
        if self.flight is not None:
            self.flight.on_event("threshold_push",
                                 {"thresholds": list(pushed),
                                  "tick": self._tick})

    # -- observability surface (repro.obs) --------------------------------
    @property
    def obs_events(self):
        """The engine-level event log (None with the recorder off) —
        the hook ThresholdController uses to record solver resolves."""
        return self.flight.events if self.flight is not None else None

    def dump_flight(self, rid: int) -> Optional[dict]:
        """One request's span tree (live or from the done ring), or None
        if unknown / ring-evicted / recorder off."""
        return self.flight.dump(rid) if self.flight is not None else None

    def flights(self, include_live: bool = False) -> List[dict]:
        return (self.flight.flights(include_live)
                if self.flight is not None else [])

    def latency_stats(self) -> dict:
        """p50/p95/p99 latency summaries.  ``admission_wait_ticks`` comes
        from the window counter (available with the recorder off, resets
        with :meth:`reset_metrics`); the rest come from the recorder's
        lifetime reservoirs (None with it off)."""
        from repro.obs.recorder import quantiles
        out = {"admission_wait_ticks": quantiles(self._admit_waits)}
        if self.flight is not None:
            lat = self.flight.latency()
            lat.pop("admission_wait_ticks", None)
            out.update(lat)
        else:
            out.update({"e2e_seconds": None, "per_token_seconds": None,
                        "macs_per_request": None,
                        "tokens_per_request": None})
        return out

    def scrape(self) -> str:
        """Prometheus text exposition of this engine's metrics."""
        from repro.obs.metrics import MetricsRegistry, engine_metrics_into
        return engine_metrics_into(MetricsRegistry(), self).render_text()

    def scrape_json(self) -> dict:
        from repro.obs.metrics import MetricsRegistry, engine_metrics_into
        return engine_metrics_into(MetricsRegistry(), self).render_json()

    def _account(self, lane_id: int, depths: np.ndarray, n_tokens: int,
                 ran: np.ndarray, steps: int, max_depths):
        """Shared per-tick accounting over ``steps`` decode steps of one
        lane: ``depths`` are the exit indices of every live (slot, step),
        ``ran`` the segment execution-counter deltas (cohort units),
        ``max_depths`` the per-step max live exit depth."""
        n_comp = self.cfg.cascade.n_components
        self._decode_steps += steps
        # real execution accounting from the carried segment counters: in
        # cond_batch mode skipped segments genuinely did not compute; with
        # C cohorts a segment-step splits into C independently skippable
        # cohort units, so the skipped count is fractional
        self._segments_run += ran.astype(np.int64)
        C = self.cohorts
        skipped_real = float(np.sum((C * steps - ran[1:]) / C))
        # scheduling headroom: segments nobody needed each step (what a
        # perfect cond_batch run would skip), vs what actually skipped
        for md in max_depths:
            self._skip_opportunities += max(0, (n_comp - 1) - md)
            self._skip_opportunity_total += n_comp - 1
        # analytic MAC accounting (paper §6.2): dense cost vs exit cost
        self._macs_dense += n_tokens * self.mac_prefix[-1]
        self._macs_spent += float(
            np.sum(np.asarray(self.mac_prefix)[depths])) if n_tokens else 0.0
        self.compactor.observe(lane_id, depths, skipped_real, steps=steps)

    def _host_tick(self, lane, lane_id: int):
        """Decode ONE token for every live slot of a lane (one dispatch +
        one host sync per token)."""
        last = [s.generated[-1] if not s.done else 0
                for s in lane["slots"]]
        token = jnp.asarray(np.array(last, np.int32)[:, None])
        live = self._live_mask(lane)
        state = lane["state"].replace(active=jnp.asarray(live))
        run_before = np.asarray(state.segments_run)
        if self.paged:
            self.pcache.pool.begin_chunk()
        t0 = time.perf_counter()
        tok, exit_idx, conf, cache, state = self._decode(
            self.params, token, self._lane_cache(lane), state,
            self._extra(self.lane_batch))
        tok = np.asarray(tok)              # forces device sync
        exit_idx = np.asarray(exit_idx)
        conf = np.asarray(conf)
        dt = time.perf_counter() - t0
        n_live = int(live.sum())
        warm = self._decode_warm
        if warm:
            self._decode_seconds += dt
            self._decode_tokens += n_live
        else:                              # first dispatch pays compilation
            self._compile_seconds += dt
            self._decode_warm = True
        self._take_cache(lane, cache)
        lane["state"] = state
        depths = exit_idx[live]
        ran = np.asarray(state.segments_run) - run_before
        if self.flight is not None:
            # stamped around the dispatch that just synced — the slot loop
            # below may retire flights, so the chunk span lands first
            self.flight.on_chunk(
                lane_id, t0, dt, 1,
                [(s.request.rid, [int(tok[i])], [int(exit_idx[i])],
                  [float(conf[i])])
                 for i, s in enumerate(lane["slots"]) if not s.done],
                compiled=not warm, segments_run=ran)
        if warm:
            # the warm-up dispatch is excluded from EVERY window metric
            # (MAC, skip, opportunity, wallclock) so stats() rates all
            # cover the same steps; its tokens still reach the slots below
            self._account(lane_id, depths, n_live, ran, steps=1,
                          max_depths=[int(depths.max()) if n_live else 0])
        for i, s in enumerate(lane["slots"]):
            if s.done:
                continue
            s.generated.append(int(tok[i]))
            s.exit_depths.append(int(exit_idx[i]))
            s.confs.append(float(conf[i]))
            self._finish_if_done(s, int(state.t), lane_id, i)
        self._sync_tables(lane, lane_id)
        if self.paged:
            self.pcache.pool.end_chunk()

    def _device_tick(self, lane, lane_id: int):
        """Decode up to ``chunk`` tokens for a lane inside the device
        while_loop — one dispatch and ONE host sync per chunk; finished
        slots drain from the returned buffers."""
        slots = lane["slots"]
        last = [s.generated[-1] if not s.done else 0 for s in slots]
        token = np.array(last, np.int32)[:, None]
        live = self._live_mask(lane)
        remaining = np.array(
            [s.request.max_new_tokens - len(s.generated) if not s.done else 0
             for s in slots], np.int32)
        state = lane["state"].replace(active=jnp.asarray(live))
        run_before = np.asarray(state.segments_run)
        if self.paged:
            self.pcache.pool.begin_chunk()
        chunk, cache, state = self.loop.run_chunk(
            self.params, token, self._lane_cache(lane), state, remaining,
            self._extra(self.lane_batch))
        self._take_cache(lane, cache)
        lane["state"] = state
        n = chunk.n_steps
        n_tok = int(chunk.live.sum())
        if chunk.compiled:                 # first dispatch pays compilation
            self._compile_seconds += chunk.seconds
        else:
            self._decode_seconds += chunk.seconds
            self._decode_tokens += n_tok
        if not n:
            if self.paged:
                self.pcache.pool.end_chunk()
            return
        if self.flight is not None:
            entries = []
            for i, s in enumerate(slots):
                if s.done:
                    continue
                rows = [step for step in range(n) if chunk.live[step, i]]
                entries.append((
                    s.request.rid,
                    [int(chunk.tokens[r, i]) for r in rows],
                    [int(chunk.exits[r, i]) for r in rows],
                    [float(chunk.confs[r, i]) for r in rows]))
            self.flight.on_chunk(
                lane_id, chunk.t_host, chunk.seconds, n, entries,
                compiled=chunk.compiled,
                segments_run=np.asarray(state.segments_run) - run_before)
        if not chunk.compiled:
            # like the host tick: the compile chunk is excluded from every
            # window metric so all stats() rates cover the same steps
            ran = np.asarray(state.segments_run) - run_before
            max_depths = []
            for step in range(n):
                d = chunk.exits[step][chunk.live[step]]
                max_depths.append(int(d.max()) if d.size else 0)
            self._account(lane_id, chunk.exits[chunk.live], n_tok, ran,
                          steps=n, max_depths=max_depths)
        pos = int(state.t)
        for i, s in enumerate(slots):
            if s.done:
                continue
            for step in range(n):
                if chunk.live[step, i]:
                    s.generated.append(int(chunk.tokens[step, i]))
                    s.exit_depths.append(int(chunk.exits[step, i]))
                    s.confs.append(float(chunk.confs[step, i]))
            self._finish_if_done(s, pos, lane_id, i)
        self._sync_tables(lane, lane_id)
        if self.paged:
            self.pcache.pool.end_chunk()

    def run(self, max_ticks: int = 1000):
        for _ in range(max_ticks):
            if not self.queue and all(
                    s.done for ln in self.lanes for s in ln["slots"]):
                break
            self.step()
        return self.finished

    # -- metrics ---------------------------------------------------------
    def speedup(self) -> float:
        """Analytic MAC speedup vs always running the full cascade."""
        if not self._macs_spent:
            return 1.0
        return self._macs_dense / self._macs_spent

    def wallclock_us_per_token(self) -> Optional[float]:
        """Measured decode wall-clock per generated token (µs).  The jit
        warm-up dispatch is timed separately (``compile_seconds`` in
        :meth:`stats`) and never counted here."""
        if not self._decode_tokens:
            return None
        return 1e6 * self._decode_seconds / self._decode_tokens

    def stats(self) -> dict:
        """A SNAPSHOT of the engine's metrics: every nested container is
        deep-copied, so a fleet poller holding the returned dict across
        later ``step()`` calls never observes torn state (the live
        counters — ``_admit_waits``, the paged pool's reclaim window, the
        escalation counters — keep mutating underneath)."""
        depths = list(itertools.chain.from_iterable(
            r["exit_depths"] for r in self.finished.values()))
        opp = (self._skip_opportunities / self._skip_opportunity_total
               if self._skip_opportunity_total else 0.0)
        return copy.deepcopy({
            "requests_finished": len(self.finished),
            "mean_exit_depth": float(np.mean(depths)) if depths else None,
            "exit_histogram": np.bincount(
                depths, minlength=self.cfg.cascade.n_components).tolist()
            if depths else None,
            "analytic_speedup": self.speedup(),
            # realized skips (cond_batch executes them; select never skips)
            "cond_batch_skip_rate": self.compactor.skip_rate(),
            # what perfect depth compaction could have skipped
            "skip_opportunity_rate": opp,
            "segments_run": self._segments_run.tolist(),
            "wallclock_us_per_token": self.wallclock_us_per_token(),
            # one-time jit compilation cost (first decode dispatch per
            # runtime path; cumulative across reset_metrics)
            "compile_seconds": self._compile_seconds,
            "runtime": self.runtime,
            "n_cohorts": self.cohorts,
            "cohort_layout": self.cfg.cascade.cohort_layout,
            "use_kernels": self.cfg.use_kernels,
            "lane_batch": self.lane_batch,
            "chunk": self.chunk if self.runtime == "device" else 1,
            "cache_layout": "paged" if self.paged else "dense",
            # ticks a request waited between submit and admission (0 =
            # admitted the same tick) — the continuous-batching win metric
            "admission_wait_ticks": list(self._admit_waits),
            "admission_wait_mean": (float(np.mean(self._admit_waits))
                                    if self._admit_waits else None),
            # block-pool occupancy (paged) vs the always-resident slab
            # footprint (dense) — same keys so the bench gate can compare
            "memory": (self.pcache.stats() if self.paged else {
                "cache_layout": "dense",
                "num_blocks": None,
                "block_size": None,
                "block_bytes": None,
                "blocks_free": None,
                "blocks_used": None,
                "peak_blocks_used": None,
                "reclaimed_by_exit": 0,
                "reclaimed_at_retire": 0,
                "blocks_reclaimed_per_chunk": [],
                "peak_cache_bytes": self._dense_cache_bytes,
                "dense_slab_bytes": self._dense_cache_bytes,
            }),
            # per-lane mean of the carried confidence EMA (slot difficulty
            # telemetry from DecodeState)
            "lane_conf_ema": [
                float(np.mean(np.asarray(lane["state"].ema_conf)))
                for lane in self.lanes],
            # per-request latency distributions (satellite of PR 10):
            # queueing + end-to-end p50/p95/p99 next to the per-token mean
            "latency": self.latency_stats(),
            "obs": (self.flight.stats() if self.flight is not None
                    else None),
            "autotune": self._autotune_stats(),
            # cross-model escalation accounting: replayed-prefix prefill is
            # attributed to the escalated request (fresh vs replayed
            # position split) so the tier's MAC window never double-counts
            # the committed prefix as new traffic
            "escalation": {
                "escalated_requests_admitted": self._escalated_admitted,
                "cancelled_for_escalation": self._cancelled_for_escalation,
                "prefill_positions_fresh": self._prefill_positions_fresh,
                "prefill_positions_replayed": self._prefill_positions_replayed,
                "replay_prefill_macs": self._replay_prefill_macs,
                "replay_prefill_seconds": self._replay_prefill_seconds,
            },
        })

    def _autotune_stats(self):
        if not self.cfg.autotune.enabled:
            return None
        from repro.autotune.telemetry import merge_telemetry
        tels = self.lane_telemetry()
        out = {
            "thresholds": (list(self._live_thresholds)
                           if self._live_thresholds is not None else None),
            "controller": (self.controller.stats()
                           if self.controller is not None else None),
        }
        if tels:
            tel = merge_telemetry(tels)
            out.update({
                "steps": float(tel["steps"]),
                "shadow_steps": float(tel["shadow_steps"]),
                "exit_counts": [float(c) for c in tel["exit_counts"]],
                "mac_spent": float(tel["mac_spent"]),
            })
        return out
