"""Fleet-wide telemetry merge + one shared solve.

:class:`TelemetryAggregator` IS a :class:`~repro.autotune.controller.
ThresholdController` — the fleet scheduler exposes the same three-method
surface an engine does (``lane_telemetry()`` concatenating every healthy
member's lanes, ``current_thresholds()``, ``push_thresholds()`` fanning
out to every member), so the controller's whole pipeline — window
accounting, min-shadow / hysteresis / drift guards, histogram build,
coordinate-descent solve, artifact persistence — runs UNCHANGED one
level up.  There is no fleet-specific solver: fixed-bin histograms merge
by elementwise addition (:func:`repro.autotune.solver.merge_histograms`),
so the merged solve is exactly the pooled-sample solve.

The aggregation win is warm-up: the ``min_shadow`` evidence window fills
from K engines' shadow samplers at once, so the fleet reaches its first
stable threshold push in ~1/K the per-engine shadow samples any single
engine would need — gated in ``BENCH_serving.json``'s ``fleet`` section.
Artifacts written here carry ``source="fleet"`` so a warm-starting
engine (or a member added later via ``FleetScheduler.add_member``) can
tell it is seeding from fleet-scale evidence.
"""
from __future__ import annotations

from typing import List

from repro.autotune.controller import ThresholdController
from repro.autotune.solver import ExitHistogram, merge_histograms
from repro.autotune.telemetry import merge_telemetry


class TelemetryAggregator(ThresholdController):
    """A ThresholdController whose "engine" is a whole FleetScheduler.

    Construction is the controller's (``cfg``, ``mac_prefix``, the guard
    overrides, ``artifact_dir``); pass the instance as
    ``FleetScheduler(..., aggregator=...)`` and the scheduler attaches it
    (warm-start push fans to every member) and drives
    :meth:`maybe_update` once per fleet tick.  Members must NOT carry
    their own controllers — two solvers pushing thresholds at each other
    through the same engines is churn, and the scheduler refuses the
    combination at construction.
    """

    source = "fleet"

    # ------------------------------------------------------------------
    # introspection helpers (bench/gate instrumentation; the solve path
    # above never calls these)
    def per_member_shadow(self, fleet) -> List[float]:
        """Each member's own accumulated shadow evidence — what that
        engine would be solving from if it were alone.  The warm-up gate
        compares ``max(per_member_shadow)`` at first push against the
        single-engine ``min_shadow`` requirement."""
        out = []
        for m in fleet.members:
            tels = m.lane_telemetry()
            out.append(float(merge_telemetry(tels)["shadow_steps"])
                       if tels else 0.0)
        return out

    def metrics_into(self, reg, fleet) -> None:
        """Contribute the aggregator's view to a fleet scrape: solver
        counters plus each member's own shadow evidence (the per-member
        share of the merged solve's evidence pool)."""
        st = self.stats()
        reg.counter("repro_fleet_autotune_resolves_total",
                    "Merged telemetry solves attempted.", st["resolves"])
        reg.counter("repro_fleet_autotune_pushes_total",
                    "Merged solves that pushed thresholds.", st["pushes"])
        reg.counter("repro_fleet_autotune_drift_resets_total",
                    "Confidence-drift telemetry rebases.",
                    st["drift_resets"])
        try:
            shadows = self.per_member_shadow(fleet)
        except Exception:                             # noqa: BLE001
            return
        for i, s in enumerate(shadows):
            reg.gauge("repro_fleet_member_shadow_steps",
                      "Shadow full-depth evidence accumulated per member.",
                      s, {"member": str(i)})

    def merged_histogram(self, fleet) -> ExitHistogram:
        """Merge per-member histograms explicitly (members → histograms →
        :func:`merge_histograms`).  Equivalent to the solve path's merged-
        telemetry histogram — by construction, since fixed-bin counts sum
        — but built the long way so tests/benches can pin that equality
        member-by-member."""
        hists = [ExitHistogram.from_telemetry(merge_telemetry(tels),
                                              mac_prefix=self.mac_prefix)
                 for m in fleet.members
                 for tels in [m.lane_telemetry()] if tels]
        return merge_histograms(hists)
