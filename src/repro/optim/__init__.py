from repro.optim.optimizer import (Optimizer, adamw, clip_by_global_norm,
                                   sgd_momentum)
from repro.optim.schedule import (constant_schedule, cosine_schedule,
                                  resnet_paper_schedule, warmup_cosine)

__all__ = ["Optimizer", "adamw", "sgd_momentum", "clip_by_global_norm",
           "constant_schedule", "cosine_schedule", "resnet_paper_schedule",
           "warmup_cosine"]
