"""Ablation: softmax-max confidence (the paper) vs entropy confidence
(BranchyNet [TMK16]) on the same trained cascade.

The paper argues max-softmax (i) needs no extra training and (ii) trades
compute/accuracy at least as well.  We calibrate both measures with the §5
procedure (which is measure-agnostic: it only needs a scalar confidence)
and compare speedup at matched ε.
"""
import numpy as np

import jax

from benchmarks._shared import N_CLASSES, trained_cascade
from repro.core.calibration import calibrate_thresholds
from repro.core.cascade import cascade_evaluate
from repro.core.confidence import entropy_confidence
from repro.core.macs import resnet_component_macs
from repro.core.resnet_trainer import collect_outputs


def _entropy_conf(model, params, state, data, batch_size=256):
    @jax.jit
    def fwd(x):
        logits, _ = model.apply(params, state, x, train=False)
        return [entropy_confidence(lg) for lg in logits]
    out = [[] for _ in range(3)]
    for i in range(0, len(data), batch_size):
        es = fwd(jax.numpy.asarray(data.images[i:i + batch_size]))
        for m in range(3):
            out[m].append(np.asarray(es[m]))
    # map (-inf, 0] entropy-confidence onto (0, 1] so §5 grids behave
    return [1.0 / (1.0 - np.concatenate(o)) for o in out]


def run():
    model, report, (train, val, test) = trained_cascade()
    mac_prefix = resnet_component_macs(model.n, N_CLASSES,
                                       enhance_dim=model.enhance_dim)
    # softmax-max confidences (paper)
    conf_v, pred_v, corr_v = collect_outputs(model, report.params,
                                             report.state, val)
    conf_t, pred_t, _ = collect_outputs(model, report.params, report.state,
                                        test)
    # entropy confidences (BranchyNet baseline), same predictions
    ent_v = _entropy_conf(model, report.params, report.state, val)
    ent_t = _entropy_conf(model, report.params, report.state, test)

    rows = []
    for eps in (0.01, 0.05):
        cal_s = calibrate_thresholds(conf_v, corr_v, eps)
        res_s = cascade_evaluate(conf_t, pred_t, test.labels, mac_prefix,
                                 cal_s.thresholds)
        cal_e = calibrate_thresholds(ent_v, corr_v, eps)
        res_e = cascade_evaluate(ent_t, pred_t, test.labels, mac_prefix,
                                 cal_e.thresholds)
        rows.append((f"ablation/eps={eps:g}/softmax", 0.0,
                     f"acc={res_s.accuracy:.4f};speedup={res_s.speedup:.3f}"))
        rows.append((f"ablation/eps={eps:g}/entropy", 0.0,
                     f"acc={res_e.accuracy:.4f};speedup={res_e.speedup:.3f}"))
    return rows
