"""Table 2 reproduction: per-component accuracy + ε-swept cascade
accuracy/speedup on the synthetic difficulty-structured dataset.

Small-scale (CPU) variant of examples/paper_reproduction.py so that
``python -m benchmarks.run`` is self-contained; the full-scale numbers live
in results/repro_c10.json (EXPERIMENTS.md §Paper).
"""
import time

import numpy as np

from repro.core.resnet_trainer import evaluate_tradeoff, train_backtrack
from repro.data.synth_images import make_image_splits
from repro.models.resnet import CIResNet

EPSILONS = [0.0, 0.01, 0.02, 0.04, 0.20]


def run():
    train, val, test = make_image_splits(n_classes=10, n_train=2048,
                                         n_val=512, n_test=1024, seed=11)
    model = CIResNet(n_blocks=1, n_classes=10, enhance_dim=64)
    t0 = time.time()
    report = train_backtrack(model, train, n_epochs=3, batch_size=128,
                             augment=False, test=test)
    train_s = time.time() - t0
    rows = []
    for m, acc in enumerate(report.component_acc):
        rows.append((f"table2/acc_M{m}", train_s * 1e6 / 3, f"{acc:.4f}"))
    sweep = evaluate_tradeoff(model, report.params, report.state, val, test,
                              EPSILONS, 10)
    for eps, res in sweep:
        rows.append((f"table2/eps={eps:g}/accuracy", 0.0,
                     f"{res.accuracy:.4f}"))
        rows.append((f"table2/eps={eps:g}/speedup", 0.0,
                     f"{res.speedup:.3f}"))
    return rows
