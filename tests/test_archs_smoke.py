"""Per-architecture smoke tests: REDUCED variant (2 layers, d_model<=512,
<=4 experts), one forward + one train step on CPU, asserting shapes + no NaNs.
Plus prefill/decode consistency per family.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import INPUT_SHAPES, get_config, list_configs, reduced
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.model import build_model, extra_input_shapes

ARCHS = [a for a in list_configs() if a != "ci-resnet18"]


def _extra(cfg, batch, rng):
    return {k: jnp.asarray(rng.standard_normal(s), jnp.float32)
            for k, s in extra_input_shapes(cfg, batch).items()} or None


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)), jnp.int32)
    logits, aux = model.forward_train(params, toks, _extra(cfg, 2, rng))
    assert len(logits) == cfg.cascade.n_components
    for lg in logits:
        assert lg.shape == (2, 16, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_direction(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 17)), jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    ex = _extra(cfg, 2, rng)
    if ex:
        batch["extra"] = ex
    losses = []
    for step in range(3):
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(step), batch)
        losses.append(float(loss))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]        # same batch: loss must drop


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full_forward(arch):
    cfg = reduced(get_config(arch)).replace(dtype="float32")
    if cfg.n_experts:          # capacity drops change results; disable them
        cfg = cfg.replace(capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    S = 13
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, S + 1)), jnp.int32)
    ex = _extra(cfg, 2, rng)
    logits_full, _ = model.forward_train(params, toks, ex)
    cache = model.init_cache(2, S + 4)
    el, cache = model.prefill(params, toks[:, :S], cache, ex)
    sl, cache = model.decode_step(params, toks[:, S:S + 1], S, cache, ex)
    for a, b in zip(logits_full, sl):
        np.testing.assert_allclose(np.asarray(a[:, S, :]), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)
    for a, b in zip(logits_full, el):
        np.testing.assert_allclose(np.asarray(a[:, S - 1, :]), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_matches_full_window_mask():
    """Ring-buffer decode == full-forward with the same window mask."""
    cfg = reduced(get_config("mixtral-8x7b")).replace(
        dtype="float32", attn_window=8, capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    S = 21
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, S + 1)), jnp.int32)
    logits_full, _ = model.forward_train(params, toks)
    cache = model.init_cache(1, S + 4)   # capacity = window (8)
    assert cache["kpos"].shape[0] == 8
    el, cache = model.prefill(params, toks[:, :S], cache)
    sl, _ = model.decode_step(params, toks[:, S:S + 1], S, cache)
    for a, b in zip(logits_full, sl):
        np.testing.assert_allclose(np.asarray(a[:, S, :]), np.asarray(b),
                                   rtol=2e-3, atol=2e-3)


def test_cond_batch_skips_and_backfills():
    """cond_batch with threshold 0 ⇒ every sequence exits at component 0;
    the staged executor skips the deeper segment's compute (its execution
    counter stays 0) but keeps its caches coherent (backfill)."""
    from repro.core.exec import StagedExecutor

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(thresholds=(0.0, 0.0), exit_mode="cond_batch",
                           state_backfill=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8)), jnp.int32)
    cache = model.init_cache(2, 16)
    ex = StagedExecutor(model, cfg)
    d, cache, state = ex.prefill(params, toks, cache)
    k_before = cache["segments"][1][0]["k"][:, :, 8]
    d2, cache2, state = ex.decode_step(params, d.prediction[:, None], cache,
                                       state)
    assert int(np.max(np.asarray(d2.exit_index))) == 0
    # the deep segment never computed ...
    np.testing.assert_array_equal(np.asarray(state.segments_run), [1, 0])
    # ... yet its cache was written at slot 8 (backfill keeps it coherent)
    k_after = cache2["segments"][1][0]["k"][:, :, 8]
    assert float(jnp.max(jnp.abs(k_after))) > 0
    assert float(jnp.max(jnp.abs(k_before))) == 0


def test_exit_boundaries_cover_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch)
        segs = cfg.segments
        assert segs[0][0] == 0 and segs[-1][1] == cfg.n_layers
        for (a, b), (c, d) in zip(segs, segs[1:]):
            assert b == c and a < b
