"""Depth-compacted continuous batching.

The TPU adaptation of the paper's per-sample early termination (DESIGN.md §5):
``cond_batch`` segment skipping only saves compute when *every* co-resident
sequence is confident, so the scheduler's job is to co-locate requests with
similar expected exit depth.  Each *lane* is an independent (cache, batch)
decode stream; requests are admitted to the lane whose running depth estimate
matches the request's predicted depth (from its prefill exit, then an EMA of
observed exits).

This is a pure-host scheduling layer: no device state moves between lanes.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


def cohort_capacity(lane_batch: int, n_cohorts: int) -> int:
    """Round a lane's slot capacity UP to a multiple of ``n_cohorts``.

    Cohorts are contiguous equal-size slot ranges, so a lane whose capacity
    is not a cohort multiple silently degrades to fewer cohorts (see
    :func:`repro.core.exec.effective_cohorts`) — forfeiting exactly the
    per-cohort skip granularity the config asked for.  The serving engine
    admits with this rounded capacity so the degradation path never
    triggers in default configs; the extra slots are ordinary admission
    capacity (idle slots cost one masked row each).
    """
    n = max(1, int(n_cohorts))
    lane_batch = max(1, int(lane_batch))
    return ((lane_batch + n - 1) // n) * n


@dataclasses.dataclass
class LaneStats:
    depth_ema: float
    steps: int = 0
    # float: with cohort-split skipping (cascade.n_cohorts > 1) a segment
    # can be skipped for a fraction of the lane (skipped cohorts / cohorts)
    skipped_segments: float = 0.0
    total_segments: int = 0


class DepthCompactor:
    """Assigns requests to lanes by predicted exit depth.

    Also owns THE population depth prior: one EMA (decay ``ema``) over the
    prefill exits actually observed, used to predict the depth of requests
    that arrive without a hint.  (The serving engine used to keep its own
    copy of this EMA with hard-coded constants; there is exactly one now.)
    """

    def __init__(self, n_lanes: int, n_components: int, ema: float = 0.8):
        self.n_lanes = n_lanes
        self.n_components = n_components
        self.ema = ema
        # lane i targets depth band [i * n_c / n_lanes, (i+1) * n_c / n_lanes)
        self.lane_stats = [LaneStats(depth_ema=(i + 0.5) * n_components
                                     / n_lanes)
                           for i in range(n_lanes)]
        self.population_prior = (n_components - 1) / 2

    def predict_depth(self, hint: Optional[float] = None) -> float:
        """Expected exit depth of an incoming request: an explicit hint
        (e.g. an earlier turn's prefill exit) wins; otherwise the running
        population prior over observed prefill exits."""
        return self.population_prior if hint is None else float(hint)

    def observe_prefill_exit(self, depth: float):
        """Warm the population prior with a FIRST prefill exit."""
        self.population_prior = (self.ema * self.population_prior
                                 + (1 - self.ema) * float(depth))

    def assign(self, predicted_depth: float, free_slots: List[int]) -> int:
        """Pick the free lane whose depth estimate is closest."""
        if not free_slots:
            raise ValueError("no free lanes")
        dists = [abs(self.lane_stats[i].depth_ema - predicted_depth)
                 for i in free_slots]
        return free_slots[int(np.argmin(dists))]

    # -- cohort placement (within-lane skip granularity) -----------------
    def preferred_cohort(self, predicted_depth: float, n_cohorts: int,
                         free_per_cohort: Optional[List[int]] = None) -> int:
        """Cohort band for a predicted exit depth: cohort c of C targets
        depths in [c, c+1) * n_components / C — shallow traffic lands in
        low cohorts, deep traffic in high ones, so per-cohort skip
        predicates fire on homogeneous subgroups.

        ``free_per_cohort`` (length ``n_cohorts``) is the paged-admission
        fix: the count of slots each cohort can actually admit NOW (free
        slot with block-pool coverage behind it).  Without it, the pure
        depth-band answer could point continuous admission at a cohort
        with no admissible slot, stalling the request a whole chunk even
        while another cohort had both a slot and free blocks — worst-case
        -slot thinking surviving into the paged layout.  With it, the
        depth band only breaks ties among cohorts that CAN admit; if the
        band cohort has capacity it wins unchanged."""
        if n_cohorts <= 1:
            return 0
        frac = predicted_depth / max(1, self.n_components - 1)
        band = int(np.clip(int(frac * n_cohorts), 0, n_cohorts - 1))
        if free_per_cohort is None:
            return band
        open_cohorts = [c for c in range(n_cohorts)
                        if c < len(free_per_cohort) and free_per_cohort[c] > 0]
        if not open_cohorts or band in open_cohorts:
            return band
        return min(open_cohorts, key=lambda c: (abs(c - band), c))

    def pick_slot(self, predicted_depth: float, free_slots: List[int],
                  lane_batch: int, n_cohorts: int,
                  free_per_cohort: Optional[List[int]] = None) -> int:
        """Among a lane's free slots, pick the one whose cohort (contiguous
        ``lane_batch / n_cohorts`` slot ranges) best matches the request's
        predicted depth.  n_cohorts == 1 degenerates to first-free;
        ``free_per_cohort`` passes through to :meth:`preferred_cohort`
        (admissibility-aware cohort choice for paged admission)."""
        if not free_slots:
            raise ValueError("no free slots")
        pref = self.preferred_cohort(predicted_depth, n_cohorts,
                                     free_per_cohort)
        return min(free_slots,
                   key=lambda s: (abs(s * n_cohorts // lane_batch - pref), s))

    def observe(self, lane: int, exit_depths: np.ndarray,
                segments_skipped: float, steps: int = 1):
        """Record ``steps`` decode steps of a lane: the exit depths of every
        live (slot, step), and how many segment-executions were skipped
        (fractional under cohort splitting).  The device runtime reports a
        whole K-token chunk at once (steps = chunk length run)."""
        st = self.lane_stats[lane]
        if len(exit_depths):
            # one EMA blend per STEP, compounded: a K-step chunk report
            # must move depth_ema as far as K per-token reports would,
            # or device-runtime lanes adapt ~chunk-times slower than host
            decay = self.ema ** steps
            st.depth_ema = (decay * st.depth_ema
                            + (1 - decay) * float(np.mean(exit_depths)))
        st.steps += steps
        st.skipped_segments += segments_skipped
        st.total_segments += (self.n_components - 1) * steps

    def observe_retire(self, lane: int):
        """A slot in ``lane`` finished: decay the lane's depth EMA toward
        the population prior.  Without this, a lane that drained its deep
        requests keeps a stale high ``depth_ema`` and repels the shallow
        traffic that should now fill it (and vice versa)."""
        st = self.lane_stats[lane]
        st.depth_ema = (self.ema * st.depth_ema
                        + (1 - self.ema) * self.population_prior)

    def skip_rate(self) -> float:
        tot = sum(s.total_segments for s in self.lane_stats)
        if not tot:
            return 0.0
        return sum(s.skipped_segments for s in self.lane_stats) / tot

    def reset_skip_counters(self):
        """Zero the skip accounting without losing the learned depth EMAs
        (scheduler state) — used when the engine resets its metrics after
        jit warm-up so every reported rate covers the same step window."""
        for s in self.lane_stats:
            s.steps = 0
            s.skipped_segments = 0
            s.total_segments = 0
