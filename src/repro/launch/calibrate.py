"""Autotune calibration launcher: drive traffic, accumulate exit
telemetry, resolve thresholds, persist the artifact.

    PYTHONPATH=src python -m repro.launch.calibrate --arch qwen2.5-3b \
        --smoke --epsilon 0.05 --requests 8 --max-new 16 --out artifacts/

Runs the serving engine with ``cfg.autotune.enabled`` and an attached
:class:`repro.autotune.controller.ThresholdController`, forces a final
resolve once traffic drains, writes the config-hash-keyed calibration
artifact, and verifies it round-trips (load + key + threshold match) —
the CI ``autotune-smoke`` lane runs exactly this.  ``--budget-macs``
switches the solve from the ε direction to the average-MAC direction.
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import numpy as np

from repro.autotune import ThresholdController, load_artifact
from repro.autotune.artifacts import artifact_path
from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request
from repro.utils import get_logger

log = get_logger("calibrate")


def main():
    ap = argparse.ArgumentParser(
        description="Calibrate cascade exit thresholds from live exit "
                    "telemetry (repro.autotune) and persist the artifact.")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--epsilon", type=float, default=0.05,
                    help="target accuracy degradation ε (solve direction "
                         "when --budget-macs is not given)")
    ap.add_argument("--budget-macs", type=float, default=0.0,
                    help="target average MACs/token; > 0 switches the "
                         "solver to the budget direction")
    ap.add_argument("--out", default="artifacts",
                    help="artifact directory (config-hash-keyed JSON)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--threshold", type=float, default=0.5,
                    help="initial thresholds while telemetry accumulates")
    ap.add_argument("--runtime", default="device",
                    choices=["host", "device"])
    ap.add_argument("--chunk", type=int, default=8)
    ap.add_argument("--lanes", type=int, default=2)
    ap.add_argument("--lane-batch", type=int, default=2)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--bins", type=int, default=32,
                    help="confidence histogram resolution")
    ap.add_argument("--shadow-every", type=int, default=4,
                    help="shadow full-depth pass every k-th decode step")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    n = cfg.cascade.n_components
    cfg = cfg.with_cascade(
        thresholds=tuple([args.threshold] * (n - 1) + [0.0]),
        exit_mode="cond_batch")
    cfg = cfg.with_autotune(
        enabled=True, bins=args.bins, shadow_every=args.shadow_every,
        epsilon=args.epsilon, mac_budget=args.budget_macs)

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.core.macs import segment_macs_per_token
    controller = ThresholdController(
        cfg, segment_macs_per_token(cfg, args.cache_len),
        artifact_dir=args.out)
    engine = CascadeServingEngine(cfg, model, params,
                                  lane_batch=args.lane_batch,
                                  n_lanes=args.lanes,
                                  cache_len=args.cache_len,
                                  runtime=args.runtime, chunk=args.chunk,
                                  autotune=controller)

    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(Request(
            rid=i,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_new_tokens=args.max_new))
    engine.run()

    # final resolve on everything accumulated (bypasses the periodic tick
    # and the hysteresis guard; still refuses on zero shadow evidence).
    # A push with artifact_dir set persists the artifact itself.
    ths = controller.update(engine, force=True)
    if ths is None:
        log.error("no thresholds resolved — not enough shadow telemetry "
                  "(%d requests produced too few decode steps?)",
                  args.requests)
        return 1
    art = load_artifact(args.out, cfg)
    assert art is not None, "artifact did not round-trip"
    assert tuple(art.thresholds) == tuple(controller.thresholds), \
        (art.thresholds, controller.thresholds)
    path = artifact_path(args.out, art.config_key)

    summary = {
        "artifact": path,
        "config_key": art.config_key,
        "thresholds": list(art.thresholds),
        "direction": art.direction,
        "target": art.target,
        "agreement": art.agreement,
        "avg_macs": art.avg_macs,
        "shadow_steps": art.shadow_steps,
        "requests_finished": engine.stats()["requests_finished"],
    }
    log.info("calibration: %s", json.dumps(summary, indent=2))
    print(json.dumps(summary))
    return 0


if __name__ == "__main__":
    sys.exit(main())
