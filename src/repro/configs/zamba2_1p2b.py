"""zamba2-1.2b — Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_head_dim=64,
    ssm_expand=2,
    shared_attn_every=6,       # one shared full-attention block every 6 Mamba2 layers
    source="arXiv:2411.15242",
))
