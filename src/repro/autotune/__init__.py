"""Online exit-telemetry + threshold-autotuning subsystem.

The paper's headline knob — pick an acceptable accuracy degradation ε and
the system determines per-component confidence thresholds δ̂_m — lives here
as a *live serving* capability instead of an offline calibration script:

* :mod:`repro.autotune.telemetry` — the device-resident
  :class:`~repro.autotune.telemetry.ExitTelemetry` pytree accumulated
  inside the decode hot path (host step and device while_loop alike),
  including the sampled shadow full-depth correctness proxy.
* :mod:`repro.autotune.solver` — the histogram-space coordinate-descent
  threshold solver (ε → thresholds, and average-MAC budget → thresholds).
* :mod:`repro.autotune.controller` — the
  :class:`~repro.autotune.controller.ThresholdController` that periodically
  resolves thresholds from live telemetry and pushes them into running
  engines as plain arrays (no retrace).
* :mod:`repro.autotune.artifacts` — config-hash-keyed calibration
  artifacts so a fleet warm-starts instead of re-learning thresholds.
"""
from repro.autotune.artifacts import (CalibrationArtifact, config_key,
                                      load_artifact, save_artifact)
from repro.autotune.controller import ThresholdController
from repro.autotune.solver import (ExitHistogram, SolveResult,
                                   compose_escalation, compose_mac_prefix,
                                   edges_from_thresholds, merge_histograms,
                                   split_tier_thresholds, solve_budget,
                                   solve_epsilon, thresholds_from_edges)
from repro.autotune.telemetry import (ExitTelemetry, conf_to_bin,
                                      init_telemetry, merge_telemetry,
                                      pack_rider, telemetry_for,
                                      telemetry_to_host)

__all__ = [
    "CalibrationArtifact", "config_key", "load_artifact", "save_artifact",
    "ThresholdController",
    "ExitHistogram", "SolveResult", "compose_escalation",
    "compose_mac_prefix", "edges_from_thresholds", "merge_histograms",
    "split_tier_thresholds", "solve_budget", "solve_epsilon",
    "thresholds_from_edges",
    "ExitTelemetry", "conf_to_bin", "init_telemetry", "merge_telemetry",
    "pack_rider", "telemetry_for", "telemetry_to_host",
]
