"""Figure 3 reproduction: accuracy vs average MACs curve swept over
ε ∈ {20%, …, 1%, 0%} (the paper's grid)."""
import numpy as np

from benchmarks._shared import N_CLASSES, trained_cascade
from repro.core.resnet_trainer import evaluate_tradeoff

EPSILONS = [0.20, 0.15, 0.10, 0.08, 0.06, 0.04, 0.02, 0.01, 0.0]


def run():
    model, report, (train, val, test) = trained_cascade()
    sweep = evaluate_tradeoff(model, report.params, report.state, val, test,
                              EPSILONS, N_CLASSES,
                              measure="softmax_max", calibrator="self")
    rows = []
    accs, macs = [], []
    for eps, res in sweep:
        rows.append((f"fig3/eps={eps:g}", 0.0,
                     f"acc={res.accuracy:.4f};macs={res.avg_macs:.3g}"))
        accs.append(res.accuracy)
        macs.append(res.avg_macs)
    # the paper's qualitative claim: the curve is monotone — less compute,
    # (weakly) less accuracy
    order = np.argsort(macs)
    mono = all(np.diff(np.array(accs)[order]) >= -0.02)  # noise tolerance
    rows.append(("fig3/monotone_tradeoff", 0.0, str(mono)))
    return rows
