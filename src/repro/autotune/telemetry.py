"""Device-resident exit telemetry: the raw material of threshold autotuning.

:class:`ExitTelemetry` is a registered pytree carried inside
:class:`repro.core.exec.DecodeState`, so it rides wherever the decode state
already rides — the host serve step, the :class:`DeviceDecodeLoop`
``lax.while_loop`` carry, donation, and mesh sharding — and is accumulated
*inside* the jitted decode program.  Nothing here ever syncs to host on its
own: the device runtime keeps its one-host-sync-per-chunk discipline, and
the controller fetches the counters only at its (much sparser) resolve
ticks.

Two families of counters, all float32 (exact integer arithmetic up to 2^24
observations, and sharding-friendly):

* **live** — accumulated every decode step from the components that
  actually computed: per-component fixed-bin confidence histograms
  (``conf_hist``, restricted to samples still undecided when the component
  ran — the population its threshold gates), the answering component
  (``exit_counts``), analytic MACs of those answers (``mac_spent`` via the
  carried ``mac_weights``), and the observation count (``steps``).

* **shadow** — a sampled full-depth correctness proxy.  Every
  ``autotune.shadow_every``-th decode step (by the position cursor, so the
  schedule is deterministic and identical across runtimes) segment skipping
  is disabled for that one step, every component's (prediction, confidence)
  is captured, and the *joint* binned routing-confidence vector is
  scatter-added into ``shadow_count`` with per-component
  agreement-with-the-final-component counts in ``shadow_agree``.  Prefill
  already computes every component, so each prefill decision contributes a
  free shadow observation.  Agreement with the final component is the
  label-free stand-in for correctness: the cascade's disagreement rate with
  the full model bounds its accuracy drop, which is exactly the ε the
  paper's user-facing knob promises.

The joint histogram is what lets the solver do a *joint* threshold search:
the population reaching component m depends on the thresholds of components
before it, and only the joint distribution can re-derive that population
for candidate thresholds that differ from the deployed ones.  Cells are the
binned confidences of the ``n_components - 1`` routing components (the
final component always answers; its confidence never routes), flattened
C-order (component 0 is the slowest-varying axis) to match
``np.ravel_multi_index`` — the host-recompute reference
(:meth:`repro.autotune.solver.ExitHistogram.from_samples`) must bit-match
the device accumulation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# joint-histogram size guard: bins ** (n_components - 1) cells
MAX_CELLS = 1 << 20


def conf_to_bin(conf, bins: int):
    """Fixed-bin index of a confidence in (0, 1]: ``min(floor(c·bins),
    bins-1)``.  A deployed threshold δ = e/bins then corresponds exactly to
    the bin gate ``bin >= e`` (``c >= e/bins  ⟺  floor(c·bins) >= e`` for
    c in [0, 1]).  The fused exit-update kernel computes the same formula
    in-register; keep the two in lockstep."""
    return jnp.clip((conf * bins).astype(jnp.int32), 0, bins - 1)


def pack_rider(pred, conf, bins: int):
    """The decision scan's telemetry rider code: ``pred * bins + bin``
    packed into one int32, so each scanned component writes ONE carry row
    (the hot path pays one update, not two).  The fused kernel emits the
    same code in-register; :func:`accumulate_decode` unpacks with one
    div/mod pair per step."""
    return pred.astype(jnp.int32) * bins + conf_to_bin(conf, bins)


@dataclasses.dataclass
class ExitTelemetry:
    """Per-lane telemetry counters (a registered pytree; all f32).

    conf_hist    (n_m, bins) — live confidence histogram per component,
                 over samples still undecided when the component computed.
    exit_counts  (n_m,)      — answering component per live (slot, step).
                 The MAC counter derives from it at host-sync time
                 (``mac_spent = exit_counts · mac_weights`` in
                 :func:`telemetry_to_host`) — pricing per step on device
                 would only re-spend the decode hot path's dispatch
                 budget on arithmetic a dot product recovers exactly.
    mac_weights  (n_m,)      — per-exit analytic MAC cost (a constant
                 rider: set at init by the engine, carried untouched).
    steps        ()          — live decode (slot, step) observations.
    shadow_count (cells,)    — joint binned routing-confidence counts from
                 shadow full-depth observations (cells = bins^r with
                 r = n_m-1 routing axes, or n_m under
                 ``autotune.route_final``).
    shadow_agree (r, cells)  — of those, how many of component m's
                 predictions agreed with the final component's (the
                 route_final row is the final component's self-agreement,
                 i.e. a copy of the counts — the escalation tier rescales
                 it by the measured cross-stage agreement).
    shadow_steps ()          — shadow observations.
    """

    conf_hist: jnp.ndarray
    exit_counts: jnp.ndarray
    mac_weights: jnp.ndarray
    steps: jnp.ndarray
    shadow_count: jnp.ndarray
    shadow_agree: jnp.ndarray
    shadow_steps: jnp.ndarray

    def replace(self, **kw) -> "ExitTelemetry":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    ExitTelemetry,
    data_fields=("conf_hist", "exit_counts", "mac_weights",
                 "steps", "shadow_count", "shadow_agree", "shadow_steps"),
    meta_fields=())


def n_cells(n_components: int, bins: int, route_final: bool = False) -> int:
    cells = bins ** (n_components - 1 + bool(route_final))
    if cells > MAX_CELLS:
        raise ValueError(
            f"autotune joint histogram would need {cells} cells "
            f"(bins={bins}, n_components={n_components}, "
            f"route_final={route_final}); lower autotune.bins "
            f"(cap {MAX_CELLS})")
    return cells


def init_telemetry(n_components: int, bins: int,
                   mac_weights=None,
                   route_final: bool = False) -> ExitTelemetry:
    """Zeroed telemetry for one lane.  ``mac_weights`` is the per-exit
    analytic MAC prefix (``repro.core.macs.segment_macs_per_token``);
    zeros when the caller has no cache length to price against (the
    exit-count vector always allows a host-side re-pricing).

    ``route_final`` widens the shadow joint histogram by the final
    component's confidence axis (and its — trivially all-agreeing — agree
    row), for the cross-model escalation tier where answering at the final
    component is itself a routed decision.  The shadow fold infers the
    routing-axis count from the ``shadow_agree`` row count, so the decode
    program is shared between the two shapes.
    """
    r = n_components - 1 + bool(route_final)
    cells = n_cells(n_components, bins, route_final)
    if mac_weights is None:
        mw = jnp.zeros((n_components,), jnp.float32)
    else:
        mw = jnp.asarray(np.asarray(mac_weights, np.float32))
        if mw.shape != (n_components,):
            raise ValueError(f"mac_weights shape {mw.shape} != "
                             f"({n_components},)")
    return ExitTelemetry(
        conf_hist=jnp.zeros((n_components, bins), jnp.float32),
        exit_counts=jnp.zeros((n_components,), jnp.float32),
        mac_weights=mw,
        steps=jnp.zeros((), jnp.float32),
        shadow_count=jnp.zeros((cells,), jnp.float32),
        shadow_agree=jnp.zeros((r, cells), jnp.float32),
        shadow_steps=jnp.zeros((), jnp.float32))


def telemetry_for(cfg, mac_weights=None) -> Optional[ExitTelemetry]:
    """Telemetry for a ModelConfig, or None when autotune is disabled —
    the one switch that keeps every decode graph byte-identical to the
    pre-autotune program when the subsystem is off."""
    if not cfg.autotune.enabled:
        return None
    return init_telemetry(cfg.cascade.n_components, cfg.autotune.bins,
                          mac_weights,
                          route_final=cfg.autotune.route_final)


def _shadow_cell(tbin: jnp.ndarray, bins: int, r: int) -> jnp.ndarray:
    """Flat C-order joint cell index from the first ``r`` of the (n_m, B)
    bin rows — the routing axes (r == n_m - 1 normally: the final row
    never routes; r == n_m under ``route_final``)."""
    cell = jnp.zeros(tbin.shape[1:], jnp.int32)
    for m in range(r):
        cell = cell * bins + tbin[m]
    return cell


def _fold_shadow(ops, tbin, tpred, f_live, bins: int):
    """THE shadow fold — one full-depth observation batch into the
    (shadow_count, shadow_agree, shadow_steps) triple.  Shared by the
    decode path (under its lax.cond shadow gate) and the prefill path so
    the two sample sources can never drift apart.  The routing-axis count
    comes from the ``shadow_agree`` row count — with ``route_final`` the
    final component contributes a cell axis and an (all-ones) agree row
    of its own; the rider already carries every component's code either
    way."""
    s_count, s_agree, s_steps = ops
    r = s_agree.shape[0]
    cell = _shadow_cell(tbin, bins, r)
    s_count = s_count.at[cell].add(f_live)
    agree = (tpred[:r] == tpred[-1][None, :]).astype(jnp.float32)
    cells = s_count.shape[0]
    arows = jnp.broadcast_to(
        jnp.arange(r, dtype=jnp.int32)[:, None], agree.shape)
    aidx = (arows * cells + cell[None, :]).reshape(-1)
    s_agree = s_agree.reshape(-1).at[aidx].add(
        (agree * f_live[None, :]).reshape(-1)).reshape(s_agree.shape)
    return s_count, s_agree, s_steps + jnp.sum(f_live)


def accumulate_decode(tel: ExitTelemetry, carry, decision, active,
                      shadow) -> ExitTelemetry:
    """Fold one staged decode step into the counters (pure jnp — safe
    inside jit / lax.while_loop / lax.cond).

    ``carry`` is the finished decision-scan carry holding the telemetry
    rider (``tcode``: :func:`pack_rider`'s per-component packed
    prediction/confidence-bin codes); segments that were skipped left
    their rows zeroed.  "Still undecided when component m ran" is exactly
    ``m <= exit_index`` (the answering component is the last one a sample
    reaches), so the reach mask comes from the decision instead of a
    carried rider row — fewer hot-path dispatches.  ``shadow`` is this
    step's shadow flag (traced scalar bool): when set, skipping was
    disabled upstream, every row is filled, and the joint histogram
    absorbs the full confidence vector.
    """
    bins = tel.conf_hist.shape[1]
    tcode = carry["tcode"]
    tbin = tcode % bins
    tpred = tcode // bins
    n_m = tbin.shape[0]
    live = jnp.asarray(active, bool)
    f_live = live.astype(jnp.float32)

    # live: per-component confidence histogram over still-undecided samples
    rows = jnp.broadcast_to(jnp.arange(n_m, dtype=jnp.int32)[:, None],
                            tbin.shape)
    reach = jnp.logical_and(rows <= decision.exit_index[None, :],
                            live[None, :]).astype(jnp.float32)
    flat_idx = (rows * bins + tbin).reshape(-1)
    conf_hist = tel.conf_hist.reshape(-1).at[flat_idx].add(
        reach.reshape(-1)).reshape(tel.conf_hist.shape)

    exit_counts = tel.exit_counts.at[decision.exit_index].add(f_live)
    steps = tel.steps + jnp.sum(f_live)

    # shadow: joint routing-confidence histogram + agreement proxy.  The
    # scatter-adds sit under lax.cond so the (shadow_every - 1)/shadow_every
    # non-shadow steps skip their dispatch entirely — telemetry's per-step
    # cost is the live counters only.
    shadow_count, shadow_agree, shadow_steps = jax.lax.cond(
        jnp.asarray(shadow, bool),
        lambda ops: _fold_shadow(ops, tbin, tpred, f_live, bins),
        lambda ops: ops,
        (tel.shadow_count, tel.shadow_agree, tel.shadow_steps))

    return tel.replace(conf_hist=conf_hist, exit_counts=exit_counts,
                       steps=steps, shadow_count=shadow_count,
                       shadow_agree=shadow_agree, shadow_steps=shadow_steps)


def accumulate_prefill(tel: ExitTelemetry, tcode,
                       active) -> ExitTelemetry:
    """Fold one prefill decision into the SHADOW counters.

    Prefill computes every component anyway, so each live slot is a free
    full-depth observation: ``tcode`` is the decision carry's telemetry
    rider ((n_m, B) :func:`pack_rider` codes — all rows filled, since
    nothing skips at prefill).  Prefill does NOT touch the live counters
    — those describe the decode hot path the thresholds gate.
    """
    f_live = jnp.asarray(active, bool).astype(jnp.float32)
    bins = tel.conf_hist.shape[1]
    shadow_count, shadow_agree, shadow_steps = _fold_shadow(
        (tel.shadow_count, tel.shadow_agree, tel.shadow_steps),
        tcode % bins, tcode // bins, f_live, bins)
    return tel.replace(shadow_count=shadow_count, shadow_agree=shadow_agree,
                       shadow_steps=shadow_steps)


def telemetry_to_host(tel: ExitTelemetry) -> dict:
    """One batched device_get of every counter → plain numpy dict.

    ``mac_spent`` is derived here (``exit_counts · mac_weights`` in f32)
    rather than priced per step on device — bit-identical across host and
    device runtimes by construction, zero hot-path cost."""
    vals = jax.device_get((tel.conf_hist, tel.exit_counts,
                           tel.mac_weights, tel.steps, tel.shadow_count,
                           tel.shadow_agree, tel.shadow_steps))
    keys = ("conf_hist", "exit_counts", "mac_weights",
            "steps", "shadow_count", "shadow_agree", "shadow_steps")
    out = {k: np.asarray(v) for k, v in zip(keys, vals)}
    out["mac_spent"] = np.float32(
        np.dot(out["exit_counts"].astype(np.float32),
               out["mac_weights"].astype(np.float32)))
    return out


def merge_telemetry(tels: Sequence) -> dict:
    """Sum per-lane telemetry into one host-side counter dict, in lane
    order (fixed summation order keeps the merge bit-deterministic).
    Accepts ExitTelemetry pytrees or host dicts; ``mac_weights`` is a
    constant rider and is carried, not summed."""
    hosts = [t if isinstance(t, dict) else telemetry_to_host(t)
             for t in tels]
    if not hosts:
        raise ValueError("no telemetry to merge")
    out = {k: hosts[0][k].copy() for k in hosts[0]}
    for h in hosts[1:]:
        for k in out:
            if k == "mac_weights":
                continue
            out[k] = out[k] + h[k]
    return out
