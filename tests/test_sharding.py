"""Sharding-rule unit tests over an AbstractMesh (no devices needed).

These pin the layout contracts that the dry-run proves end-to-end:
divisibility-gated placement, FSDP placement, serve1d/serve2d semantics,
expert-parallel fallbacks, and the batch-1 sequence-parallel cache rule.
"""
import jax
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.launch.shard_rules import batch_spec, cache_spec, param_spec
from repro.models.model import build_model

def _abstract_mesh(shape, names):
    """jax >= 0.4.38 takes (shape, axis_names); 0.4.37 takes shape_tuple."""
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


MESH = _abstract_mesh((16, 16), ("data", "model"))
MESH_MP = _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _leaf(spec_tree, *path):
    node = spec_tree
    for p in path:
        node = node[p]
    return node


@pytest.fixture(scope="module")
def qwen_params():
    cfg = get_config("qwen2.5-3b")
    model = build_model(cfg)
    return cfg, jax.eval_shape(model.init, jax.random.PRNGKey(0))


def test_default_layout_tp_plus_fsdp(qwen_params):
    cfg, params = qwen_params
    spec = param_spec(params, cfg, MESH)
    # embed (V, d): vocab over model (151936 % 16 == 0), fsdp on d
    assert _leaf(spec, "embed") == P("model", "data")
    # column-parallel wq (L, d, H*hd): model on last, data on first free
    wq = _leaf(spec, "segments")[0][0]["attn"]["wq"]
    assert wq[-1] == "model" and "data" in wq
    # row-parallel wo (L, H*hd, d): model on -2
    wo = _leaf(spec, "segments")[0][0]["attn"]["wo"]
    assert wo[-2] == "model"
    # norms replicated
    assert _leaf(spec, "final_norm")["w"] == P()


def test_serve1d_no_fsdp(qwen_params):
    cfg, params = qwen_params
    spec = param_spec(params, cfg, MESH, mode="serve1d")
    wq = _leaf(spec, "segments")[0][0]["attn"]["wq"]
    assert wq[-1] == "model"
    assert "data" not in tuple(a for a in wq if a)


def test_serve2d_combined_axes(qwen_params):
    cfg, params = qwen_params
    spec = param_spec(params, cfg, MESH, mode="serve2d")
    wq = _leaf(spec, "segments")[0][0]["attn"]["wq"]
    # 16 heads x 128 = 2048 divisible by 256 -> combined axes on output dim
    assert wq[-1] == ("model", "data")


def test_moe_expert_parallel_and_fallback():
    # qwen3: 128 experts % 16 == 0 -> expert parallel (+ff over data in 2d)
    cfg = get_config("qwen3-moe-235b-a22b")
    params = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    spec = param_spec(params, cfg, MESH, mode="serve2d")

    def find_moe(spec_tree):
        for seg in spec_tree["segments"]:
            for stage in seg:
                if "moe" in stage:
                    return stage["moe"]
        raise AssertionError("no moe stage")
    moe = find_moe(spec)
    assert moe["w_up"][-3] == "model" and moe["w_up"][-1] == "data"
    # mixtral: 8 experts not divisible by 16 -> tensor-parallel inside experts
    cfg2 = get_config("mixtral-8x7b")
    params2 = jax.eval_shape(build_model(cfg2).init, jax.random.PRNGKey(0))
    spec2 = param_spec(params2, cfg2, MESH)
    moe2 = find_moe(spec2)
    assert moe2["w_up"][-3] is None and moe2["w_up"][-1] == "model"


def test_cache_batch_vs_sequence_parallel():
    cfg = get_config("yi-9b")
    model = build_model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 1024))
    spec = cache_spec(cache, cfg, MESH, batch=128)
    k = spec["segments"][0][0]["k"]
    assert k[1] == "data"                 # batch over data
    # batch=1 long-context: shard the KV slot dim instead
    cache1 = jax.eval_shape(lambda: model.init_cache(1, 1024))
    spec1 = cache_spec(cache1, cfg, MESH, batch=1)
    k1 = spec1["segments"][0][0]["k"]
    assert k1[1] is None and k1[2] == "data"


def test_batch_spec_divisibility():
    cfg = get_config("yi-9b")
    assert batch_spec(cfg, MESH, 128, 2)[0] == "data"
    assert batch_spec(cfg, MESH, 1, 2) == P()
    assert batch_spec(cfg, MESH_MP, 128, 2)[0] == ("pod", "data")


def test_whisper_vocab_not_sharded():
    # 51865 does not divide 16 -> unembedding replicated on the vocab dim
    cfg = get_config("whisper-tiny")
    params = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
    spec = param_spec(params, cfg, MESH, fsdp=False)
    assert spec["lm_head"][-1] is None
    assert spec["embed"][0] is None


def test_every_arch_spec_structurally_valid():
    """Every placed axis must divide its dim (the invariant the dry-run
    relies on); specs must match param tree structure."""
    from repro.configs import list_configs
    for arch in list_configs():
        if arch == "ci-resnet18":
            continue
        cfg = get_config(arch)
        params = jax.eval_shape(build_model(cfg).init, jax.random.PRNGKey(0))
        for mode in ("default", "serve1d", "serve2d"):
            spec = param_spec(params, cfg, MESH, mode=mode)
            flat_p = jax.tree_util.tree_leaves_with_path(params)
            flat_s = jax.tree_util.tree_leaves_with_path(
                spec, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_p) == len(flat_s)
            sizes = dict(MESH.shape)
            for (path, leaf), (_, sp) in zip(flat_p, flat_s):
                for dim, ax in zip(np.shape(leaf), tuple(sp)):
                    if ax is None:
                        continue
                    axes = ax if isinstance(ax, tuple) else (ax,)
                    total = int(np.prod([sizes[a] for a in axes]))
                    assert dim % total == 0, (arch, mode, path, dim, ax)
