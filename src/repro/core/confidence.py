"""Softmax confidence — Definitions 3.1–3.3 of the paper.

    out_m(x) = argmax_c softmax(z_m)[c]          (Def. 3.2)
    δ_m(x)   = max_c   softmax(z_m)[c]           (Def. 3.3)

Both are computed from logits without materializing the softmax vector:
δ = exp(max z − logsumexp z).  This identity is what the fused Pallas kernel
(kernels/confidence.py) streams over vocab tiles; this module is the reference
semantics used everywhere else.

``entropy_confidence`` is the BranchyNet [TMK16] baseline the paper compares
against (confidence = −entropy, higher = more confident), implemented for the
ablation benchmarks.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def softmax_outputs(logits: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(out, δ) per Defs. 3.2–3.3.  logits: (..., n_classes)."""
    x = logits.astype(jnp.float32)
    out = jnp.argmax(x, axis=-1)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    delta = jnp.exp(m - lse)
    return out, delta


def softmax_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """δ only (Def. 3.3)."""
    return softmax_outputs(logits)[1]


def entropy_confidence(logits: jnp.ndarray) -> jnp.ndarray:
    """BranchyNet-style confidence: −entropy(softmax(z)), shifted to (…,0].

    Higher is more confident; thresholds live on a different scale than δ,
    so calibration (§5) is rerun when this measure is selected.
    """
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    ent = -jnp.sum(p * jnp.log(jnp.clip(p, 1e-30, 1.0)), axis=-1)
    return -ent
