"""Roofline analysis over the dry-run records.

Terms (per step, per chip; TPU v5e):
    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_wire_bytes_per_chip / ICI_bw

Sources: unrolled dry-run records (scan bodies fully counted; validated
against hand counts).  ``cost_analysis`` is per-device on the partitioned
module.  MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference);
the MODEL/HLO ratio flags remat/redundancy waste (and, for decode, the
attention+exit-head compute that 6ND-style accounting does not include).

    PYTHONPATH=src python -m repro.launch.roofline --dir results/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link (~per chip aggregate assumption)


def load_records(d: str, suffix: str, ok_only: bool = False) -> Dict:
    out = {}
    for path in sorted(glob.glob(os.path.join(d, f"*__{suffix}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if ok_only and not rec.get("ok"):
            continue  # fall back to the scanned record instead
        out[(rec["arch"], rec["shape"])] = rec
    return out


def terms(rec: dict) -> Optional[dict]:
    if not rec.get("ok") or "flops" not in rec:
        return None
    compute = rec["flops"] / PEAK_FLOPS
    memory = rec["hlo_bytes"] / HBM_BW
    coll_bytes = sum(rec["collective_bytes"].values())
    collective = coll_bytes / ICI_BW
    dom = max(("compute", compute), ("memory", memory),
              ("collective", collective), key=lambda kv: kv[1])
    chips = 512 if rec.get("mesh") == "2x16x16" else 256
    useful = rec["model_flops"] / (rec["flops"] * chips) if rec["flops"] else 0
    return {
        "compute_s": compute, "memory_s": memory, "collective_s": collective,
        "bottleneck": dom[0], "step_s": dom[1],
        "model_flops": rec["model_flops"],
        "useful_ratio": useful,
        "coll_bytes": coll_bytes,
    }


def fmt(x: float) -> str:
    if x == 0:
        return "0"
    for unit, scale in (("s", 1), ("ms", 1e-3), ("us", 1e-6), ("ns", 1e-9)):
        if x >= scale:
            return f"{x / scale:.3g}{unit}"
    return f"{x:.2g}s"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--suffix", default="sp_unroll",
                    help="record suffix: sp | mp | sp_unroll")
    ap.add_argument("--fallback", default="sp",
                    help="suffix to fall back to when the primary is missing")
    ap.add_argument("--out", default="")
    args = ap.parse_args()
    recs = load_records(args.dir, args.suffix, ok_only=True)
    fall = load_records(args.dir, args.fallback) if args.fallback else {}
    keys = sorted(set(recs) | set(fall))
    lines = ["| arch | shape | compute | memory | collective | bottleneck "
             "| MODEL/HLO | note |",
             "|---|---|---|---|---|---|---|---|"]
    for key in keys:
        rec = recs.get(key) or fall.get(key)
        src = args.suffix if key in recs else f"{args.fallback}(fallback)"
        arch, shape = key
        if rec.get("skipped"):
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"skipped: {rec['skipped'][:60]}… |")
            continue
        t = terms(rec)
        if t is None:
            lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                         f"FAILED: {rec.get('error', '?')[:60]} |")
            continue
        lines.append(
            f"| {arch} | {shape} | {fmt(t['compute_s'])} | "
            f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
            f"**{t['bottleneck']}** | {t['useful_ratio']:.2f} | {src} |")
    table = "\n".join(lines)
    print(table)
    if args.out:
        with open(args.out, "w") as f:
            f.write(table + "\n")


if __name__ == "__main__":
    main()
