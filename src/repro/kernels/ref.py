"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``ref_*`` is the mathematically-plain implementation the kernels are
tested against with assert_allclose over shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_confidence(logits):
    """Fused softmax-confidence oracle.  logits: (B, V) ->
    (argmax (B,) int32, delta (B,) f32) per Defs. 3.2-3.3."""
    x = logits.astype(jnp.float32)
    idx = jnp.argmax(x, axis=-1).astype(jnp.int32)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    return idx, jnp.exp(m - lse)


def ref_rmsnorm(x, w, eps: float = 1e-5):
    """x: (R, d); w: (d,)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(
        x.dtype)


def ref_flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd).  GQA by head grouping."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    qpk = H // KV
    qh = q.reshape(B, KV, qpk, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qh, kf) / math.sqrt(hd)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, t, kpos, window: int = 0):
    """q: (B, H, hd); caches: (B, W, KV, hd); t scalar; kpos (W,)."""
    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    qpk = H // KV
    qh = q.reshape(B, KV, qpk, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bwkh->bkgw", qh, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    m = (kpos >= 0) & (kpos <= t)
    if window:
        m = m & (kpos > t - window)
    s = jnp.where(m[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p, v_cache.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
