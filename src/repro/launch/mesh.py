"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — smoke tests must keep seeing 1 CPU device; only
dryrun.py forces 512 host devices.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs (same axis names, sizes 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    """Axes the batch dimension shards over."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    out = 1
    for n in names:
        out *= mesh.shape[n]
    return out


def divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0
