"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

d_ff=0 per the card: xLSTM blocks carry their own internal up-projections
(mLSTM: pre-up-projection factor 2; sLSTM: post-up-projection factor 4/3),
so there is no separate FFN sublayer.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    slstm_every=6,             # xLSTM[7:1]-style mix: every 6th block is sLSTM
    source="arXiv:2405.04517",
))
