"""Automatic confidence-threshold calibration — §5 of the paper.

Given per-sample confidences δ_m(x) and correctness indicators for component
m over a calibration set T:

    T_m(δ)  = {(x,y) : δ_m(x) ≥ δ}
    α_m(δ)  = accuracy of M_m on T_m(δ)          (0 if T_m(δ) empty)
    α*_m    = max_δ α_m(δ)
    δ_m(ε)  = min { δ : α_m(δ) ≥ α*_m − ε }

The paper remarks the last component's threshold is 0, and that a validation
set (not the training set) should ideally set the thresholds — both supported
here.  Thresholds can be recomputed for any ε *without retraining* (Goal 1.2).

Implementation: sort by confidence descending; suffix-mean of correctness at
each distinct confidence gives α_m(δ) exactly at all breakpoints in O(N log N).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class CalibrationResult:
    thresholds: Tuple[float, ...]   # δ̂_m per component (last = 0)
    alpha_star: Tuple[float, ...]   # α*_m per component
    epsilon: float


def accuracy_vs_confidence(conf: np.ndarray, correct: np.ndarray):
    """Exact α_m(δ) curve.

    Returns (delta_grid, alpha) where delta_grid are the distinct confidence
    values in increasing order and alpha[i] = accuracy over samples with
    confidence >= delta_grid[i].
    """
    conf = np.asarray(conf, np.float64)
    correct = np.asarray(correct, np.float64)
    order = np.argsort(conf)                  # ascending
    c_sorted = conf[order]
    r_sorted = correct[order]
    # suffix sums: accuracy among samples with conf >= c_sorted[i]
    suffix_correct = np.cumsum(r_sorted[::-1])[::-1]
    n = len(conf)
    counts = n - np.arange(n)
    alpha_at_i = suffix_correct / counts
    # collapse to distinct confidence values (keep first occurrence = full set
    # of samples with that confidence or more)
    distinct_mask = np.ones(n, bool)
    distinct_mask[1:] = c_sorted[1:] != c_sorted[:-1]
    return c_sorted[distinct_mask], alpha_at_i[distinct_mask]


def threshold_for_epsilon(conf: np.ndarray, correct: np.ndarray,
                          epsilon: float,
                          target: float | None = None,
                          val_conf: np.ndarray | None = None,
                          val_correct: np.ndarray | None = None
                          ) -> Tuple[float, float]:
    """δ_m(ε) = min{δ : α_m(δ) ≥ target − ε} and α*_m, per §5.

    target defaults to the component's own α*_m (the paper's rule).  When
    the target is unreachable at any δ, returns threshold 1.1 (never exit).

    ``val_conf`` / ``val_correct`` realize the paper's remark that a
    validation set distinct from the statistics set should ideally pick
    the threshold: α*_m (and the default target) still come from
    ``(conf, correct)``, but the threshold is the smallest δ whose
    accuracy ON THE VALIDATION CURVE clears the goal — so the selection
    cannot overfit the same samples that set the bar."""
    grid, alpha = accuracy_vs_confidence(conf, correct)
    alpha_star = float(np.max(alpha))
    goal = (alpha_star if target is None else target) - epsilon
    if val_conf is not None:
        if val_correct is None:
            raise ValueError("val_conf given without val_correct")
        grid, alpha = accuracy_vs_confidence(val_conf, val_correct)
    ok = alpha >= goal
    if not ok.any():
        return 1.1, alpha_star
    idx = int(np.argmax(ok))                  # first (lowest δ) satisfying
    return float(grid[idx]), alpha_star


def calibrate_thresholds(confidences: Sequence[np.ndarray],
                         corrects: Sequence[np.ndarray],
                         epsilon: float,
                         relative_to: str = "self",
                         val_confidences: Sequence[np.ndarray] | None = None,
                         val_corrects: Sequence[np.ndarray] | None = None
                         ) -> CalibrationResult:
    """Per-component thresholds for accuracy budget ε.

    confidences[m], corrects[m]: arrays over the calibration set for component
    m.  The final component's threshold is forced to 0 (paper's remark (i)).

    ``relative_to`` is a calibrator registry spec (repro.core.policy):
      "self"    — the paper's §5 rule (SelfCalibrator).
      "final"   — beyond-paper cascade-level rule (FinalCalibrator).
      "holdout" — §5 with the threshold *selected* on a validation split
                  distinct from the statistics that set α*_m (the paper's
                  validation-set remark); splits internally unless
                  ``val_confidences`` / ``val_corrects`` are given.
    New rules register via ``@register_calibrator`` and become available here
    without touching this function.  Explicit ``val_confidences`` /
    ``val_corrects`` (per-component arrays like the calibration set) are
    honored by every calibrator.
    """
    from repro.core.policy import get_calibrator  # circular-import guard
    cal = get_calibrator(relative_to)
    if val_confidences is None and val_corrects is None:
        # registered third-party calibrators may predate the validation-
        # split kwargs; don't force the wider signature on them
        return cal.calibrate(confidences, corrects, epsilon)
    return cal.calibrate(confidences, corrects, epsilon,
                         val_confidences=val_confidences,
                         val_corrects=val_corrects)
