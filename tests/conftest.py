import os
import sys

# Keep smoke tests on 1 device (the dry-run, and ONLY the dry-run, forces 512).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_enable_x64", False)


@pytest.fixture(autouse=True)
def _reset_kernel_state():
    """Re-arm the one-shot interpret-on-TPU warning and restore default
    tiles between tests: a test that forces interpret mode or installs
    tuned tiles must not leak that state into every later test."""
    yield
    from repro.kernels.autotune import reset_tiles
    from repro.kernels.backend import reset_backend_warnings
    reset_backend_warnings()
    reset_tiles()

try:  # the image may lack hypothesis; fall back to the deterministic stub
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub
    _hypothesis_stub.strategies = _hypothesis_stub
