"""Observability for the cascade serving stack (DESIGN.md §10).

Three layers, all host-side and all assembled from data the jitted
programs already return at existing host-sync boundaries:

* :mod:`repro.obs.recorder` — the **flight recorder**: a structured span
  tree per request (submit → queue-wait → admit → prefill → per-chunk
  decode → exit | escalate | migrate → finalize) kept in a bounded ring,
  plus an engine-level event log (threshold pushes, drains) and bounded
  latency reservoirs.
* :mod:`repro.obs.metrics` — a small metrics registry (counters /
  gauges / quantile summaries) rendered as Prometheus text exposition
  or JSON; ``engine_metrics_into`` maps an engine's ``stats()`` +
  recorder onto it, ``parse_prometheus`` round-trips the text format.
* :mod:`repro.obs.traceviz` — Perfetto / Chrome trace-event JSON export
  (one track per lane/member, chunk-level slices, instant markers for
  threshold pushes and drains) plus a schema validator.

Nothing in here may touch a traced graph: recording adds ZERO new host
syncs and ZERO retraces, so streams are bit-identical recorder-on vs
off (``tests/test_obs.py``) and the overhead ratio is gated ≥ 0.97 in
``BENCH_serving.json["obs"]``.
"""
from repro.obs.metrics import (MetricsRegistry, engine_metrics_into,
                               parse_prometheus)
from repro.obs.recorder import EventLog, FlightRecorder, Span
from repro.obs.server import MetricsServer
from repro.obs.traceviz import (export_trace, trace_events,
                                validate_trace_events)

__all__ = [
    "EventLog",
    "FlightRecorder",
    "MetricsRegistry",
    "MetricsServer",
    "Span",
    "engine_metrics_into",
    "export_trace",
    "parse_prometheus",
    "trace_events",
    "validate_trace_events",
]
