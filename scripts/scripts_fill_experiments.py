"""Fill EXPERIMENTS.md placeholders from results/ artifacts."""
import glob
import json
import os

import numpy as np


def paper_table():
    path = "results/repro_c10.json"
    if not os.path.exists(path):
        return "_(full-scale run still in progress — see results/repro_c10.log)_"
    d = json.load(open(path))
    acc = d["component_acc"]
    lines = [
        f"Component accuracies (test): M0={acc[0]:.4f}  M01={acc[1]:.4f}  "
        f"M012={acc[2]:.4f}",
        "",
        "| ε | accuracy | speedup | exit fractions | thresholds δ̂(ε) |",
        "|---|---|---|---|---|",
    ]
    for row in d["sweep"]:
        lines.append(
            f"| {row['eps']:g} | {row['accuracy']:.4f} | {row['speedup']:.3f}"
            f" | {np.round(row['exit_fractions'], 3).tolist()}"
            f" | {np.round(row['thresholds'], 3).tolist()} |")
    lines.append("")
    lines.append(f"α_m(δ) linearity (Pearson r, test set): "
                 f"{[round(x, 4) for x in d['linearity']]}")
    return "\n".join(lines)


def dryrun_table():
    rows = {}
    for path in glob.glob("results/dryrun/*__sp.json") + \
            glob.glob("results/dryrun/*__mp.json"):
        r = json.load(open(path))
        key = (r["arch"], r["shape"])
        mesh = "mp" if path.endswith("__mp.json") else "sp"
        rows.setdefault(key, {})[mesh] = r
    lines = ["| arch | shape | 16×16 | 2×16×16 | compile sp/mp (s) |",
             "|---|---|---|---|---|"]
    n_ok = {"sp": 0, "mp": 0}
    for (arch, shape) in sorted(rows):
        cell = {}
        comp = {}
        for mesh in ("sp", "mp"):
            r = rows[(arch, shape)].get(mesh)
            if r is None:
                cell[mesh] = "—"
            elif r.get("skipped"):
                cell[mesh] = "SKIP"
                n_ok[mesh] += 1
            elif r.get("ok"):
                cell[mesh] = "OK"
                n_ok[mesh] += 1
                comp[mesh] = r.get("t_compile_s", "")
            else:
                cell[mesh] = "FAIL"
        lines.append(f"| {arch} | {shape} | {cell['sp']} | {cell['mp']} | "
                     f"{comp.get('sp', '—')}/{comp.get('mp', '—')} |")
    lines.append("")
    lines.append(f"Totals: {n_ok['sp']}/40 single-pod, {n_ok['mp']}/40 "
                 f"multi-pod (SKIP = the one documented long_500k carve-out).")
    return "\n".join(lines)


def roofline_table():
    import subprocess
    out = subprocess.run(
        ["python", "-m", "repro.launch.roofline", "--dir", "results/dryrun",
         "--suffix", "sp_unroll", "--fallback", "sp"],
        capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": "src"})
    return out.stdout.strip()


def main():
    src = open("EXPERIMENTS.md").read()
    src = src.replace("RESULT_PLACEHOLDER_PAPER", paper_table())
    src = src.replace("RESULT_PLACEHOLDER_DRYRUN", dryrun_table())
    src = src.replace("RESULT_PLACEHOLDER_ROOFLINE", roofline_table())
    open("EXPERIMENTS.md", "w").write(src)
    print("EXPERIMENTS.md filled")


if __name__ == "__main__":
    main()
