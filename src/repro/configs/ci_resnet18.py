"""CI-RESNET(n) — the paper's own architecture (Fig. 2c).

RESNET(n): 3x3 stem conv, then 3 ResNet modules of n blocks each (first block
of modules 2,3 subsamples with stride 2), BN+ReLU+skip per block, GAP +
FC(64 -> n_c) + softmax.  Module widths are (16, 32, 64) — the classic
[HZRS15a] profile.  Evidence: the paper's reported max speedup (×2.953 SVHN)
equals MAC(M_{0,1,2})/MAC(M_0) which is ≈2.96 only under this profile, and the
total (253M MACs at n=18) matches ResNet-110's canonical count.  The text's
"32 3x3x3 filters" stem is inconsistent with both; see models/resnet.py.

Cascade: classifier heads branch after modules 0 and 1 with the paper's
classifier enhancement; head 2 is the standard GAP+FC.
"""
from repro.configs.base import CascadeConfig, ModelConfig, register

# n (ResNet blocks per module); the paper's experiments use n=18 (CI-RESNET(18),
# 110 conv layers).  For CPU experiments we also provide n=3 via reduced().
N_BLOCKS = 18

CONFIG = register(ModelConfig(
    name="ci-resnet18",
    family="cnn",
    n_layers=3 * N_BLOCKS,      # resnet blocks across 3 modules
    d_model=64,                 # final feature width (FC input)
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab_size=100,             # n_c (CIFAR-100); overridden per dataset
    norm="layernorm",
    act="gelu",
    dtype="float32",
    cascade=CascadeConfig(
        n_components=3,
        exit_boundaries=(N_BLOCKS, 2 * N_BLOCKS),
        enhance_dim=128,        # the paper's classifier enhancement
        thresholds=(0.9, 0.9, 0.0),
    ),
    source="DOI 10.1007/978-3-030-30484-3_26",
))
