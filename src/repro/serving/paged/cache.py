"""Paged cascade KV cache: shared block stores + per-(component, slot)
block tables.

Dense layout keeps one worst-case ``(B, W)`` slab per lane; every slot owns
``W`` ring positions in every component's cache for its whole residency,
whether or not the cascade ever computes those components.  The paged
layout replaces the slab's attention k/v leaves with SHARED stores shaped
``(n_layers, num_blocks, block_size, kv_heads, head_dim)`` and addresses
them through per-slot block tables (one row per cascade component),
carried in :class:`repro.core.exec.DecodeState` as plain jit data:

::

    DecodeState.block_tables          (K components, B slots, W/bs)  int32
        |                                        .-------------------.
        | table[m, b, j] = physical block id --> | store[:, id]      |
        |   (0 = trash: slot b owns no block     |  (n, bs, kv, hd)  |
        |    for ring range j of component m)    '-------------------'

A slot's logical ``(W, kv, hd)`` ring view is the gather of its table row;
ring position ``p`` lives at ``(table[m, b, p // bs], p % bs)``.  Blocks
are fungible across lanes, slots and components — one
:class:`~repro.serving.paged.pool.BlockPool` free list serves the whole
engine, which is what lets memory freed by one lane's exits admit the next
request on any other lane.

Coherence is by masking, not zeroing: each slot carries its OWN ``kpos``
row (paged caches use a per-slot ``(B, W)`` position ring instead of the
dense lane-wide ``(W,)``), and ring positions a slot never wrote are
``-1``-masked out of its attention, so stale values in a reallocated
block are unreachable.  That is why freed blocks can be rebound with no
device traffic at all — the pool is pure host bookkeeping.

Dead slots keep writing one (masked, never-read) k/v row per decode step;
their table rows are repointed at the reserved trash block 0 on release so
those writes cannot corrupt a reallocated block.

Token/exit/confidence streams are bit-identical to the dense layout for
lanes admitted by whole-lane prefill (pinned by
``tests/test_paged_cache.py``): the gathered ring view holds exactly the
dense values at every kpos-valid position, and masked positions contribute
``-inf`` either way.  Continuous single-slot admission is the sanctioned
divergence — the whole point of the layout (see the engine docs).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.paged.pool import TRASH_BLOCK, BlockPool


def _stage_is_attn(stage_cache) -> bool:
    """A stage cache the paged layout can address: exactly {'k', 'v'} ring
    leaves of shape (n_layers, B, W, kv_heads, head_dim)."""
    if not isinstance(stage_cache, dict):
        return False
    if set(stage_cache.keys()) != {"k", "v"}:
        return False
    return all(np.ndim(v) == 5 for v in stage_cache.values())


class PagedCascadeCache:
    """Builds and books the paged layout for one serving engine.

    Owns the shared device stores (adopted back after every donated
    dispatch), the host block-table mirrors per lane, and the
    per-(lane, slot, component) allocation map the release accounting
    reads.  All methods are host-side; the only device work is rebuilding
    a lane's ``(K, B, nblk)`` table array when its rows change (a data
    swap — never a retrace).
    """

    def __init__(self, model, cfg, lane_batch: int, n_lanes: int,
                 cache_len: int):
        pc = cfg.paged_cache
        self.cfg = cfg
        self.lane_batch = lane_batch
        self.n_lanes = n_lanes
        self.W = model.cache_capacity(cache_len)
        self.block_size = pc.block_size
        if self.W % self.block_size:
            raise ValueError(
                f"paged_cache.block_size={pc.block_size} must divide the "
                f"cache capacity W={self.W} (cache_len={cache_len}, "
                f"attn_window={cfg.attn_window})")
        if cfg.n_experts:
            raise ValueError(
                "cache_layout='paged' does not support MoE configs: expert "
                "capacity couples batch rows, so a dead slot's trash-block "
                "garbage becomes observable in live rows and breaks the "
                "dense-ablation bit-identity contract")
        self.nblk = self.W // self.block_size
        self.K = cfg.cascade.n_components

        # shared stores mirror init_cache's (segments x stages) structure
        # with the (B, W) slab dims of every attention k/v leaf replaced by
        # (num_blocks, block_size); any other cache kind (ssm state, conv,
        # xlstm cells, ...) has no ring to page — reject rather than
        # silently keeping a dense slab next to the paged one
        template = jax.eval_shape(
            lambda: model.init_cache(lane_batch, cache_len))
        for si, stages in enumerate(template["segments"]):
            for stage in stages:
                if not _stage_is_attn(stage):
                    raise ValueError(
                        f"cache_layout='paged' needs every cache leaf to be "
                        f"an attention k/v ring; segment {si} of family "
                        f"{cfg.family!r} has a non-attention cache stage "
                        f"({list(stage) if isinstance(stage, dict) else type(stage).__name__}). "
                        f"Use cache_layout='dense' for this config.")

        dense_equiv = n_lanes * lane_batch * self.K * self.nblk
        num_blocks = pc.num_blocks or (dense_equiv + 1)
        if num_blocks < 2:
            raise ValueError(f"num_blocks must be >= 2, got {num_blocks}")

        bytes_per_block = 0   # across every segment's k+v planes (one block
        segs = []             # id implicitly occupies its planes everywhere)
        for stages in template["segments"]:
            built = []
            for stage in stages:
                leaf = stage["k"]          # (n, B, W, kv, hd)
                n, _B, _W, kv, hd = leaf.shape
                shape = (n, num_blocks, self.block_size, kv, hd)
                built.append({
                    "k": jnp.zeros(shape, leaf.dtype),
                    "v": jnp.zeros(shape, leaf.dtype),
                })
                bytes_per_block += (2 * n * self.block_size * kv * hd
                                    * leaf.dtype.itemsize)
            segs.append(built)
        self.segments = segs
        self.pool = BlockPool(num_blocks, self.block_size,
                              block_bytes=bytes_per_block)
        # the dense ablation's always-resident footprint, for stats/bench
        self.dense_slab_bytes = dense_equiv * bytes_per_block

        # host mirrors: per-lane (K, B, nblk) tables, all rows at trash
        self._tables = [np.zeros((self.K, lane_batch, self.nblk), np.int32)
                        for _ in range(n_lanes)]
        self._dev_tables: List[Optional[jnp.ndarray]] = [None] * n_lanes
        # (lane, slot) -> {segment: {ring_block_index: physical id}}
        self._allocs: Dict[Tuple[int, int], Dict[int, Dict[int, int]]] = {}

    # ------------------------------------------------------------------
    # coverage planning
    # ------------------------------------------------------------------
    def coverage(self, start: int, stop: int) -> List[int]:
        """Ring-block indices backing positions [start, stop) — clipped to
        the last W positions (earlier ones are overwritten by the ring
        before they could be read)."""
        lo = max(start, stop - self.W, 0)
        if lo >= stop:
            return []
        ps = np.arange(lo, stop)
        return sorted(set(((ps % self.W) // self.block_size).tolist()))

    def blocks_needed(self, start: int, stop: int) -> int:
        """Pool blocks a slot spanning positions [start, stop) claims, over
        all K components."""
        return len(self.coverage(start, stop)) * self.K

    def fits_ever(self, start: int, stop: int) -> bool:
        return self.blocks_needed(start, stop) <= self.pool.num_blocks - 1

    def can_admit(self, n_blocks: int) -> bool:
        return self.pool.can_alloc(n_blocks)

    # ------------------------------------------------------------------
    # slot lifecycle
    # ------------------------------------------------------------------
    def alloc_slot(self, lane: int, slot: int, start: int,
                   stop: int) -> bool:
        """Bind fresh blocks covering positions [start, stop) for every
        component of (lane, slot).  All-or-nothing: on pool exhaustion
        nothing is claimed and the caller backpressures admission."""
        assert (lane, slot) not in self._allocs, \
            f"slot ({lane}, {slot}) released twice-admitted"
        js = self.coverage(start, stop)
        ids = self.pool.alloc(len(js) * self.K)
        if ids is None:
            return False
        table = self._tables[lane]
        per_seg: Dict[int, Dict[int, int]] = {}
        it = iter(ids)
        for m in range(self.K):
            per_seg[m] = {j: next(it) for j in js}
            for j, b in per_seg[m].items():
                table[m, slot, j] = b
        self._allocs[(lane, slot)] = per_seg
        self._dev_tables[lane] = None
        return True

    def release_slot(self, lane: int, slot: int,
                     max_exit_depth: int = None):
        """Return (lane, slot)'s blocks to the pool at the first host sync
        after it finishes.  Components deeper than the slot's observed max
        exit depth count as ``reclaimed_by_exit`` (the cascade skipped
        them; their blocks only mirrored backfill state); the rest as
        ``reclaimed_at_retire``.  Table rows repoint at the trash block so
        the dead slot's masked writes stay harmless."""
        per_seg = self._allocs.pop((lane, slot), None)
        if per_seg is None:
            return
        if max_exit_depth is None:
            max_exit_depth = self.K - 1
        table = self._tables[lane]
        for m, blocks in per_seg.items():
            if blocks:
                self.pool.free(list(blocks.values()),
                               by_exit=m > max_exit_depth)
            for j in blocks:
                table[m, slot, j] = TRASH_BLOCK
        self._dev_tables[lane] = None

    def slot_blocks(self, lane: int, slot: int) -> int:
        per_seg = self._allocs.get((lane, slot))
        if not per_seg:
            return 0
        return sum(len(b) for b in per_seg.values())

    # ------------------------------------------------------------------
    # device views
    # ------------------------------------------------------------------
    def device_tables(self, lane: int) -> jnp.ndarray:
        if self._dev_tables[lane] is None:
            self._dev_tables[lane] = jnp.asarray(self._tables[lane])
        return self._dev_tables[lane]

    def lane_cache(self, kpos: jnp.ndarray) -> dict:
        """Compose a lane's cache pytree: its private per-slot kpos ring
        over the engine-shared stores."""
        return {"kpos": kpos, "segments": self.segments}

    def adopt(self, new_cache: dict) -> jnp.ndarray:
        """Take back the stores after a donated dispatch (the old buffers
        are gone); returns the lane's updated kpos for the caller to
        keep."""
        self.segments = new_cache["segments"]
        return new_cache["kpos"]

    def fresh_kpos(self) -> jnp.ndarray:
        return jnp.full((self.lane_batch, self.W), -1, jnp.int32)

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        out = self.pool.stats()
        out.update({
            "cache_layout": "paged",
            "nblk_per_slot": self.nblk,
            "dense_slab_bytes": self.dense_slab_bytes,
        })
        return out
