"""Cascaded Inference — Algorithm 1 of the paper, plus the vectorized
evaluation harness that produces the paper's accuracy/speedup tables.

Two execution styles:

* ``cascade_infer_sequential`` — Algorithm 1 verbatim for a single input:
  run components in order inside a ``lax.while_loop`` and stop as soon as
  δ_m ≥ δ̂_m.  This is the per-sample dynamic path (the paper's deployment
  model; on TPU it is the single-request serving path).

* ``cascade_evaluate`` — the measurement harness: given per-component
  (confidence, prediction) arrays over a dataset and the per-component MAC
  prefix costs, compute for a threshold vector the exit distribution,
  accuracy, average MACs and speedup.  The paper evaluates exactly this way
  (its MAC counts are analytic, §6.2); computing all components once and
  sweeping thresholds afterwards lets one ε-sweep reuse one forward pass.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence, Tuple

import numpy as np

from repro.core.policy import Calibrator, ExitDecider, get_calibrator


@dataclasses.dataclass
class CascadeEvalResult:
    accuracy: float
    avg_macs: float
    speedup: float              # vs always running the full cascade
    exit_fractions: np.ndarray  # fraction of samples answered by component m
    thresholds: Tuple[float, ...]


def cascade_infer_sequential(component_fns: Sequence[Callable],
                             thresholds: Sequence[float], x,
                             decider: Optional[ExitDecider] = None):
    """Algorithm 1 CI(M, δ̂, x) for a single input (batch allowed; the stop
    condition then requires *all* sequences confident — the batch-uniform
    TPU semantics).

    component_fns[m](x, state) -> (logits, state): state carries reused
    computation (the feature map so far), making components nested prefixes.
    The exit decision itself is delegated to the shared :class:`ExitDecider`
    (default: the paper's softmax_max measure under ThresholdPolicy).
    """
    decider = decider or ExitDecider("softmax_max")
    logits_list = []
    state = None
    # Python loop over components (n_m is small and static); early termination
    # is realized by the decider's masked selection so the graph stays
    # compilable.
    for fn in component_fns:
        logits, state = fn(x, state)
        logits_list.append(logits)
    decision = decider.decide(logits_list, thresholds=thresholds,
                              batch_uniform=True)
    return decision.prediction, decision.confidence


def cascade_evaluate(confidences: Sequence[np.ndarray],
                     predictions: Sequence[np.ndarray],
                     labels: np.ndarray,
                     mac_prefix: Sequence[float],
                     thresholds: Sequence[float],
                     decider: Optional[ExitDecider] = None
                     ) -> CascadeEvalResult:
    """Evaluate early-termination for one threshold vector.

    confidences[m], predictions[m]: (N,) arrays for component m over the
    evaluation set; mac_prefix[m]: cumulative MACs of running components
    0..m (nested cascade ⇒ prefix cost).  The last threshold is forced to 0
    (the final component always answers), matching Algorithm 1's accounting
    regardless of what the caller passes.
    """
    n_m = len(confidences)
    N = len(labels)
    thresholds = tuple(float(t) for t in thresholds[:-1]) + (0.0,)
    decider = decider or ExitDecider("softmax_max")
    exit_idx = decider.exit_indices(confidences, thresholds)
    preds = np.stack(predictions, axis=0)[exit_idx, np.arange(N)]
    acc = float(np.mean(preds == labels))
    macs = np.asarray(mac_prefix, np.float64)[exit_idx]
    avg = float(np.mean(macs))
    fractions = np.bincount(exit_idx, minlength=n_m) / N
    return CascadeEvalResult(
        accuracy=acc, avg_macs=avg,
        speedup=float(mac_prefix[-1] / avg),
        exit_fractions=fractions,
        thresholds=tuple(float(t) for t in thresholds))


def sweep_epsilons(confidences_cal, corrects_cal, confidences_test,
                   predictions_test, labels_test, mac_prefix,
                   epsilons: Sequence[float],
                   calibrator: "str | Calibrator" = "self"):
    """Full Figure-3 style sweep: calibrate δ̂(ε) on the calibration split,
    evaluate accuracy/MACs on the test split, one result per ε.

    ``calibrator`` is a registry spec ("self" = paper §5, "final" =
    cascade-level budget) or a Calibrator instance."""
    if isinstance(calibrator, str):
        calibrator = get_calibrator(calibrator)
    results = []
    for eps in epsilons:
        cal = calibrator.calibrate(confidences_cal, corrects_cal, eps)
        res = cascade_evaluate(confidences_test, predictions_test,
                               labels_test, mac_prefix, cal.thresholds)
        results.append((eps, cal, res))
    return results
