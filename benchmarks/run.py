"""Benchmark driver — one benchmark per paper table/figure plus the
beyond-paper LLM-cascade and kernel benches.

Prints ``name,us_per_call,derived`` CSV (and tees a copy to
results/bench.csv when results/ exists).
"""
import os
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_table2, bench_fig3, bench_fig4,
                            bench_llm_cascade, bench_kernels, bench_ablation)
    mods = [("table2", bench_table2), ("fig3", bench_fig3),
            ("fig4", bench_fig4), ("ablation", bench_ablation),
            ("llm_cascade", bench_llm_cascade), ("kernels", bench_kernels)]
    lines = ["name,us_per_call,derived"]
    failed = False
    for name, mod in mods:
        try:
            for row_name, us, derived in mod.run():
                lines.append(f"{row_name},{us:.1f},{derived}")
        except Exception as e:
            failed = True
            lines.append(f"{name}/ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc()
    out = "\n".join(lines)
    print(out)
    if os.path.isdir("results"):
        with open("results/bench.csv", "w") as f:
            f.write(out + "\n")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
