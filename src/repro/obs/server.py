"""Tiny stdlib HTTP endpoint for scrapes and flight dumps.

``MetricsServer`` serves whatever callables it was handed — it holds no
engine reference and no lock discipline of its own, because every
handler calls back into host-side snapshot methods (``scrape()`` builds
from a deep-copied ``stats()``; flight dumps serialize to plain dicts).
Routes:

* ``GET /metrics``       — Prometheus text exposition
* ``GET /metrics.json``  — the same registry as JSON
* ``GET /flights``       — completed flight ring (JSON list)
* ``GET /flights/<rid>`` — one flight's span tree (404 if evicted)
* ``GET /trace``         — Chrome trace-event JSON of the recording

Binds 127.0.0.1 only (this is a debug/scrape port, not a frontend);
``port=0`` picks a free port (exposed as ``.port``), which is what the
tests and the CI round-trip use.
"""
from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional


class MetricsServer:
    def __init__(self, port: int,
                 scrape_text: Callable[[], str],
                 scrape_json: Optional[Callable[[], dict]] = None,
                 flights: Optional[Callable[[], list]] = None,
                 flight: Optional[Callable[[int], Optional[dict]]] = None,
                 trace: Optional[Callable[[], list]] = None):
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a):        # no stderr chatter per scrape
                pass

            def _send(self, code, body, ctype):
                data = body.encode() if isinstance(body, str) else body
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):
                try:
                    path = self.path.split("?", 1)[0].rstrip("/") or "/"
                    if path == "/metrics":
                        self._send(200, scrape_text(),
                                   "text/plain; version=0.0.4")
                    elif path == "/metrics.json" and scrape_json:
                        self._send(200, json.dumps(scrape_json()),
                                   "application/json")
                    elif path == "/flights" and flights:
                        self._send(200, json.dumps(flights()),
                                   "application/json")
                    elif path.startswith("/flights/") and flight:
                        try:
                            rid = int(path.rsplit("/", 1)[1])
                        except ValueError:
                            self._send(400, "bad rid\n", "text/plain")
                            return
                        f = flight(rid)
                        if f is None:
                            self._send(404, "unknown rid\n", "text/plain")
                        else:
                            self._send(200, json.dumps(f),
                                       "application/json")
                    elif path == "/trace" and trace:
                        self._send(200, json.dumps(
                            {"traceEvents": trace(),
                             "displayTimeUnit": "ms"}),
                            "application/json")
                    else:
                        self._send(404, "unknown route\n", "text/plain")
                except Exception as e:          # scrape must never kill serve
                    self._send(500, f"scrape error: {e}\n", "text/plain")

        self._httpd = ThreadingHTTPServer(("127.0.0.1", port), Handler)
        self._httpd.daemon_threads = True
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    def close(self):
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
