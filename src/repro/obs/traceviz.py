"""Perfetto / Chrome trace-event JSON export for flight recordings.

``trace_events`` flattens one or more :class:`FlightRecorder`\\ s into
the Chrome trace-event format (the JSON Perfetto and ``chrome://tracing``
both load): one *process* per recorder (engine / fleet member), one
*thread* per lane plus a ``queue`` track, complete (``ph="X"``) slices
for prefills / decode chunks / per-request queue waits, and instant
(``ph="i"``) markers for threshold pushes, drains, migrations and
request terminals — so a fleet drain or an autotune push is visible on
the same timeline as the chunks it perturbed.

Timestamps: recorders stamp ``time.perf_counter`` seconds; the export
rebases everything to the earliest stamp and converts to integer-ish
microseconds (the unit the trace-event spec mandates).

``validate_trace_events`` is the schema check CI runs on the export.
"""
from __future__ import annotations

import json
from typing import List, Optional, Tuple

_QUEUE_TID = 0          # per-process track for queue_wait spans
_EVENT_TID = 999        # per-process track for instant markers

_SLICE_SPANS = ("prefill", "reprefill", "chunk")
_TERMINALS = ("exit", "escalate", "migrate", "cancelled")


def _named(recorders) -> List[Tuple[str, object]]:
    out = []
    for i, r in enumerate(recorders):
        if isinstance(r, tuple):
            out.append((str(r[0]), r[1]))
        else:
            out.append((getattr(r, "name", None) or f"engine{i}", r))
    return out


def trace_events(recorders, extra_events=None) -> List[dict]:
    """Flatten recorders (or ``(name, recorder)`` pairs) into a trace
    event list.  ``extra_events`` is an optional iterable of
    fleet-level :class:`~repro.obs.recorder.EventLog` snapshots to render
    as instants on a dedicated ``fleet`` process (pid 0); recorder
    processes start at pid 1."""
    named = _named(recorders)
    t_min = None
    for _, rec in named:
        for f in list(rec.done.values()) + list(rec.live.values()):
            if t_min is None or f.t_submit < t_min:
                t_min = f.t_submit
        for e in rec.events.snapshot():
            if t_min is None or e["t"] < t_min:
                t_min = e["t"]
    for e in (extra_events or []):
        if t_min is None or e["t"] < t_min:
            t_min = e["t"]
    if t_min is None:
        t_min = 0.0

    def us(t):
        return max(0.0, (t - t_min) * 1e6)

    evs: List[dict] = []

    def meta(pid, name):
        evs.append({"ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                    "args": {"name": name}})

    def thread_meta(pid, tid, tname):
        evs.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": tname}})

    if extra_events:
        meta(0, "fleet")
        for e in extra_events:
            evs.append({"ph": "i", "s": "g", "name": e["name"],
                        "pid": 0, "tid": _EVENT_TID, "ts": us(e["t"]),
                        "args": dict(e.get("attrs") or {})})

    for pidx, (name, rec) in enumerate(named):
        pid = pidx + 1
        meta(pid, name)
        thread_meta(pid, _QUEUE_TID, "queue")
        thread_meta(pid, _EVENT_TID, "events")
        seen_lanes = set()

        def lane_tid(lane):
            tid = 1 + int(lane)
            if tid not in seen_lanes:
                seen_lanes.add(tid)
                thread_meta(pid, tid, f"lane{int(lane)}")
            return tid

        for f in list(rec.done.values()) + list(rec.live.values()):
            for s in f.spans:
                if s.name == "queue_wait":
                    evs.append({
                        "ph": "X", "name": f"queue_wait rid={f.rid}",
                        "cat": "queue", "pid": pid, "tid": _QUEUE_TID,
                        "ts": us(s.t0), "dur": max(0.0, us(s.t1) - us(s.t0)),
                        "args": {"rid": f.rid, **s.attrs}})
                elif s.name in _SLICE_SPANS:
                    evs.append({
                        "ph": "X",
                        "name": f"{s.name} rid={f.rid}",
                        "cat": "decode", "pid": pid,
                        "tid": lane_tid(s.attrs.get("lane", 0)),
                        "ts": us(s.t0), "dur": max(0.0, us(s.t1) - us(s.t0)),
                        "args": {"rid": f.rid, **s.attrs}})
                elif s.name in _TERMINALS:
                    evs.append({
                        "ph": "i", "s": "t",
                        "name": f"{s.name} rid={f.rid}",
                        "cat": "terminal", "pid": pid,
                        "tid": lane_tid(f.attrs.get("lane") or 0),
                        "ts": us(s.t0),
                        "args": {"rid": f.rid, **s.attrs}})
        # engine-level events: lane_chunk / lane_prefill become per-lane
        # slices (the lane track shows utilization even for slots whose
        # flights were ring-evicted); everything else becomes an instant
        for e in rec.events.snapshot():
            at = e.get("attrs") or {}
            if e["name"] in ("lane_chunk", "lane_prefill"):
                evs.append({
                    "ph": "X", "name": e["name"], "cat": "lane",
                    "pid": pid, "tid": lane_tid(at.get("lane", 0)),
                    "ts": us(e["t"]),
                    "dur": max(0.0, float(at.get("seconds", 0.0)) * 1e6),
                    "args": at})
            else:
                evs.append({
                    "ph": "i", "s": "p", "name": e["name"],
                    "cat": "event", "pid": pid, "tid": _EVENT_TID,
                    "ts": us(e["t"]), "args": at})
    return evs


def export_trace(path: str, recorders, extra_events=None) -> dict:
    """Write ``{"traceEvents": [...]}`` (validated) and return it."""
    evs = trace_events(recorders, extra_events=extra_events)
    validate_trace_events(evs)
    doc = {"traceEvents": evs, "displayTimeUnit": "ms"}
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return doc


def validate_trace_events(events, require_names=()) -> None:
    """Chrome trace-event schema check (raises ValueError).

    Enforced per event: required keys by phase (``X``: ts+dur+pid+tid,
    ``i``: ts+pid+tid+scope in g/p/t, ``M``: metadata name + args),
    numeric non-negative timestamps/durations, and JSON
    serializability of args.  ``require_names`` additionally asserts
    that each named event (e.g. ``drain``, ``threshold_push``) appears
    at least once — CI uses it to pin that a fleet trace actually shows
    its drain/migration."""
    if not isinstance(events, list):
        raise ValueError("traceEvents must be a list")
    seen = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object")
        ph = e.get("ph")
        if ph not in ("X", "i", "M"):
            raise ValueError(f"event {i}: unsupported ph {ph!r}")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"event {i}: missing name")
        seen.add(e["name"])
        if ph == "M":
            if e["name"] not in ("process_name", "thread_name"):
                raise ValueError(f"event {i}: unknown metadata "
                                 f"{e['name']!r}")
            if "name" not in (e.get("args") or {}):
                raise ValueError(f"event {i}: metadata without args.name")
            continue
        for key in ("pid", "tid"):
            if not isinstance(e.get(key), int):
                raise ValueError(f"event {i}: {key} must be an int")
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if ph == "i" and e.get("s") not in ("g", "p", "t"):
            raise ValueError(f"event {i}: instant scope must be g/p/t")
        try:
            json.dumps(e.get("args", {}))
        except TypeError as err:
            raise ValueError(
                f"event {i}: args not JSON-serializable: {err}")
    missing = [n for n in require_names
               if not any(s == n or s.startswith(n + " ")
                          for s in seen)]
    if missing:
        raise ValueError(f"required trace events missing: {missing}")
