"""Configuration system for the cascaded-inference framework.

Every assigned architecture is expressed as a :class:`ModelConfig`.  Configs are
frozen dataclasses so they are hashable and can key jit caches.  Each arch file
in this package exports ``CONFIG`` (the full, paper-cited configuration) and a
``reduced()`` smoke variant (2 layers, d_model<=512, <=4 experts) used by the
CPU tests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class CascadeConfig:
    """Cascade (the paper's contribution) hyper-parameters.

    ``n_components`` is the paper's ``n_m``.  ``exit_boundaries`` are the layer
    indices *after which* an exit head branches (len == n_components - 1); the
    final component exits at the last layer implicitly.  ``enhance_dim``
    implements the paper's "classifier enhancement" (a widening projection in
    the intermediate heads; 0 disables).  ``thresholds`` is the live
    ``(δ̂_0 … δ̂_{n_m-1})`` vector — mutable at inference time *without
    retraining* (Goal 1.2); the last entry must be 0.
    """

    n_components: int = 3
    exit_boundaries: Tuple[int, ...] = ()
    enhance_dim: int = 0
    thresholds: Tuple[float, ...] = (0.9, 0.9, 0.0)
    # Strategy strings resolved through repro.core.policy's registries (kept
    # as strings so the config stays frozen/hashable and can key jit caches).
    # Measures: "softmax_max" | "entropy" | "margin" | "patience@k[:base]".
    confidence: str = "softmax_max"
    # Exit policies: "threshold" (Algorithm 1) | "budget@<avg-mac-target>"
    # (budget additionally needs a calibration-time policy.fit() with
    # held-out confidences before it can decide).
    policy: str = "threshold"
    # Threshold calibrators (§5): "self" (paper) | "final" (cascade-level).
    calibrator: str = "self"
    # How the staged executor (repro.core.exec) realizes the exit decision:
    #   "select"     — fixed graph: every segment computes, the skip
    #                  predicate selects results (dry-run/roofline shape);
    #   "cond_batch" — lax.cond per segment: once every live sequence has
    #                  exited, deeper segments' compute is skipped (only the
    #                  cheap cache backfill runs).
    # The two modes produce bit-identical tokens, exit indices and carried
    # DecodeState — exit_mode picks an execution strategy, never a semantics.
    exit_mode: str = "select"
    # Skip-predicate granularity for staged decode: the batch is split into
    # ``n_cohorts`` contiguous, equal-size cohorts, each with its OWN skip
    # predicate (nested lax.cond per cohort in cond_batch mode).  A segment's
    # compute is skipped for a cohort once every live sequence in THAT cohort
    # has exited, so mixed-difficulty batches realize more of the measured
    # skip opportunity than the whole-batch (n_cohorts=1) predicate.  Unlike
    # exit_mode this IS semantics: which rows get backfilled (vs computed)
    # cache entries depends on the cohort split, so compare runs at equal
    # n_cohorts.  Batches not divisible by n_cohorts degrade to the largest
    # divisor (1 in the worst case), mirroring the sharding rules.
    n_cohorts: int = 1
    # How cohort-split staged decode touches memory (perf only — the two
    # layouts are bit-identical; tested):
    #   "major" — cohort-major hot path: the batch axis of h / carry /
    #             cache is viewed as (cohort, B/C) (a zero-copy reshape —
    #             cohorts are contiguous batch ranges), the per-cohort
    #             split happens ONCE per step, and every deep segment
    #             dispatches on the lane's exit state (all-exited -> one
    #             whole-batch backfill; none-exited -> one whole-batch
    #             dense segment; mixed -> per-cohort lax.cond), so the
    #             slice/re-join machinery only runs when cohorts disagree.
    #   "copy"  — the legacy per-segment slice + concat path, kept as the
    #             ablation baseline for the layout benchmark.
    cohort_layout: str = "major"
    # Whether deeper-layer KV / recurrent state is backfilled from the exit
    # hidden state so later tokens can attend at full depth.
    state_backfill: bool = True
    # Share the final unembedding across exit heads (the LLM adaptation of the
    # paper's "negligible parameter addition": per-exit norm + low-rank
    # enhancement only; the vocab projection is shared).
    share_unembed: bool = True
    # Loss mode for train_step: "joint" (BranchyNet-style multi-loss baseline),
    # "backtrack" (the paper's Algorithm 2, phase-controlled), "last" (phase 0).
    loss_mode: str = "joint"
    # Per-exit loss weights in joint mode.
    joint_weights: Tuple[float, ...] = ()
    # Train intermediate exit heads on every k-th position only (§Perf H7):
    # the (B,S,vocab) intermediate logits dominate training HBM traffic for
    # large-vocab archs; the heads see plenty of signal at stride 4.
    exit_loss_stride: int = 1

    def __post_init__(self):
        if self.exit_mode not in ("select", "cond_batch"):
            raise ValueError(
                f"exit_mode must be 'select' or 'cond_batch', got "
                f"{self.exit_mode!r}")
        if self.n_cohorts < 1:
            raise ValueError(f"n_cohorts must be >= 1, got {self.n_cohorts}")
        if self.cohort_layout not in ("major", "copy"):
            raise ValueError(
                f"cohort_layout must be 'major' or 'copy', got "
                f"{self.cohort_layout!r}")


@dataclasses.dataclass(frozen=True)
class AutotuneConfig:
    """Online exit-telemetry + threshold-autotuning knobs (``repro.autotune``).

    With ``enabled``, every staged decode step accumulates a device-resident
    :class:`repro.autotune.telemetry.ExitTelemetry` pytree inside the carried
    ``DecodeState`` (per-component confidence histograms, exit counts, MAC
    counters, and a shadow-sampled joint histogram with a correctness proxy:
    does the exited prediction agree with the final component?).  The
    histograms are fixed-bin over the confidence range (0, 1]: ``bins``
    uniform bins, so a deployed threshold δ = e/bins corresponds exactly to
    the bin-edge gate ``bin >= e``.

    ``shadow_every`` picks the shadow full-depth sampling rate: every k-th
    decode step (by the lane's position cursor, so the schedule is
    deterministic and identical across host/device runtimes) OBSERVES the
    full depth — segments the skip predicate would drop compute their exit
    logits from a separate shadow hidden chain and record ALL components'
    confidences + agreement-with-final into the telemetry rider only,
    while the committed caches, decisions and patience streaks keep exact
    skip semantics.  Token streams are bit-identical with telemetry on or
    off (pinned by tests); the cost is ~1/k extra segment compute and the
    ``segments_run`` counters counting the observations.

    The remaining fields parameterize the :class:`ThresholdController`:
    ``resolve_every`` engine ticks between threshold resolutions,
    ``min_shadow`` shadow observations before the first solve, ``hysteresis``
    (minimum max-threshold movement worth pushing), and ``drift_tol``
    (L1 distance between consecutive windows' normalized joint SHADOW
    histograms — full-depth, threshold-independent evidence — beyond which
    the pre-drift accumulated history is excluded from this and all future
    resolves).
    ``epsilon`` / ``mac_budget`` pick the solve direction: a target accuracy
    degradation ε (paper §5, generalized to a joint search) or a target
    average-MAC budget (``mac_budget > 0`` wins when both are set).
    """

    enabled: bool = False
    bins: int = 32
    shadow_every: int = 16
    resolve_every: int = 64
    min_shadow: int = 256
    hysteresis: float = 0.02
    drift_tol: float = 0.25
    epsilon: float = 0.05
    mac_budget: float = 0.0
    # Add the FINAL component's confidence as an extra routing axis of the
    # shadow joint histogram.  Within one model the final component always
    # answers and its confidence never routes; in a cross-model escalation
    # tier (``repro.escalate``) answering at the final component is itself
    # a routed decision — defer to the next stage when its confidence is
    # below the escalation threshold — so the tier's joint solve needs the
    # final axis observed.  Costs bins× cells; leave False outside a tier.
    route_final: bool = False

    def __post_init__(self):
        if self.bins < 2:
            raise ValueError(f"autotune.bins must be >= 2, got {self.bins}")
        if self.shadow_every < 1:
            raise ValueError(
                f"autotune.shadow_every must be >= 1, got {self.shadow_every}")
        if self.resolve_every < 1:
            raise ValueError(
                f"autotune.resolve_every must be >= 1, got "
                f"{self.resolve_every}")


@dataclasses.dataclass(frozen=True)
class PagedCacheConfig:
    """KV-cache layout knobs for the serving engine (``repro.serving.paged``).

    ``layout="dense"`` keeps the per-lane worst-case ``(B, cache_len)`` slab
    (the bit-identity ablation baseline).  ``layout="paged"`` replaces the
    slab's attention k/v leaves with shared block stores addressed through
    per-(component, slot) block tables carried in ``DecodeState``: blocks are
    allocated lazily as the ring cursor reaches them and return to the
    :class:`repro.serving.paged.BlockPool` the moment a slot finishes — for
    skipped deep components first — instead of at whole-lane re-prefill.

    ``block_size`` is the number of ring positions per block and must divide
    the engine's ``cache_len``.  ``num_blocks`` sizes the shared pool
    (``0`` = auto: the dense-equivalent block count plus the reserved trash
    block, i.e. the same bytes as the dense slabs).  Token/exit/confidence
    streams are bit-identical between the two layouts (pinned by
    ``tests/test_paged_cache.py``); layout is an execution strategy, never a
    semantics.
    """

    layout: str = "dense"
    block_size: int = 16
    num_blocks: int = 0

    def __post_init__(self):
        if self.layout not in ("dense", "paged"):
            raise ValueError(
                f"cache layout must be 'dense' or 'paged', got "
                f"{self.layout!r}")
        if self.block_size < 1:
            raise ValueError(
                f"paged_cache.block_size must be >= 1, got {self.block_size}")
        if self.num_blocks < 0:
            raise ValueError(
                f"paged_cache.num_blocks must be >= 0 (0 = auto), got "
                f"{self.num_blocks}")


@dataclasses.dataclass(frozen=True)
class EscalationConfig:
    """Cross-model escalation knobs for one stage of a
    :class:`repro.escalate.ModelCascadeTier`.

    The tier fronts an ordered pool of serving engines (small drafts,
    large verifies).  A request decodes on its current stage; every token
    that the intra-model cascade answers at the stage's FINAL component is
    additionally gated by ``threshold`` — an IDK-style answer-or-defer
    decision (Wang et al., 2017): when the final component's confidence is
    below it, the request is cancelled at that token and re-submitted to
    the next stage, replaying the already-committed prefix as prefill.

    ``threshold`` uses the engine's confidence conventions: 0.0 never
    defers (every final-component answer stands — the escalate-never
    parity corner), the sentinel 1.1 always defers.  ``confidence`` names
    the :class:`repro.core.policy.ConfidenceMeasure` registry entry the
    defer decision reads; it must match the stage's own
    ``cascade.confidence`` measure (the deferral reuses the confidence the
    decision scan already computed for the answering token — a different
    measure would need the logits, which the serving engine does not
    retain), or be left "" to inherit it.  ``share_prefix`` gates prefix
    replay into the next stage: ``None`` auto-detects (same vocab_size and
    family ⇒ the committed tokens are valid next-stage input), ``False``
    forces full regeneration from the original prompt.
    """

    enabled: bool = False
    threshold: float = 0.0
    confidence: str = ""
    share_prefix: Optional[bool] = None

    def __post_init__(self):
        if self.threshold < 0.0:
            raise ValueError(
                f"escalation.threshold must be >= 0, got {self.threshold}")


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    """Cross-engine fleet knobs (``repro.fleet``).

    A :class:`repro.fleet.FleetScheduler` fronts ``n_engines`` serving
    engines (or escalation tiers) and places each incoming request by a
    weighted score over three signals: the distance between the member's
    observed exit-depth EMA and the request's predicted depth
    (``depth_weight`` — the same DepthCompactor prior the engines use for
    lane assignment, lifted one level up), the member's occupancy
    (``load_weight`` — live slots plus queued requests over capacity),
    and, for paged members, block-pool pressure (``block_weight`` — the
    used fraction of the shared KV pool).  Weights are relative; zeroing
    one disables that signal.

    Health tracking probes each member's ``stats()`` every
    ``heartbeat_every`` scheduler ticks.  A failed probe backs off
    exponentially (``backoff_base ** consecutive_failures`` ticks,
    bounded by ``backoff_cap``) before re-probing; ``max_failures``
    consecutive failures mark the member unhealthy — excluded from
    placement, stepping and telemetry until a later probe succeeds.

    ``drain_mode`` picks the default :meth:`~repro.fleet.FleetScheduler.
    drain` semantics: ``"finish"`` lets in-flight slots run to exit or
    budget on the draining member while its queued requests requeue to
    siblings; ``"migrate"`` additionally cancels in-flight slots and
    replays their committed prefixes into siblings (PR 7's replay path —
    zero committed tokens lost between prefix-compatible members).
    """

    n_engines: int = 1
    depth_weight: float = 1.0
    load_weight: float = 1.0
    block_weight: float = 0.5
    heartbeat_every: int = 4
    max_failures: int = 3
    backoff_base: int = 2
    backoff_cap: int = 64
    drain_mode: str = "finish"

    def __post_init__(self):
        if self.n_engines < 1:
            raise ValueError(
                f"fleet.n_engines must be >= 1, got {self.n_engines}")
        for knob in ("depth_weight", "load_weight", "block_weight"):
            if getattr(self, knob) < 0.0:
                raise ValueError(
                    f"fleet.{knob} must be >= 0, got {getattr(self, knob)}")
        if self.heartbeat_every < 1:
            raise ValueError(
                f"fleet.heartbeat_every must be >= 1, got "
                f"{self.heartbeat_every}")
        if self.max_failures < 1:
            raise ValueError(
                f"fleet.max_failures must be >= 1, got {self.max_failures}")
        if self.backoff_base < 1:
            raise ValueError(
                f"fleet.backoff_base must be >= 1, got {self.backoff_base}")
        if self.backoff_cap < 1:
            raise ValueError(
                f"fleet.backoff_cap must be >= 1, got {self.backoff_cap}")
        if self.drain_mode not in ("finish", "migrate"):
            raise ValueError(
                f"fleet.drain_mode must be 'finish' or 'migrate', got "
                f"{self.drain_mode!r}")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability knobs (``repro.obs``): the cascade flight recorder.

    With ``enabled``, the serving engine assembles a structured span tree
    per request — submit → queue-wait → admit(lane, cohort, predicted
    depth) → prefill → per-chunk decode (tokens, exit components,
    confidence at exit) → exit | escalate | migrate → finalize — entirely
    host-side, from data the jitted programs already return at existing
    host-sync boundaries plus ``perf_counter`` stamps around them.  The
    device programs gain ZERO new host syncs and ZERO retraces: recording
    never touches a traced graph, so token/exit/confidence streams are
    bit-identical recorder-on vs recorder-off (pinned by
    ``tests/test_obs.py`` and gated ≥ 0.97 throughput ratio in
    ``BENCH_serving.json["obs"]``).

    ``max_flights`` bounds the ring buffer of COMPLETED flight records
    (live flights are bounded by slot capacity); the oldest record is
    evicted when the ring is full, so a long-running engine's postmortem
    memory stays O(max_flights).  ``max_events`` bounds the engine-level
    event log (threshold pushes, drains, chunk slices for the Perfetto
    timeline).  ``reservoir`` bounds the per-metric latency reservoirs
    the p50/p95/p99 summaries are computed from (newest-wins).
    """

    enabled: bool = False
    max_flights: int = 64
    max_events: int = 1024
    reservoir: int = 1024

    def __post_init__(self):
        if self.max_flights < 1:
            raise ValueError(
                f"obs.max_flights must be >= 1, got {self.max_flights}")
        if self.max_events < 1:
            raise ValueError(
                f"obs.max_events must be >= 1, got {self.max_events}")
        if self.reservoir < 1:
            raise ValueError(
                f"obs.reservoir must be >= 1, got {self.reservoir}")


@dataclasses.dataclass(frozen=True)
class KernelTuneConfig:
    """Pallas kernel tile autotuning + fusion knobs (``repro.kernels``).

    ``enabled`` sweeps each kernel's candidate tile shapes on
    representative shapes at engine build time (or loads a previously
    swept artifact — :mod:`repro.kernels.autotune`) and installs the
    winners into the process-wide tile registry every ``kernels/ops.py``
    wrapper consults.  Tile shapes are *static* kernel parameters, so an
    install that changes a tile triggers exactly one recompile of that
    kernel's inner jit at install time; installs that resolve to the same
    tiles are cache hits (no retrace — the serving loop's
    ``_cache_size() == 1`` contract holds because installation happens
    before the decode loop traces).

    ``artifact_dir`` persists the sweep result keyed by a config hash
    over (artifact version, platform, execution backend, sweep preset):
    a matching artifact skips the sweep entirely; a mismatched hash falls
    back to the defaults with a warning (never silently reuses stale
    tiles).  ``shapes`` picks the sweep preset (``"tiny"`` = CI-sized
    shapes, ``"serving"`` = the serving-bench shapes).

    ``megakernel`` routes the decode scan's exit-head evaluation through
    the fused per-segment megakernel (:mod:`repro.kernels.megakernel`):
    rmsnorm + shared-unembed matmul + softmax confidence + exit-update
    carry merge in ONE streaming pass over vocab tiles — the (B, V)
    logits never reach HBM.  Heads outside the fusion boundary
    (layernorm bias, enhancement MLP) transparently fall back to the
    unfused path.  ``cohort_scatter`` replaces the mixed-exit cohort
    re-join (per-cohort slice + ``concatenate``) with the aliased Pallas
    scatter kernel (:mod:`repro.kernels.cohort_cache`) that writes each
    cohort's cache rows in place.  Both default off: decode streams are
    pinned bit-identical either way, but flipping them changes the
    traced graph.
    """

    enabled: bool = False
    artifact_dir: Optional[str] = None
    shapes: str = "tiny"
    megakernel: bool = False
    cohort_scatter: bool = False

    def __post_init__(self):
        if self.shapes not in ("tiny", "serving"):
            raise ValueError(
                f"kernel_tune.shapes must be 'tiny' or 'serving', got "
                f"{self.shapes!r}")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Units follow each model card exactly."""

    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    source: str = ""   # paper / model-card citation

    # --- attention ---
    attn_window: int = 0          # 0 = full attention; >0 = sliding window
    # chunked-attention tile sizes (§Perf H8): KV is re-read once per query
    # chunk, so total attention HBM traffic ∝ S/attn_qchunk
    attn_qchunk: int = 512
    attn_kchunk: int = 1024
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    max_seq_len: int = 131072

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # --- xLSTM ---
    slstm_every: int = 0          # every k-th layer is sLSTM (0 = none)

    # --- hybrid (zamba2-style shared attention) ---
    shared_attn_every: int = 0    # a shared attention block every k SSM layers

    # --- VLM ---
    cross_attn_every: int = 0     # every k-th layer has cross-attention
    n_image_tokens: int = 0

    # --- audio (enc-dec) ---
    encoder_layers: int = 0
    n_audio_frames: int = 0       # encoder output frames (stub frontend)

    # --- numerics / misc ---
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    act: str = "swiglu"           # swiglu | gelu
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    use_kernels: bool = False     # route hot ops through Pallas kernels
    # Pallas execution backend override for this config's kernels: None =
    # auto (interpret only off-TPU; REPRO_KERNEL_INTERPRET env var wins),
    # True/False force the interpreter / compiled path.  See
    # repro.kernels.backend.resolve_interpret for the precedence order.
    kernel_interpret: Optional[bool] = None
    remat: bool = True            # activation-checkpoint each block in training
    # remat policy: "full" recomputes everything in backward (min memory,
    # max recompute bytes); "dots" saves matmul outputs and recomputes only
    # elementwise ops (§Perf H6 — trades temp memory for HBM traffic).
    remat_policy: str = "full"
    # Fully unroll the layer scans.  HLO size grows O(L) but XLA cost
    # analysis then counts every layer (scan bodies are otherwise counted
    # once) — used by the dry-run to extract exact roofline terms.
    scan_unroll: bool = False

    cascade: CascadeConfig = dataclasses.field(default_factory=CascadeConfig)
    autotune: AutotuneConfig = dataclasses.field(
        default_factory=AutotuneConfig)
    paged_cache: PagedCacheConfig = dataclasses.field(
        default_factory=PagedCacheConfig)
    escalation: EscalationConfig = dataclasses.field(
        default_factory=EscalationConfig)
    fleet: FleetConfig = dataclasses.field(default_factory=FleetConfig)
    kernel_tune: KernelTuneConfig = dataclasses.field(
        default_factory=KernelTuneConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def segments(self) -> Tuple[Tuple[int, int], ...]:
        """(start, end) layer ranges of the n_components backbone segments."""
        bounds = self.cascade.exit_boundaries or default_exit_boundaries(
            self.n_layers, self.cascade.n_components)
        out, prev = [], 0
        for b in bounds:
            out.append((prev, b))
            prev = b
        out.append((prev, self.n_layers))
        return tuple(out)

    def with_cascade(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, cascade=dataclasses.replace(self.cascade, **kw))

    def with_autotune(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, autotune=dataclasses.replace(self.autotune, **kw))

    def with_paged_cache(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, paged_cache=dataclasses.replace(self.paged_cache, **kw))

    def with_escalation(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, escalation=dataclasses.replace(self.escalation, **kw))

    def with_fleet(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, fleet=dataclasses.replace(self.fleet, **kw))

    def with_kernel_tune(self, **kw) -> "ModelConfig":
        return dataclasses.replace(
            self, kernel_tune=dataclasses.replace(self.kernel_tune, **kw))

    def with_obs(self, **kw) -> "ModelConfig":
        if not kw:
            kw = {"enabled": True}
        return dataclasses.replace(
            self, obs=dataclasses.replace(self.obs, **kw))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def default_exit_boundaries(n_layers: int, n_components: int) -> Tuple[int, ...]:
    """Split ``n_layers`` into ``n_components`` near-equal segments.

    Returns the n_components-1 interior boundaries.  Exits branch *after*
    these layer indices.
    """
    if n_components < 2:
        return ()
    step = n_layers / n_components
    return tuple(max(1, round(step * (i + 1))) for i in range(n_components - 1))


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Smoke-test variant of a config: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    n_heads = min(cfg.n_heads, 4)
    n_kv = max(1, min(cfg.n_kv_heads, n_heads))
    # keep the GQA ratio if possible
    if cfg.n_kv_heads < cfg.n_heads:
        n_kv = max(1, n_heads // max(1, cfg.q_per_kv))
    kw = dict(
        n_layers=2,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=0,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        max_seq_len=512,
        n_experts=min(cfg.n_experts, 4) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_head_dim=32,
        ssm_chunk=32,
        n_image_tokens=min(cfg.n_image_tokens, 16) if cfg.n_image_tokens else 0,
        encoder_layers=min(cfg.encoder_layers, 2) if cfg.encoder_layers else 0,
        n_audio_frames=min(cfg.n_audio_frames, 30) if cfg.n_audio_frames else 0,
        cross_attn_every=2 if cfg.cross_attn_every else 0,
        shared_attn_every=2 if cfg.shared_attn_every else 0,
        slstm_every=2 if cfg.slstm_every else 0,
        attn_window=min(cfg.attn_window, 128) if cfg.attn_window else 0,
        dtype="float32",
        cascade=dataclasses.replace(cfg.cascade, exit_boundaries=(1,),
                                    n_components=2,
                                    thresholds=(0.9, 0.0)),
    )
    kw.update(overrides)
    return dataclasses.replace(cfg, **kw)


_REGISTRY = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    """Look up a registered architecture by ``--arch`` id."""
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs():
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all():
    # import for registration side effect
    from repro.configs import (  # noqa: F401
        zamba2_1p2b, mixtral_8x7b, qwen3_moe_235b_a22b, minitron_4b,
        xlstm_350m, deepseek_coder_33b, yi_9b, whisper_tiny,
        llama_3p2_vision_90b, qwen2p5_3b, ci_resnet18)
