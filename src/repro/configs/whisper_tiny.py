"""whisper-tiny — encoder-decoder transformer backbone [arXiv:2212.04356].

The mel-spectrogram + conv frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings of shape (batch, n_audio_frames, d_model)
delivered by ``input_specs``.  The cascade runs on the decoder.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                # decoder layers
    encoder_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    n_audio_frames=1500,
    norm="layernorm",
    act="gelu",
    rope_theta=0.0,            # learned absolute positions, no RoPE
    max_seq_len=448,
    source="arXiv:2212.04356",
))
