"""Quickstart: build a cascade model, run a forward pass, decode with
confidence-thresholded early exit, and change thresholds on the fly
(Goal 1.2 — no retraining).

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2.5-3b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.confidence import softmax_outputs
from repro.models.model import build_model, extra_input_shapes
from repro.serving.engine import select_exit


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))          # smoke-scale variant
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"segments={cfg.segments}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    extra = {k: jnp.zeros(s, jnp.float32)
             for k, s in extra_input_shapes(cfg, 2).items()} or None

    # 1) full-sequence forward: one logits tensor per cascade exit
    logits, aux = model.forward_train(params, toks, extra)
    for m, lg in enumerate(logits):
        _, conf = softmax_outputs(lg[:, -1])
        print(f"exit {m}: logits {lg.shape}, last-pos confidence "
              f"{np.round(np.asarray(conf), 3)}")

    # 2) prefill + a few decode steps with early exit
    cache = model.init_cache(2, 32)
    exit_logits, cache = model.prefill(params, toks, cache, extra)
    t = toks.shape[1]
    for thresholds in [(0.9, 0.0), (0.0, 0.0)]:   # on-the-fly change
        tok, exit_idx, conf = select_exit(exit_logits, thresholds)
        print(f"thresholds={thresholds}: next tokens "
              f"{np.asarray(tok)}, exits {np.asarray(exit_idx)}")
    step_logits, cache = model.decode_step(params, tok[:, None], t, cache,
                                           extra)
    tok2, exits2, _ = select_exit(step_logits, (0.5, 0.0))
    print(f"decode step at t={t}: tokens {np.asarray(tok2)}, "
          f"exits {np.asarray(exits2)}")


if __name__ == "__main__":
    main()
