"""Kernel execution-backend policy: when do Pallas kernels run interpreted?

The kernels were seeded with ``interpret=True`` hard defaults (this repo's CI
is CPU-only), which meant a real TPU deployment that forgot to flip an env
var silently ran every kernel through the Pallas *interpreter* — orders of
magnitude slower than the compiled path, with no error to notice.  This
module makes the default backend-aware and keeps exactly one precedence
order for overrides:

1. an explicit non-None override — either an ``interpret=`` argument at a
   kernel call site (tests pin interpreter semantics this way) or
   ``ModelConfig.kernel_interpret`` threaded through ``kernels/ops.py`` by
   the model layer (both arrive here as ``override``),
2. the ``REPRO_KERNEL_INTERPRET`` environment variable ("0" forces
   compiled, anything else forces interpreted) — consulted only when no
   explicit override was given,
3. auto-detection: interpret only off-TPU (CPU/GPU hosts run the
   interpreter because Mosaic lowering needs a TPU; a TPU backend runs
   compiled).

Forcing the interpreter ON a TPU backend is almost always a mistake, so that
combination logs a one-time warning instead of staying silent.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.utils import get_logger

log = get_logger("kernels.backend")

_ENV = "REPRO_KERNEL_INTERPRET"
_warned_interpret_on_tpu = False


def reset_backend_warnings() -> None:
    """Re-arm the one-time interpret-on-TPU warning.

    The warning latch is a module global, so a test that legitimately
    forces interpret mode on a TPU backend would otherwise silence the
    warning for every later test in the process.  ``tests/conftest.py``
    calls this between tests; production code never needs to.
    """
    global _warned_interpret_on_tpu
    _warned_interpret_on_tpu = False


def on_tpu() -> bool:
    """True when the default jax backend is a TPU."""
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:  # pragma: no cover - backend init failure
        return False


def resolve_interpret(override: Optional[bool] = None) -> bool:
    """The ``interpret=`` value a Pallas kernel should actually use.

    ``override`` is a call-site / config override (``None`` = no opinion).
    Precedence: explicit override > ``REPRO_KERNEL_INTERPRET`` env var >
    backend auto-detection (interpret iff not on TPU).
    """
    global _warned_interpret_on_tpu
    if override is None and _ENV in os.environ:
        override = os.environ[_ENV] != "0"
    if override is None:
        return not on_tpu()
    override = bool(override)
    if override and on_tpu() and not _warned_interpret_on_tpu:
        _warned_interpret_on_tpu = True
        log.warning(
            "Pallas kernels forced to interpret mode ON a TPU backend "
            "(override/%s) — this runs the interpreter, not Mosaic; "
            "expect orders-of-magnitude slowdown", _ENV)
    return override
