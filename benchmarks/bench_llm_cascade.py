"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures, per threshold / measure, BOTH of:
  (i)  the paper's analytic MAC speedup (§6.2), and
  (ii) measured decode wall-clock per token under ``select`` (fixed graph)
       vs ``cond_batch`` (lax.cond skips exited segments' compute) — the
       ``wallclock_speedup`` column is real elapsed time, with jit warm-up
       excluded via a first request wave + ``engine.reset_metrics()``.

Also reports the realized ``cond_batch`` skip rate (segments that actually
did not execute) next to the scheduling opportunity rate.  All exit
decisions route through the one ExitDecider resolved from the config's
registry strings; per-lane decode state (patience streaks included) rides
in the carried DecodeState.
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request


def _drive(cfg, model, params, n_req=6, max_new=8):
    """Run a warm-up wave, reset metrics, run the measured wave."""
    rng = np.random.default_rng(0)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=2, cache_len=48)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2 * n_req)]
    for i in range(n_req):                       # wave 1: jit warm-up
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    eng.reset_metrics()
    for i in range(n_req, 2 * n_req):            # wave 2: measured
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    return eng.stats()


def run(quick: bool = False):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    n_req = 2 if quick else 6
    ths_grid = (0.0, 0.5) if quick else (0.0, 0.5, 1.1)
    for th in ths_grid:
        per_mode = {}
        for mode in ("select", "cond_batch"):
            c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode=mode)
            st = _drive(c, model, params, n_req=n_req)
            per_mode[mode] = st
            rows.append((f"llm_cascade/th={th:g}/{mode}",
                         st["wallclock_us_per_token"] or 0.0,
                         f"analytic={st['analytic_speedup']:.3f};"
                         f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                         f"opportunity={st['skip_opportunity_rate']:.3f}"))
        sel, cb = (per_mode["select"]["wallclock_us_per_token"],
                   per_mode["cond_batch"]["wallclock_us_per_token"])
        wc = (sel / cb) if (sel and cb) else 1.0
        rows.append((f"llm_cascade/th={th:g}/wallclock_speedup", 0.0,
                     f"{wc:.3f}"))
    # alternative measures through the same registry-resolved engine path —
    # patience@2 carries its streaks in the lane DecodeState and still skips
    measures = ("patience@2",) if quick else ("entropy", "patience@2")
    for measure in measures:
        c = cfg.with_cascade(thresholds=(0.5, 0.0), exit_mode="cond_batch",
                             confidence=measure)
        st = _drive(c, model, params, n_req=n_req)
        rows.append((f"llm_cascade/measure={measure}",
                     st["wallclock_us_per_token"] or 0.0,
                     f"analytic={st['analytic_speedup']:.3f};"
                     f"skip_rate={st['cond_batch_skip_rate']:.3f}"))
    return rows
