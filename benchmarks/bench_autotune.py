"""Autotune benchmark: solver quality and telemetry overhead.

Two measurements, persisted to ``BENCH_serving.json`` (under ``autotune``)
by ``benchmarks/run.py`` and gated by ``scripts/check_bench_serving.py``:

* **solver vs shared quantile** — on a heterogeneous synthetic cascade
  population (an informative early component that beats the final model at
  high confidence, a noise-confidence middle component), fit thresholds
  for >= 3 average-MAC budgets two ways: the legacy shared exit quantile
  (``budget@<macs>:shared``) and the ``repro.autotune`` coordinate-descent
  solver seeded with it.  Both are evaluated on a held-out split at their
  REALIZED MACs; the gate requires the solver strictly more accurate at
  <= the shared fit's MACs on every budget.

* **telemetry overhead** — the serving engine (device runtime, cond_batch,
  kernels on) decodes identical traffic with ``cfg.autotune.enabled`` on
  vs off, measured in interleaved waves like the llm_cascade ablation.
  The gate requires tokens/s with telemetry within 3%, and the device
  loop's host-sync discipline unchanged: exactly ONE ``jax.device_get``
  per decode chunk, telemetry on or off (counted, not assumed).
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core.policy import get_policy
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

BINS = 64
BUDGETS = (1.5, 2.0, 2.5)          # avg-MAC targets on mac_prefix (1, 2, 3)
MAC_PREFIX = (1.0, 2.0, 3.0)
N_CAL = 60000
LANE_BATCH = 2
CHUNK = 8

# set by run(): machine-readable summary merged into BENCH_serving.json
LAST_AUTOTUNE_SUMMARY = None


def _population(rng, n):
    """Heterogeneous 3-component cascade sample: component 0 informative
    (accuracy 0.2 + 0.8·conf — beats the final model's 0.75 when
    confident), component 1 uninformative (flat 0.55), final 0.75."""
    c0 = np.clip(rng.random(n), 1e-6, 1.0)
    a0 = (rng.random(n) < 0.2 + 0.8 * c0).astype(np.float64)
    c1 = np.clip(rng.random(n), 1e-6, 1.0)
    a1 = (rng.random(n) < 0.55).astype(np.float64)
    a2 = (rng.random(n) < 0.75).astype(np.float64)
    return np.stack([c0, c1, np.ones(n)]), np.stack([a0, a1, a2])


def _eval_split(confs, agrees, thresholds):
    """Realized (avg MACs, accuracy) of a threshold vector on raw samples
    — the exact first-open-gate scan, no histogram quantization."""
    ths = np.asarray(thresholds, np.float64)
    gates = confs >= ths[:, None]
    gates[-1] = True
    ex = np.argmax(gates, axis=0)
    macs = float(np.asarray(MAC_PREFIX, np.float64)[ex].mean())
    acc = float(np.take_along_axis(agrees, ex[None], axis=0)[0].mean())
    return macs, acc


def _solver_rows(rng, quick):
    from repro.autotune import (ExitHistogram, edges_from_thresholds,
                                solve_budget)
    import warnings
    n = N_CAL // 4 if quick else N_CAL
    confs, agrees = _population(rng, 2 * n)
    cal_c, cal_a = confs[:, :n], agrees[:, :n]
    ev_c, ev_a = confs[:, n:], agrees[:, n:]
    hist = ExitHistogram.from_samples(cal_c, cal_a, MAC_PREFIX, BINS)
    rows, summary = [], []
    for budget in BUDGETS:
        shared = get_policy(f"budget@{budget}:shared")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            shared.fit([c for c in cal_c], MAC_PREFIX)
        shared_macs, shared_acc = _eval_split(ev_c, ev_a,
                                              shared.thresholds)
        # equal-budget comparison: the solver gets the shared fit's
        # REALIZED spend as its cap (and the shared point as a start)
        res = solve_budget(hist, shared_macs,
                           init_edges=edges_from_thresholds(
                               shared.thresholds, BINS))
        solver_macs, solver_acc = _eval_split(ev_c, ev_a, res.thresholds)
        rows.append((f"autotune/budget={budget:g}/solver_vs_shared", 0.0,
                     f"solver_acc={solver_acc:.4f};"
                     f"shared_acc={shared_acc:.4f};"
                     f"solver_macs={solver_macs:.4f};"
                     f"shared_macs={shared_macs:.4f}"))
        summary.append({
            "budget": budget,
            "shared_macs": shared_macs,
            "shared_acc": shared_acc,
            "solver_macs": solver_macs,
            "solver_acc": solver_acc,
            "solver_edges": list(res.edges),
        })
    return rows, summary


def _telemetry_overhead(quick):
    """tokens/s with telemetry on vs off over identical interleaved
    traffic, plus the per-chunk host-sync count (must be exactly 1)."""
    # thresholds at a genuinely MIXED-exit operating point (exits at every
    # component) — the streams_identical gate below is only meaningful
    # where shadow observation touches skipped depth that later tokens
    # read; the summary records the exit counts so the gate can verify
    # the point stayed mixed
    base = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32", use_kernels=True).with_cascade(
            n_components=3, exit_boundaries=(1, 2), exit_mode="cond_batch",
            thresholds=(0.021, 0.021, 0.0))
    # shadow_every=64: the overhead row measures telemetry's serving cost
    # at a fleet-scale sampling rate (shadow cost scales as 1/k — README
    # documents the knob; the aggressive default of 16 is for fast warm-up)
    cfg_on = base.with_autotune(enabled=True, bins=32, shadow_every=64)
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(1))
    n_req = 2 * LANE_BATCH
    max_new = 12 if quick else 16
    waves = 4 if quick else 8

    sync_counts = {}
    engines = {}
    for name, cfg in (("off", base), ("on", cfg_on)):
        eng = CascadeServingEngine(cfg, model, params,
                                   lane_batch=LANE_BATCH, n_lanes=2,
                                   cache_len=128, runtime="device",
                                   chunk=CHUNK)
        # count host syncs per chunk: wrap the loop's one sanctioned
        # device_get (run_chunk) and the global device_get entry point
        counts = {"get": 0, "chunks": 0}
        real_run = eng.loop.run_chunk

        def wrap_run(*a, _eng=eng, _real=real_run, _c=counts, **k):
            _c["chunks"] += 1
            real_get = jax.device_get
            try:
                def wg(x):
                    _c["get"] += 1
                    return real_get(x)
                jax.device_get = wg
                return _real(*a, **k)
            finally:
                jax.device_get = real_get
        eng.loop.run_chunk = wrap_run
        sync_counts[name] = counts
        engines[name] = eng

    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab_size, 8).astype(np.int32)
               for _ in range((waves + 1) * n_req)]
    # warm-up wave per engine (pays jit)
    for eng in engines.values():
        for i in range(n_req):
            eng.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=max_new))
        eng.run(300)
        eng.reset_metrics()
    # measured waves, interleaved at TICK granularity (machine-load drift
    # lands on both engines near-symmetrically — wave-level interleave
    # hands multi-second drift windows to one variant); the reported
    # ratio is the MEDIAN of per-wave paired ratios, robust to a noisy
    # wave on a shared machine
    wave_ratios = []
    for w in range(1, waves + 1):
        for eng in engines.values():
            eng.reset_metrics()
            for i in range(w * n_req, (w + 1) * n_req):
                eng.submit(Request(rid=i, prompt=prompts[i],
                                   max_new_tokens=max_new))
        for _ in range(300):
            busy = False
            for eng in engines.values():
                if eng.queue or any(not s.done for ln in eng.lanes
                                    for s in ln["slots"]):
                    eng.step()
                    busy = True
            if not busy:
                break
        w_on = engines["on"].stats()["wallclock_us_per_token"]
        w_off = engines["off"].stats()["wallclock_us_per_token"]
        if w_on and w_off:
            wave_ratios.append(w_off / w_on)

    us_on = engines["on"].stats()["wallclock_us_per_token"]
    us_off = engines["off"].stats()["wallclock_us_per_token"]
    ratio = float(np.median(wave_ratios)) if wave_ratios else 1.0
    extra = {name: c["get"] - c["chunks"] for name, c in sync_counts.items()}
    streams_equal = (
        {r: tuple(v["tokens"]) for r, v in engines["on"].finished.items()}
        == {r: tuple(v["tokens"]) for r, v in engines["off"].finished.items()})
    from repro.autotune import merge_telemetry
    tel = merge_telemetry(engines["on"].lane_telemetry())
    exit_counts = [float(c) for c in tel["exit_counts"]]
    return {
        "telemetry_on_us_per_token": us_on,
        "telemetry_off_us_per_token": us_off,
        "tokens_per_s_ratio": ratio,          # on/off throughput; 1.0 = free
        "extra_host_syncs_per_chunk_on": extra["on"],
        "extra_host_syncs_per_chunk_off": extra["off"],
        "streams_identical": streams_equal,
        "shadow_every": cfg_on.autotune.shadow_every,
        "exit_counts": exit_counts,
        # the streams gate is vacuous unless exits actually span depths
        "mixed_exits": bool(exit_counts[0] > 0
                            and sum(exit_counts[1:]) > 0),
    }


def run(quick: bool = False):
    global LAST_AUTOTUNE_SUMMARY
    rng = np.random.default_rng(7)
    rows, budget_summary = _solver_rows(rng, quick)
    overhead = _telemetry_overhead(quick)
    rows.append(("autotune/telemetry_overhead",
                 overhead["telemetry_on_us_per_token"] or 0.0,
                 f"ratio={overhead['tokens_per_s_ratio']:.3f};"
                 f"extra_syncs={overhead['extra_host_syncs_per_chunk_on']};"
                 f"streams_identical={overhead['streams_identical']}"))
    LAST_AUTOTUNE_SUMMARY = {
        "bins": BINS,
        "mac_prefix": list(MAC_PREFIX),
        "quick": bool(quick),
        "budgets": budget_summary,
        "telemetry": overhead,
    }
    return rows
