"""Algorithm 1 semantics + the vectorized evaluation harness."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.cascade import (cascade_evaluate, cascade_infer_sequential,
                                sweep_epsilons)


def _fake_components(outputs):
    """Components returning fixed logits regardless of input."""
    fns = []
    for lg in outputs:
        fns.append(lambda x, state, _lg=jnp.asarray(lg): (_lg, state))
    return fns


def test_sequential_early_exit_takes_first_confident():
    # component 0 confident -> its answer wins even if later ones differ
    c0 = [[10.0, 0.0]]       # delta ~ 1.0, predicts 0
    c1 = [[0.0, 10.0]]       # predicts 1
    c2 = [[0.0, 10.0]]
    out, conf = cascade_infer_sequential(
        _fake_components([c0, c1, c2]), (0.9, 0.9, 0.0), jnp.zeros((1, 4)))
    assert int(out[0]) == 0


def test_sequential_falls_through_to_last():
    c0 = [[0.1, 0.0]]        # delta ~ 0.52 < 0.9
    c1 = [[0.0, 0.2]]        # delta ~ 0.55 < 0.9
    c2 = [[0.0, 10.0]]       # last always answers
    out, conf = cascade_infer_sequential(
        _fake_components([c0, c1, c2]), (0.9, 0.9, 0.0), jnp.zeros((1, 4)))
    assert int(out[0]) == 1


def test_cascade_evaluate_exit_accounting():
    N = 6
    labels = np.array([0, 0, 0, 1, 1, 1])
    conf = [np.array([.95, .2, .2, .95, .2, .2]),
            np.array([.0, .9, .1, .0, .9, .1]),
            np.ones(N)]
    preds = [np.array([0, 1, 1, 1, 0, 0]),
             np.array([1, 0, 0, 0, 1, 1]),
             labels.copy()]
    res = cascade_evaluate(conf, preds, labels, [1.0, 2.0, 3.0],
                           (0.9, 0.8, 0.0))
    # samples 0,3 exit at 0 (correct); 1,4 exit at 1 (correct); 2,5 at 2
    np.testing.assert_allclose(res.exit_fractions, [2 / 6, 2 / 6, 2 / 6])
    assert res.accuracy == 1.0
    assert res.avg_macs == (2 * 1 + 2 * 2 + 2 * 3) / 6
    assert res.speedup == pytest.approx(3.0 / 2.0)


@settings(max_examples=30, deadline=None)
@given(st.integers(30, 200), st.integers(0, 2 ** 31 - 1))
def test_speedup_monotone_in_threshold(n, seed):
    """Property: lowering thresholds can only increase (or keep) the speedup
    — more samples exit early."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 5, n)
    confs = [rng.random(n) for _ in range(3)]
    preds = [rng.integers(0, 5, n) for _ in range(2)] + [labels.copy()]
    macs = [1.0, 2.0, 3.0]
    hi = cascade_evaluate(confs, preds, labels, macs, (0.9, 0.9, 0.0))
    lo = cascade_evaluate(confs, preds, labels, macs, (0.5, 0.5, 0.0))
    assert lo.avg_macs <= hi.avg_macs + 1e-12
    assert lo.speedup >= hi.speedup - 1e-12


@settings(max_examples=20, deadline=None)
@given(st.integers(50, 150), st.integers(0, 2 ** 31 - 1))
def test_epsilon_zero_preserves_final_accuracy_on_calibration_set(n, seed):
    """ε=0 evaluated on the calibration set itself can't lose accuracy vs the
    full cascade when intermediate confidences are *discriminative* (exits
    only fire where the component is perfectly accurate).

    NB: the paper's δ_m(ε) is relative to each component's OWN α*_m — a
    component whose confidence does not discriminate (constant δ) exits
    everything at its own accuracy even for ε=0.  That is the paper's
    observed ε↔actual-degradation gap on CIFAR-100 (§7), covered by
    test_speedup_monotone_in_threshold instead."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 3, n)
    # component 0: confidently correct on a subset, unconfident garbage else
    correct_mask = rng.random(n) < 0.4
    conf0 = np.where(correct_mask, 0.99, 0.2)
    pred0 = np.where(correct_mask, labels, (labels + 1) % 3)
    conf1 = np.where(correct_mask, 0.9, 0.1)   # discriminative as well
    pred1 = np.where(correct_mask, labels, (labels + 2) % 3)
    confs = [conf0, conf1, np.ones(n)]
    preds = [pred0, pred1, labels.copy()]
    corrs = [(p == labels).astype(float) for p in preds]
    results = sweep_epsilons(confs, corrs, confs, preds, labels,
                             [1.0, 2.0, 3.0], [0.0])
    _, cal, res = results[0]
    full_acc = 1.0  # last component is perfect here
    assert res.accuracy >= full_acc - 1e-9
    assert res.speedup >= 1.0
