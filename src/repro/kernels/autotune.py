"""Per-arch / per-backend Pallas kernel tile autotuner.

The kernels ship with hand-picked default tiles (``tk=512`` KV tiles for
decode attention, ``(8, 2048)`` logits tiles for the exit-update family,
...).  Whether those win depends on the execution backend: the Pallas
*interpreter* (CPU CI) pays per-grid-cell Python dispatch, so it wants
few large tiles, while compiled Mosaic on a TPU wants tiles sized to VMEM
and the VPU/MXU shapes.  This module measures instead of guessing:

* :func:`sweep` times every candidate tile shape for each kernel on
  representative shapes — the default tiles are always in the candidate
  set, so the winner is never slower than the default *on the measured
  shapes by construction* (``tuned_us = min over candidates``).
* Winners install into a process-wide **tile registry** that every
  ``kernels/ops.py`` wrapper consults at call time.  Tile shapes are
  static kernel parameters (they are BlockSpec shapes), so an install
  that changes a tile costs exactly one recompile of that kernel's inner
  jit; re-installing identical tiles is a jit cache hit.  Installation
  happens *before* a serving loop traces (``DeviceDecodeLoop`` calls
  :func:`ensure_tuned` in its constructor), so the loop's
  ``_cache_size() == 1`` zero-retrace contract is preserved.
* :func:`ensure_tuned` persists the sweep in a config-hash-keyed JSON
  artifact (the ``repro.autotune.artifacts`` idiom: atomic write, key
  check on load, refuse-don't-guess on mismatch) so a fleet of engines
  sweeps once per (platform, backend, preset) and warm-starts afterwards.

``paged_gather`` has no free tile parameter (its block shape IS the cache
block), so its tunable axis is *implementation selection*: the Pallas
scalar-prefetch gather vs the plain XLA ``store[table]`` take — whichever
measures faster on this backend.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.backend import resolve_interpret
from repro.utils import get_logger

log = get_logger("kernels.autotune")

TILE_ARTIFACT_VERSION = 1

# hand-picked seeds — every kernel's no-registry fallback, and always a
# member of its candidate set (the >= 1.0 tuned-speedup invariant)
DEFAULT_TILES: Dict[str, Dict[str, Any]] = {
    "decode_attention": {"tk": 512},
    "flash_attention": {"tq": 128, "tk": 128},
    "rmsnorm": {"rt": 8},
    "confidence": {"bt": 8, "vt": 2048},
    "exit_update": {"bt": 8, "vt": 2048},
    # matches exit_update: same (bt, vt) ⇒ same streaming accumulation
    # order ⇒ bit-identical confidences between the fused and mega paths
    "megakernel": {"bt": 8, "vt": 2048},
    "paged_gather": {"impl": "pallas"},
}

CANDIDATE_TILES: Dict[str, List[Dict[str, Any]]] = {
    "decode_attention": [{"tk": t} for t in (128, 256, 512, 1024)],
    "flash_attention": [{"tq": tq, "tk": tk}
                        for tq in (64, 128) for tk in (64, 128, 256)],
    "rmsnorm": [{"rt": r} for r in (4, 8, 16, 32, 64)],
    "confidence": [{"bt": b, "vt": v}
                   for b in (8, 16, 32) for v in (512, 1024, 2048)],
    "exit_update": [{"bt": b, "vt": v}
                    for b in (8, 16, 32) for v in (512, 1024, 2048)],
    "megakernel": [{"bt": b, "vt": v}
                   for b in (8, 16) for v in (512, 1024, 2048)],
    "paged_gather": [{"impl": "pallas"}, {"impl": "take"}],
}

# sweep presets: (name, shape dict) per kernel.  "tiny" = CI-sized (the
# interpreter makes big sweeps expensive); "serving" = the serving-bench
# shapes (lane_batch 4 x cohorts, cache_len 256, reduced vocab).
SWEEP_SHAPES: Dict[str, Dict[str, List[Dict[str, int]]]] = {
    "tiny": {
        "decode_attention": [{"B": 4, "KV": 2, "qpk": 2, "hd": 64,
                              "W": 128}],
        "flash_attention": [{"B": 2, "H": 4, "KV": 2, "hd": 64, "S": 128}],
        "rmsnorm": [{"R": 32, "d": 256}],
        "confidence": [{"B": 8, "V": 2048}],
        "exit_update": [{"B": 8, "V": 2048}],
        "megakernel": [{"B": 8, "d": 256, "V": 2048}],
        "paged_gather": [{"NB": 32, "bs": 16, "kv": 2, "hd": 64, "B": 4,
                          "nblk": 8}],
    },
    "serving": {
        "decode_attention": [{"B": 8, "KV": 2, "qpk": 2, "hd": 64,
                              "W": 256}],
        "flash_attention": [{"B": 2, "H": 4, "KV": 2, "hd": 64, "S": 256}],
        "rmsnorm": [{"R": 64, "d": 512}, {"R": 256, "d": 4096}],
        "confidence": [{"B": 8, "V": 8192}],
        "exit_update": [{"B": 8, "V": 8192}],
        "megakernel": [{"B": 8, "d": 512, "V": 8192}],
        "paged_gather": [{"NB": 64, "bs": 16, "kv": 2, "hd": 64, "B": 8,
                          "nblk": 16}],
    },
}

# ---------------------------------------------------------------------------
# the tile registry ops.py consults
# ---------------------------------------------------------------------------

_TUNED: Dict[str, Dict[str, Any]] = {}


def tile(kernel: str, param: str):
    """The resolved value of one tile parameter: tuned if installed,
    else the hand-picked default.  Read at wrapper-call (= trace) time,
    NOT baked into any one trace — swapping a tile invalidates exactly
    the affected kernel's inner-jit cache entry."""
    tuned = _TUNED.get(kernel)
    if tuned is not None and param in tuned:
        return tuned[param]
    return DEFAULT_TILES[kernel][param]


def install_tiles(tiles: Dict[str, Dict[str, Any]]) -> None:
    """Install sweep winners into the registry (merge per kernel)."""
    for kernel, params in tiles.items():
        if kernel not in DEFAULT_TILES:
            raise ValueError(f"unknown kernel {kernel!r}")
        _TUNED.setdefault(kernel, {}).update(params)


def reset_tiles() -> None:
    """Drop every installed tile (tests; defaults apply again)."""
    _TUNED.clear()


def current_tiles() -> Dict[str, Dict[str, Any]]:
    """The effective tile table: defaults overlaid with installs."""
    out = {k: dict(v) for k, v in DEFAULT_TILES.items()}
    for k, v in _TUNED.items():
        out[k].update(v)
    return out


# ---------------------------------------------------------------------------
# timing
# ---------------------------------------------------------------------------

def _time_us(fn, reps: int = 3) -> float:
    """Median wall time of ``fn()`` in µs (after one warm-up/compile call).

    Median over reps: a single scheduler hiccup must not crown the wrong
    tile (the winner feeds a >= 1.0 speedup gate)."""
    out = fn()
    jax.tree_util.tree_map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
        else x, out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def _shape_tag(shape: Dict[str, int]) -> str:
    return ";".join(f"{k}={v}" for k, v in sorted(shape.items()))


def _make_call(kernel: str, shape: Dict[str, int], params: Dict[str, Any],
               interpret: bool):
    """A zero-arg timed callable for (kernel, shape, candidate tiles)."""
    rng = np.random.default_rng(0)

    def arr(*s, dtype=jnp.float32):
        return jnp.asarray(rng.standard_normal(s), dtype)

    if kernel == "decode_attention":
        from repro.kernels.decode_attention import decode_attention
        q = arr(shape["B"], shape["KV"], shape["qpk"], shape["hd"])
        k = arr(shape["B"], shape["KV"], shape["W"], shape["hd"])
        v = arr(shape["B"], shape["KV"], shape["W"], shape["hd"])
        kpos = jnp.arange(shape["W"], dtype=jnp.int32)
        t = jnp.asarray(shape["W"] - 1, jnp.int32)
        return lambda: decode_attention(q, k, v, t, kpos, None,
                                        tk=params["tk"], interpret=interpret)
    if kernel == "flash_attention":
        from repro.kernels.flash_attention import flash_attention
        q = arr(shape["B"], shape["H"], shape["S"], shape["hd"])
        k = arr(shape["B"], shape["KV"], shape["S"], shape["hd"])
        v = arr(shape["B"], shape["KV"], shape["S"], shape["hd"])
        return lambda: flash_attention(q, k, v, tq=params["tq"],
                                       tk=params["tk"], interpret=interpret)
    if kernel == "rmsnorm":
        from repro.kernels.rmsnorm import rmsnorm
        x = arr(shape["R"], shape["d"])
        w = jnp.ones((shape["d"],), jnp.float32)
        return lambda: rmsnorm(x, w, rt=params["rt"], interpret=interpret)
    if kernel == "confidence":
        from repro.kernels.confidence import confidence
        x = arr(shape["B"], shape["V"])
        return lambda: confidence(x, bt=params["bt"], vt=params["vt"],
                                  interpret=interpret)
    if kernel == "exit_update":
        from repro.kernels.exit_update import exit_update
        B = shape["B"]
        x = arr(B, shape["V"])
        zi = jnp.zeros((B,), jnp.int32)
        zf = jnp.zeros((B,), jnp.float32)
        ones = jnp.ones((B,), jnp.int32)
        return lambda: exit_update(
            x, zi, zi, zi, zf, zi, zf, ones, threshold=0.5, m=0,
            n_components=2, bt=params["bt"], vt=params["vt"],
            interpret=interpret)
    if kernel == "megakernel":
        from repro.kernels.megakernel import exit_head_update
        B = shape["B"]
        h = arr(B, shape["d"])
        w = jnp.ones((shape["d"],), jnp.float32)
        head = arr(shape["d"], shape["V"])
        zi = jnp.zeros((B,), jnp.int32)
        zf = jnp.zeros((B,), jnp.float32)
        ones = jnp.ones((B,), jnp.int32)
        return lambda: exit_head_update(
            h, w, head, zi, zi, zi, zf, zi, zf, ones, threshold=0.5, m=0,
            n_components=2, bt=params["bt"], vt=params["vt"],
            interpret=interpret)
    if kernel == "paged_gather":
        table = jnp.asarray(
            rng.integers(0, shape["NB"], (shape["B"], shape["nblk"])),
            jnp.int32)
        store = arr(shape["NB"], shape["bs"], shape["kv"], shape["hd"])
        if params["impl"] == "take":
            fn = jax.jit(lambda s, t: jnp.take(s, t, axis=0).reshape(
                (t.shape[0], t.shape[1] * s.shape[1]) + s.shape[2:]))
            return lambda: fn(store, table)
        from repro.kernels.paged_gather import paged_gather
        return lambda: paged_gather(store, table, interpret=interpret)
    raise ValueError(f"unknown kernel {kernel!r}")


def sweep(kernels: Optional[List[str]] = None, shapes: str = "tiny",
          reps: int = 3, interpret: Optional[bool] = None,
          ) -> Tuple[Dict[str, Dict[str, Any]], List[Dict[str, Any]]]:
    """Time every candidate tile for every kernel; return
    ``(winners, rows)``.

    ``winners[kernel]`` is the candidate minimizing total time across the
    preset's shapes.  ``rows`` carries one bench record per (kernel,
    shape): default vs tuned µs from the SAME sweep (so
    ``tuned_speedup >= 1.0`` holds by construction) plus the backend
    provenance (interpret/compiled, platform) the gate requires.
    """
    interpret = resolve_interpret(interpret)
    backend = "interpret" if interpret else "compiled"
    platform = jax.default_backend()
    kernels = list(kernels or DEFAULT_TILES)
    preset = SWEEP_SHAPES[shapes]
    winners: Dict[str, Dict[str, Any]] = {}
    rows: List[Dict[str, Any]] = []
    for kernel in kernels:
        cands = CANDIDATE_TILES[kernel]
        default = DEFAULT_TILES[kernel]
        if default not in cands:
            cands = cands + [default]
        shape_list = preset[kernel]
        # times[c][s] = µs of candidate c on shape s
        times = [[_time_us(_make_call(kernel, s, c, interpret), reps)
                  for s in shape_list] for c in cands]
        totals = [sum(ts) for ts in times]
        best = int(np.argmin(totals))
        di = cands.index(default)
        winners[kernel] = dict(cands[best])
        for si, s in enumerate(shape_list):
            rows.append({
                "kernel": kernel,
                "shape": _shape_tag(s),
                "tiles": dict(cands[best]),
                "default_tiles": dict(default),
                "default_us": round(times[di][si], 2),
                "tuned_us": round(times[best][si], 2),
                # the PER-SHAPE winner can differ from the per-kernel
                # winner; the gate checks the installed (per-kernel) one,
                # so report exactly what installs
                "tuned_speedup": round(
                    times[di][si] / max(times[best][si], 1e-9), 4),
                "backend": backend,
                "platform": platform,
            })
        log.info("kernel %s: tuned %s (default %s)", kernel, winners[kernel],
                 default)
    return winners, rows


# ---------------------------------------------------------------------------
# config-hash-keyed tile artifact (the autotune/artifacts.py idiom)
# ---------------------------------------------------------------------------

def tune_key(shapes: str = "tiny", interpret: Optional[bool] = None) -> str:
    """Stable identity of a tile sweep: tiles transfer only between
    processes with the same execution backend, platform, candidate grids
    and sweep preset."""
    interpret = resolve_interpret(interpret)
    ident = {
        "version": TILE_ARTIFACT_VERSION,
        "platform": jax.default_backend(),
        "interpret": bool(interpret),
        "shapes": shapes,
        "candidates": CANDIDATE_TILES,
        "defaults": DEFAULT_TILES,
    }
    blob = json.dumps(ident, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class TileArtifact:
    """One persisted tile sweep: the winners plus the timing evidence."""

    config_key: str
    platform: str
    interpret: bool
    shapes: str
    tiles: Dict[str, Dict[str, Any]]
    rows: List[Dict[str, Any]]

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["version"] = TILE_ARTIFACT_VERSION
        return d

    @classmethod
    def from_json(cls, d: dict) -> "TileArtifact":
        d = dict(d)
        ver = d.pop("version", TILE_ARTIFACT_VERSION)
        if ver != TILE_ARTIFACT_VERSION:
            raise ValueError(
                f"tile artifact version {ver} != {TILE_ARTIFACT_VERSION}")
        return cls(**d)


def tile_artifact_path(artifact_dir: str, key: str) -> str:
    return os.path.join(artifact_dir, f"kernel_tiles_{key[:16]}.json")


def save_tile_artifact(artifact_dir: str, artifact: TileArtifact) -> str:
    """Atomically persist; returns the written path."""
    os.makedirs(artifact_dir, exist_ok=True)
    path = tile_artifact_path(artifact_dir, artifact.config_key)
    fd, tmp = tempfile.mkstemp(dir=artifact_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(artifact.to_json(), f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_tile_artifact(artifact_dir: str, shapes: str = "tiny",
                       interpret: Optional[bool] = None
                       ) -> Optional[TileArtifact]:
    """The artifact matching this process's tune key, or None.

    A key mismatch inside the file (hand-copied artifact, different
    platform/backend/candidate grid) WARNS and returns None — the caller
    falls back to the default tiles and may re-sweep; stale tiles are
    never silently installed."""
    key = tune_key(shapes, interpret)
    path = tile_artifact_path(artifact_dir, key)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        art = TileArtifact.from_json(json.load(f))
    if art.config_key != key:
        log.warning(
            "tile artifact %s was swept under key %s..., not this "
            "backend/platform's %s... — falling back to default tiles",
            path, art.config_key[:16], key[:16])
        return None
    return art


def ensure_tuned(cfg=None, artifact_dir: Optional[str] = None,
                 shapes: Optional[str] = None, reps: int = 3,
                 force: bool = False) -> TileArtifact:
    """Sweep-or-load, then install: the one entry point engine builds use.

    Resolution order: a matching artifact in ``artifact_dir`` (skip the
    sweep) > a fresh :func:`sweep` (persisted when ``artifact_dir`` is
    set).  ``cfg`` supplies ``kernel_tune.artifact_dir`` /
    ``kernel_tune.shapes`` defaults and its ``kernel_interpret``
    override.  Returns the installed artifact.
    """
    interpret = None
    if cfg is not None:
        interpret = cfg.kernel_interpret
        if artifact_dir is None:
            artifact_dir = cfg.kernel_tune.artifact_dir
        if shapes is None:
            shapes = cfg.kernel_tune.shapes
    shapes = shapes or "tiny"
    art = None
    if artifact_dir and not force:
        art = load_tile_artifact(artifact_dir, shapes, interpret)
    if art is None:
        tiles, rows = sweep(shapes=shapes, reps=reps, interpret=interpret)
        art = TileArtifact(
            config_key=tune_key(shapes, interpret),
            platform=jax.default_backend(),
            interpret=resolve_interpret(interpret),
            shapes=shapes, tiles=tiles, rows=rows)
        if artifact_dir:
            save_tile_artifact(artifact_dir, art)
    install_tiles(art.tiles)
    return art
