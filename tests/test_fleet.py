"""Fleet tier (repro.fleet): scheduler, merged telemetry, drain/migration.

Pins the subsystem's contracts: fixed-bin histogram merging is EXACTLY the
pooled-sample histogram (so the fleet solve equals the pooled solve, not
approximates it), placement follows the depth/load signals, drain loses
zero requests and zero committed tokens (migrated prefixes replay through
PR 7's path), the aggregator fans one merged solve to every member with
zero retraces, and health tracking backs off / rescues a failing member.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.autotune import (ExitHistogram, load_artifact, merge_histograms,
                            solve_epsilon)
from repro.configs import get_config, reduced
from repro.configs.base import FleetConfig
from repro.escalate import ModelCascadeTier
from repro.fleet import EngineHealth, FleetScheduler, TelemetryAggregator
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

BINS = 16


def _tiny(**cascade):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    return cfg.with_cascade(**cascade)


def _tiny_autotune(**kw):
    cascade = kw.pop("cascade", {})
    at = dict(enabled=True, bins=BINS, shadow_every=4, min_shadow=8,
              resolve_every=8)
    at.update(kw)
    return _tiny(**cascade).with_autotune(**at)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = _tiny()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _engines(cfg, model, params, n=2, **kw):
    kw.setdefault("lane_batch", 2)
    kw.setdefault("n_lanes", 1)
    kw.setdefault("cache_len", 32)
    return [CascadeServingEngine(cfg, model, params, **kw)
            for _ in range(n)]


def _submit(fleet, cfg, n, max_new=6, seed=3, prompt_len=6):
    rng = np.random.default_rng(seed)
    for i in range(n):
        fleet.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
            max_new_tokens=max_new))


# ---------------------------------------------------------------------------
# histogram merge: exact pooled equality
# ---------------------------------------------------------------------------

def test_merge_histograms_is_exactly_the_pooled_histogram():
    """bincount(a ++ b) == bincount(a) + bincount(b): a merged fleet
    histogram IS the pooled-sample histogram, so the merged solve equals
    the pooled solve edge for edge — equality, not tolerance."""
    rng = np.random.default_rng(0)
    mac_prefix = (1.0, 2.0, 3.0)
    shards = []
    confs, agrees = [], []
    for _ in range(4):
        c = rng.random((2, 2000))
        a = (rng.random((2, 2000)) < 0.3 + 0.6 * c).astype(np.float64)
        shards.append(ExitHistogram.from_samples(c, a, mac_prefix, BINS))
        confs.append(c)
        agrees.append(a)
    merged = merge_histograms(shards)
    pooled = ExitHistogram.from_samples(np.concatenate(confs, axis=1),
                                        np.concatenate(agrees, axis=1),
                                        mac_prefix, BINS)
    np.testing.assert_array_equal(merged.counts, pooled.counts)
    np.testing.assert_array_equal(merged.agree, pooled.agree)
    for eps in (0.02, 0.1):
        assert (solve_epsilon(merged, eps).edges
                == solve_epsilon(pooled, eps).edges)


def test_merge_histograms_refuses_incompatible_grids():
    rng = np.random.default_rng(1)
    c = rng.random((1, 100))
    a = np.ones((1, 100))
    h16 = ExitHistogram.from_samples(c, a, (1.0, 2.0), 16)
    h8 = ExitHistogram.from_samples(c, a, (1.0, 2.0), 8)
    hcost = ExitHistogram.from_samples(c, a, (1.0, 9.0), 16)
    with pytest.raises(ValueError, match="grid"):
        merge_histograms([h16, h8])
    with pytest.raises(ValueError, match="mac_prefix"):
        merge_histograms([h16, hcost])
    with pytest.raises(ValueError, match="at least one"):
        merge_histograms([])


# ---------------------------------------------------------------------------
# engine fleet hooks (the two bugfix satellites ride here)
# ---------------------------------------------------------------------------

def test_engine_stats_is_a_deep_snapshot(tiny_model):
    """stats() must be safe to hold across later step()s: mutating the
    returned dict never writes through to the engine, and nested dicts
    are fresh objects per call."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=1, cache_len=32)
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       max_new_tokens=4))
    eng.run(50)
    s1 = eng.stats()
    s1["escalation"]["prefill_positions_fresh"] = 10**9
    s1["memory"]["reclaimed_by_exit"] = 10**9
    s1["segments_run"][0] = 10**9
    s2 = eng.stats()
    assert s2["escalation"]["prefill_positions_fresh"] != 10**9
    assert s2["memory"]["reclaimed_by_exit"] != 10**9
    assert s2["segments_run"][0] != 10**9
    assert s1["escalation"] is not s2["escalation"]


def test_engine_cancel_queued_request(tiny_model):
    """cancel() of a never-admitted request removes it from the queue and
    returns a well-formed empty record (the drain-time requeue path)."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=1, cache_len=32)
    prompts = [np.arange(4, dtype=np.int32) for _ in range(4)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=4))
    # capacity 2: rids 2, 3 still queue after the first tick
    eng.step()
    assert 3 in [r.rid for r in eng.queue]
    before = eng._cancelled_for_escalation
    rec = eng.cancel(3)
    assert rec == {"tokens": [], "exit_depths": [], "confs": [],
                   "lane": None, "escalated": True}
    assert 3 not in [r.rid for r in eng.queue]
    assert 3 not in eng._submit_tick
    # queue cancels are not escalation cancels (nothing was decoded)
    assert eng._cancelled_for_escalation == before
    assert eng.cancel(99) is None
    eng.run(100)
    assert sorted(eng.finished) == [0, 1, 2, 3]
    assert len(eng.finished[2]["tokens"]) == 4


def test_engine_admitting_gate_and_take_queue(tiny_model):
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=1, cache_len=32)
    for i in range(3):
        eng.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                           max_new_tokens=4))
    eng.admitting = False
    eng.step()
    assert eng.queued_count() == 3 and not eng.live_rids()
    taken = eng.take_queue()
    assert [r.rid for r in taken] == [0, 1, 2]
    assert eng.queued_count() == 0 and not eng._submit_tick
    eng.admitting = True
    for r in taken:
        eng.submit(r)
    eng.run(100)
    assert sorted(eng.finished) == [0, 1, 2]
    assert eng.free_slot_count() == 2


# ---------------------------------------------------------------------------
# scheduler logic on fake members (deterministic, no device work)
# ---------------------------------------------------------------------------

class FakeMember:
    """Minimal fleet-member surface: instant one-token-per-step decode."""

    def __init__(self, cfg, capacity=4):
        self.cfg = cfg
        self.capacity = capacity
        self.admitting = True
        self.fail = False
        self.queue = []
        self.live = {}
        self.finished = {}

    def submit(self, req):
        self.queue.append(req)

    def step(self):
        if self.fail:
            raise RuntimeError("boom")
        while (self.admitting and self.queue
               and len(self.live) < self.capacity):
            r = self.queue.pop(0)
            self.live[r.rid] = (r, [])
        for rid, (r, toks) in list(self.live.items()):
            toks.append(1000 * rid + len(toks))
            if len(toks) >= r.max_new_tokens:
                self.finished[rid] = self._record(toks, escalated=False)
                del self.live[rid]

    @staticmethod
    def _record(toks, escalated):
        return {"tokens": list(toks), "exit_depths": [0] * len(toks),
                "confs": [1.0] * len(toks), "lane": 0,
                "escalated": escalated}

    def stats(self):
        if self.fail:
            raise RuntimeError("probe boom")
        return {"requests_finished": len(self.finished)}

    def free_slot_count(self):
        return self.capacity - len(self.live)

    def queued_count(self):
        return len(self.queue)

    def live_rids(self):
        return list(self.live)

    def take_queue(self):
        taken, self.queue = self.queue, []
        return taken

    def cancel(self, rid, keep=None):
        if rid in self.live:
            r, toks = self.live.pop(rid)
            toks = toks if keep is None else toks[:keep]
            self.finished[rid] = self._record(toks, escalated=True)
            return self.finished[rid]
        return None


def _fake_fleet(n=2, capacity=4, **fleet_kw):
    cfg = _tiny()
    fleet_cfg = FleetConfig(n_engines=n, **fleet_kw)
    members = [FakeMember(cfg, capacity=capacity) for _ in range(n)]
    return FleetScheduler(members, fleet=fleet_cfg), members


def test_placement_follows_depth_signal():
    fleet, members = _fake_fleet(depth_weight=1.0, load_weight=0.0,
                                 block_weight=0.0)
    fleet.compactor.lane_stats[0].depth_ema = 0.0
    fleet.compactor.lane_stats[1].depth_ema = 1.0
    fleet.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                         max_new_tokens=2,
                         extra={"predicted_depth": 1.0}))
    fleet.submit(Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                         max_new_tokens=2,
                         extra={"predicted_depth": 0.0}))
    fleet.step()
    assert [r.rid for (r, _) in members[1].live.values()] == [0]
    assert [r.rid for (r, _) in members[0].live.values()] == [1]


def test_placement_follows_load_signal():
    fleet, members = _fake_fleet(depth_weight=0.0, load_weight=1.0,
                                 block_weight=0.0, capacity=8)
    for i in range(4):
        fleet.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=8))
    fleet.step()
    # with equal depth scores the load term must spread the burst
    assert len(members[0].live) == 2 and len(members[1].live) == 2


def test_drain_migrate_on_fakes_finalizes_and_requeues():
    fleet, members = _fake_fleet(capacity=2)
    for i in range(5):
        fleet.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=4))
    fleet.step()            # 4 live (2 per member), 1 queued at the fleet
    lived_on_0 = set(members[0].live)
    summary = fleet.drain(0, mode="migrate")
    assert set(summary["migrated"]) == lived_on_0
    assert not members[0].live and not members[0].queue
    # the cancel records were migration bookkeeping, not completions
    assert not members[0].finished
    fleet.run(50)
    assert sorted(fleet.finished) == [0, 1, 2, 3, 4]
    assert 0 in fleet.drained
    for rid in lived_on_0:
        rec = fleet.finished[rid]
        assert rec["migrations"] == 1
        # committed prefix survived the migration verbatim
        assert rec["tokens"][0] == 1000 * rid
        assert len(rec["tokens"]) == 4
    st = fleet.stats()
    assert st["requests_finished"] == 5 and st["discarded_tokens"] == 0


def test_drain_finish_mode_completes_in_flight_locally():
    fleet, members = _fake_fleet(capacity=2)
    for i in range(3):
        fleet.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=4))
    fleet.step()
    lived_on_0 = set(members[0].live)
    assert lived_on_0
    fleet.drain(0, mode="finish")
    fleet.run(50)
    assert sorted(fleet.finished) == [0, 1, 2]
    for rid in lived_on_0:
        assert fleet.finished[rid]["migrations"] == 0
        assert fleet.finished[rid]["engine"] == 0
    assert 0 in fleet.drained
    # resume re-opens admission
    fleet.resume(0)
    assert members[0].admitting and 0 not in fleet.drained


def test_unhealthy_member_is_rescued_and_recovers():
    fleet, members = _fake_fleet(capacity=2, max_failures=2,
                                 heartbeat_every=1, backoff_base=2,
                                 backoff_cap=4, load_weight=1.0,
                                 depth_weight=0.0)
    for i in range(4):
        fleet.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                             max_new_tokens=3))
    members[1].fail = True
    fleet.run(60)
    # every request finished on the healthy member
    assert sorted(fleet.finished) == [0, 1, 2, 3]
    assert all(r["engine"] == 0 for r in fleet.finished.values())
    assert not fleet.health.healthy(1)
    st = fleet.health.stats()[1]
    assert st["unhealthy_marks"] == 1 and st["total_failures"] >= 2
    # recovery: a successful probe restores the member
    members[1].fail = False
    tick = fleet._tick + st["backoff"] + 1
    assert fleet.health.beat(1, tick, members[1].stats) is True
    assert fleet.health.healthy(1)


def test_health_backoff_window_blocks_probes():
    h = EngineHealth(1, max_failures=3, backoff_base=2, backoff_cap=8)
    assert h.beat(0, 0, lambda: 1) is True
    h.note_failure(0, 10)
    st = h.states[0]
    assert st.backoff == 2 and st.next_probe_tick == 12
    assert h.beat(0, 11, lambda: 1) is None     # inside the window
    h.note_failure(0, 12)
    assert st.backoff == 4
    h.note_failure(0, 16)
    assert st.backoff == 8 and not st.healthy   # capped, unhealthy at 3
    h.note_failure(0, 24)
    assert st.backoff == 8                      # stays capped
    assert h.beat(0, 40, lambda: 1) is True     # recovery resets
    assert st.healthy and st.failures == 0 and st.backoff == 0


# ---------------------------------------------------------------------------
# real engines: end-to-end fleet, drain mid-decode, merged solve
# ---------------------------------------------------------------------------

def test_fleet_end_to_end_on_real_engines(tiny_model):
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    fleet = FleetScheduler(_engines(cfg, model, params, n=2))
    _submit(fleet, cfg, 6, max_new=5)
    fleet.run(200)
    assert sorted(fleet.finished) == list(range(6))
    for rec in fleet.finished.values():
        assert len(rec["tokens"]) == 5
        assert rec["migrations"] == 0 and rec["discarded_tokens"] == 0
    st = fleet.stats()
    assert st["placements"] == 6
    # both members actually served traffic (load signal spreads a burst
    # that exceeds one member's 2 slots)
    assert {rec["engine"] for rec in fleet.finished.values()} == {0, 1}


def test_fleet_drain_mid_decode_replays_committed_prefix(tiny_model):
    """The acceptance-criteria drain semantics on real engines: drain one
    engine mid-run, committed prefixes replay into the sibling through
    build_replay, zero requests dropped, zero tokens lost."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    fleet = FleetScheduler(_engines(cfg, model, params, n=2))
    _submit(fleet, cfg, 6, max_new=8)
    for _ in range(3):
        fleet.step()
    committed = {}
    for ln in fleet.members[0].lanes:
        for s in ln["slots"]:
            if not s.done and s.request is not None:
                committed[s.request.rid] = list(s.generated)
    assert committed, "need in-flight work on member 0 to drain"
    summary = fleet.drain(0, mode="migrate")
    assert set(summary["migrated"]) >= {
        r for r, t in committed.items() if len(t) < 8}
    fleet.run(300)
    assert sorted(fleet.finished) == list(range(6))
    for rid, prefix in committed.items():
        rec = fleet.finished[rid]
        assert rec["tokens"][:len(prefix)] == prefix   # nothing lost
        assert len(rec["tokens"]) == 8                 # full budget served
        assert rec["discarded_tokens"] == 0
    # the migrated prefill rode the escalation replay accounting
    esc = fleet.members[1].stats()["escalation"]
    assert esc["prefill_positions_replayed"] > 0
    assert 0 in fleet.drained


def test_aggregator_merged_solve_fans_out_without_retrace(tiny_model,
                                                          tmp_path):
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(0.5, 0.0),
                                      exit_mode="cond_batch"))
    members = _engines(cfg, model, params, n=2)
    agg = TelemetryAggregator(cfg, members[0].mac_prefix,
                              resolve_every=4, min_shadow=4,
                              hysteresis=0.0, artifact_dir=str(tmp_path))
    fleet = FleetScheduler(members, aggregator=agg)
    _submit(fleet, cfg, 6, max_new=8)
    fleet.run(300)
    assert sorted(fleet.finished) == list(range(6))
    assert agg.resolves >= 1 and agg.pushes >= 1
    ths = fleet.current_thresholds()
    assert ths is not None
    for m in members:
        assert m.current_thresholds() == ths       # fan-out reached all
        assert m._decode._cache_size() == 1        # push never retraced
    # the merged histogram equals merging per-member histograms
    per = agg.merged_histogram(fleet)
    assert per.total == sum(agg.per_member_shadow(fleet))
    # artifacts carry fleet provenance; a new member warm-starts from the
    # live fleet thresholds immediately
    art = load_artifact(str(tmp_path), cfg)
    assert art is not None and art.source == "fleet"
    fresh = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                 n_lanes=1, cache_len=32)
    idx = fleet.add_member(fresh)
    assert idx == 2 and fresh.current_thresholds() == ths


def test_aggregator_refuses_heterogeneous_or_controllered_members(
        tiny_model, tmp_path):
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(0.5, 0.0)))
    members = _engines(cfg, model, params, n=2)
    agg = TelemetryAggregator(cfg, members[0].mac_prefix)
    plain = CascadeServingEngine(_tiny(), model, params, lane_batch=2,
                                 n_lanes=1, cache_len=32)
    with pytest.raises(ValueError, match="autotune disabled"):
        FleetScheduler([members[0], plain], aggregator=agg)
    other_cfg = _tiny_autotune(cascade=dict(thresholds=(0.5, 0.0),
                                            confidence="entropy"))
    other = CascadeServingEngine(other_cfg, build_model(other_cfg),
                                 params, lane_batch=2, n_lanes=1,
                                 cache_len=32)
    with pytest.raises(ValueError, match="config_key"):
        FleetScheduler([members[0], other], aggregator=agg)


# ---------------------------------------------------------------------------
# tier as a fleet member
# ---------------------------------------------------------------------------

def test_tier_exposes_the_fleet_member_surface(tiny_model):
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.5, 0.0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=1, cache_len=32)
    tier = ModelCascadeTier([eng])
    assert tier.cfg is eng.cfg
    assert tier.free_slot_count() == 2 and tier.queued_count() == 0
    for i in range(2):
        tier.submit(Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                            max_new_tokens=3))
    tier.admitting = False
    assert not eng.admitting
    assert tier.queued_count() == 2 and tier.live_rids() == []
    taken = tier.take_queue()
    assert [r.rid for r in taken] == [0, 1]
    assert not tier._tracked            # untracked for fleet requeue
    tier.admitting = True
    for r in taken:
        tier.submit(r)
    tier.run(100)
    assert sorted(tier.finished) == [0, 1]


def test_fleet_config_validation():
    with pytest.raises(ValueError, match="drain_mode"):
        FleetConfig(drain_mode="teleport")
    with pytest.raises(ValueError, match="n_engines"):
        FleetConfig(n_engines=0)
    with pytest.raises(ValueError, match="depth_weight"):
        FleetConfig(depth_weight=-1.0)
    cfg = _tiny().with_fleet(n_engines=4, drain_mode="migrate")
    assert dataclasses.asdict(cfg.fleet)["n_engines"] == 4
