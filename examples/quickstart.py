"""Quickstart: build a cascade model, run a forward pass, decode with
confidence-thresholded early exit, and change thresholds on the fly
(Goal 1.2 — no retraining).

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2.5-3b]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.confidence import softmax_outputs
from repro.core.policy import ExitDecider
from repro.models.model import build_model, extra_input_shapes


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))          # smoke-scale variant
    print(f"arch={cfg.name} family={cfg.family} layers={cfg.n_layers} "
          f"segments={cfg.segments}")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    extra = {k: jnp.zeros(s, jnp.float32)
             for k, s in extra_input_shapes(cfg, 2).items()} or None

    # 1) full-sequence forward: one logits tensor per cascade exit
    logits, aux = model.forward_train(params, toks, extra)
    for m, lg in enumerate(logits):
        _, conf = softmax_outputs(lg[:, -1])
        print(f"exit {m}: logits {lg.shape}, last-pos confidence "
              f"{np.round(np.asarray(conf), 3)}")

    # 2) prefill + a few decode steps with early exit, all through the one
    #    ExitDecider resolved from the config's registry strings
    decider = ExitDecider.from_config(cfg)
    cache = model.init_cache(2, 32)
    exit_logits, cache = model.prefill(params, toks, cache, extra)
    t = toks.shape[1]
    for thresholds in [(0.9, 0.0), (0.0, 0.0)]:   # on-the-fly change
        d = decider.decide(exit_logits, thresholds=thresholds)
        tok = d.prediction
        print(f"thresholds={thresholds}: next tokens "
              f"{np.asarray(tok)}, exits {np.asarray(d.exit_index)}")
    step_logits, cache = model.decode_step(params, tok[:, None], t, cache,
                                           extra)
    d2 = decider.decide(step_logits, thresholds=(0.5, 0.0))
    print(f"decode step at t={t}: tokens {np.asarray(d2.prediction)}, "
          f"exits {np.asarray(d2.exit_index)}")

    # 3) STAGED decode with a carried DecodeState: under
    #    exit_mode="cond_batch" segments nobody needs are actually skipped
    #    (watch segments_run), with identical outputs to "select"
    from repro.core.exec import StagedExecutor

    staged_cfg = cfg.with_cascade(exit_mode="cond_batch",
                                  thresholds=(0.0, 0.0))
    ex = StagedExecutor(model, staged_cfg)
    cache2 = model.init_cache(2, 32)
    d, cache2, state = ex.prefill(params, toks, cache2, extra)
    for _ in range(3):
        d, cache2, state = ex.decode_step(params, d.prediction[:, None],
                                          cache2, state, extra)
    print(f"staged decode: exits {np.asarray(d.exit_index)}, "
          f"segments actually run {np.asarray(state.segments_run)} "
          f"(deep segment skipped {3 - int(state.segments_run[1])}/3 steps)")

    # 4) swap the confidence measure without touching the model: any
    #    registered measure (entropy, margin, patience@k, your own) plugs in
    for measure in ("entropy", "margin"):
        alt = ExitDecider(measure, thresholds=(0.5, 0.0))
        d3 = alt.decide(exit_logits)
        print(f"measure={measure}: exits {np.asarray(d3.exit_index)}, "
              f"confidence {np.round(np.asarray(d3.confidence), 3)}")


if __name__ == "__main__":
    main()
