"""Synthetic image classification data with *controllable per-sample difficulty*.

CIFAR-10/100/SVHN are not available offline (the data gate anticipated by the
repro band).  The paper's claims are about the *relationship* between
per-sample difficulty, intermediate-classifier confidence, and early-exit
savings — so the synthetic distribution must contain that structure:

* each class c has a smooth random template ``T_c`` (low-frequency pattern);
* a sample is ``difficulty``-interpolated between its class template and a
  mixture of a distractor class template plus pixel noise;
* difficulty is drawn per-sample from a Beta distribution, so the dataset has
  a long easy tail (early exits fire) and a hard head (cascade escalates).

This reproduces the paper's qualitative setting: most inputs are easy, some
are intrinsically hard, and "the required computational effort for
classification is an intrinsic yet hidden property of the images" (§1).
Images are 32x32x3, per-pixel standardized like the paper's input.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


def _smooth_templates(rng: np.random.Generator, n_classes: int,
                      size: int = 32, channels: int = 3) -> np.ndarray:
    """Low-frequency class templates via random Fourier features."""
    yy, xx = np.meshgrid(np.linspace(0, 1, size), np.linspace(0, 1, size),
                         indexing="ij")
    n_waves = 6
    out = np.zeros((n_classes, size, size, channels), np.float32)
    for c in range(n_classes):
        for ch in range(channels):
            acc = np.zeros((size, size), np.float32)
            for _ in range(n_waves):
                fx, fy = rng.uniform(0.5, 4.0, 2)
                phase = rng.uniform(0, 2 * np.pi)
                amp = rng.uniform(0.5, 1.0)
                acc += amp * np.sin(2 * np.pi * (fx * xx + fy * yy) + phase)
            out[c, :, :, ch] = acc
    # unit-normalize each template
    out /= (np.sqrt((out ** 2).mean(axis=(1, 2, 3), keepdims=True)) + 1e-6)
    return out


@dataclasses.dataclass
class SynthImageDataset:
    images: np.ndarray   # (N, 32, 32, 3) float32, standardized
    labels: np.ndarray   # (N,) int32
    difficulty: np.ndarray  # (N,) float32 in [0,1] — hidden ground truth

    def __len__(self):
        return len(self.labels)

    def batches(self, batch_size: int, rng: np.random.Generator,
                epochs: int = 1, augment: bool = False):
        """Shuffled minibatch iterator; optional paper-style augmentation
        (pad-4 + random crop + horizontal flip, as in [HZRS15a])."""
        n = len(self)
        for _ in range(epochs):
            order = rng.permutation(n)
            for i in range(0, n - batch_size + 1, batch_size):
                idx = order[i:i + batch_size]
                x = self.images[idx]
                if augment:
                    x = _augment(x, rng)
                yield x, self.labels[idx]


def _augment(x: np.ndarray, rng: np.random.Generator) -> np.ndarray:
    b, h, w, c = x.shape
    pad = np.pad(x, ((0, 0), (4, 4), (4, 4), (0, 0)), mode="reflect")
    out = np.empty_like(x)
    offs = rng.integers(0, 9, size=(b, 2))
    flips = rng.random(b) < 0.5
    for i in range(b):
        oy, ox = offs[i]
        img = pad[i, oy:oy + h, ox:ox + w]
        if flips[i]:
            img = img[:, ::-1]
        out[i] = img
    return out


def make_image_splits(n_classes: int = 10, n_train: int = 8192,
                      n_val: int = 2048, n_test: int = 2048,
                      noise: float = 0.9, hard_frac_beta=(1.2, 2.5),
                      seed: int = 0) -> Tuple[SynthImageDataset, ...]:
    """Build (train, val, test) with shared class templates.

    ``noise`` scales the additive pixel noise at difficulty=1; the Beta
    parameters control the easy/hard mix (defaults give ~60% easy samples).
    """
    rng = np.random.default_rng(seed)
    templates = _smooth_templates(rng, n_classes)

    def make(n, split_seed):
        r = np.random.default_rng(split_seed)
        labels = r.integers(0, n_classes, n).astype(np.int32)
        difficulty = r.beta(*hard_frac_beta, size=n).astype(np.float32)
        distract = (labels + r.integers(1, n_classes, n)) % n_classes
        base = templates[labels]
        mix = templates[distract]
        d = difficulty[:, None, None, None]
        sig = (1 - 0.75 * d) * base + (0.75 * d) * mix
        x = sig + noise * d * r.standard_normal(base.shape).astype(np.float32)
        # per-pixel standardization (paper: "per-pixel-standardized RGB image")
        x = (x - x.mean(axis=(1, 2, 3), keepdims=True)) / (
            x.std(axis=(1, 2, 3), keepdims=True) + 1e-6)
        return SynthImageDataset(x.astype(np.float32), labels, difficulty)

    return (make(n_train, seed + 1), make(n_val, seed + 2),
            make(n_test, seed + 3))
