"""Online exit-telemetry + threshold autotuning (repro.autotune).

Pins the subsystem's contracts: the histogram solver reproduces §5 exactly
on bin-aligned data and its joint search dominates the independent one,
the budget solver dominates the legacy shared quantile, device-accumulated
telemetry bit-matches a host recompute, and a controller threshold push
neither retraces the decode programs nor perturbs token streams.
"""
import json
import os
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.policy as policy_mod
from repro.autotune import (CalibrationArtifact, ExitHistogram,
                            ThresholdController, config_key,
                            edges_from_thresholds, load_artifact,
                            merge_telemetry, save_artifact, solve_budget,
                            solve_epsilon, thresholds_from_edges)
from repro.autotune.solver import independent_epsilon_edges
from repro.autotune.telemetry import (accumulate_prefill, init_telemetry,
                                      pack_rider, telemetry_to_host)
from repro.configs import get_config, reduced
from repro.core.calibration import calibrate_thresholds, threshold_for_epsilon
from repro.core.policy import BudgetPolicy, get_calibrator, get_policy
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

BINS = 32


def _tiny(**cascade):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    return cfg.with_cascade(**cascade)


def _tiny_autotune(**kw):
    cascade = kw.pop("cascade", {})
    at = dict(enabled=True, bins=16, shadow_every=4, min_shadow=8,
              resolve_every=8)
    at.update(kw)
    return _tiny(**cascade).with_autotune(**at)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = _tiny()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _drive(cfg, model, params, runtime, n_req=4, max_new=6, seed=3,
           autotune=None, push_at=None, push=None):
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=2,
                               cache_len=32, runtime=runtime, chunk=4,
                               autotune=autotune)
    rng = np.random.default_rng(seed)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(n_req)]
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=p, max_new_tokens=max_new))
    if push_at is None:
        eng.run(200)
    else:
        for tick in range(200):
            if tick == push_at:
                eng.push_thresholds(push)
            if not eng.queue and all(s.done for ln in eng.lanes
                                     for s in ln["slots"]):
                break
            eng.step()
    return eng


# ---------------------------------------------------------------------------
# solver: §5 exactness, joint vs independent, budget vs shared quantile
# ---------------------------------------------------------------------------

def _edge_quantized(rng, n, lo=1, hi=BINS - 1):
    """Confidences exactly on interior bin edges e/BINS — the §5 grid and
    the histogram grid coincide, so the two solvers must agree exactly."""
    return rng.integers(lo, hi + 1, n).astype(np.float64) / BINS


def test_solver_recovers_paper_calibration_exactly():
    """independent_epsilon_edges == core.calibration.calibrate_thresholds,
    threshold for threshold, from ONE pass over the binned data."""
    rng = np.random.default_rng(0)
    N = 8000
    mac_prefix = (1.0, 2.0, 3.0)
    c0, c1 = _edge_quantized(rng, N), _edge_quantized(rng, N)
    a0 = (rng.random(N) < c0).astype(np.float64)
    a1 = (rng.random(N) < 0.4 + 0.5 * c1).astype(np.float64)
    hist = ExitHistogram.from_samples(np.stack([c0, c1]),
                                      np.stack([a0, a1]), mac_prefix, BINS)
    for eps in (0.02, 0.05, 0.1, 0.3):
        got = thresholds_from_edges(
            independent_epsilon_edges(hist, eps), BINS)
        want = calibrate_thresholds(
            [c0, c1, np.ones(N)], [a0, a1, np.ones(N)], eps).thresholds
        assert got == want, (eps, got, want)


def test_joint_search_dominates_independent_at_equal_epsilon():
    """The §5 rule tunes each component against its own α*_m; the joint
    constraint is the cascade's.  On a cascade with a well-calibrated
    early component the joint solver must spend strictly fewer MACs at
    the same ε while staying feasible."""
    rng = np.random.default_rng(1)
    N = 20000
    mac_prefix = (1.0, 5.0)
    c0 = _edge_quantized(rng, N)
    a0 = (c0 >= 0.5).astype(np.float64)     # deterministic: α*_0 = 1
    hist = ExitHistogram.from_samples(c0[None], a0[None], mac_prefix, BINS)
    eps = 0.1
    ind = solve_epsilon(hist, eps, mode="independent")
    joint = solve_epsilon(hist, eps, mode="joint")
    base = hist.final_accuracy
    assert ind.feasible and joint.feasible
    assert ind.agreement >= base - eps - 1e-9
    assert joint.agreement >= base - eps - 1e-9
    # α*_0-relative tuning exits only where comp0 is perfect; the joint
    # constraint tolerates cheap imperfect exits up to the cascade's ε
    assert joint.avg_macs < ind.avg_macs


def _heterogeneous_population(rng, n):
    """Two routing components with very different reliability curves — an
    allocation a shared exit quantile cannot express: component 0 is
    informative (accuracy tracks confidence and BEATS the final model's
    0.75 at high confidence), component 1's confidence is noise around a
    flat 0.55.  The accuracy-optimal budget spend shifts exit mass toward
    component 0; the shared quantile ties the components' exit fractions
    together and cannot."""
    mac_prefix = (1.0, 2.0, 3.0)
    c0 = np.clip(rng.random(n), 1e-6, 1.0)
    a0 = (rng.random(n) < 0.2 + 0.8 * c0).astype(np.float64)
    c1 = np.clip(rng.random(n), 1e-6, 1.0)
    a1 = (rng.random(n) < 0.55).astype(np.float64)
    a2 = (rng.random(n) < 0.75).astype(np.float64)
    confs = np.stack([c0, c1, np.ones(n)])
    agrees = np.stack([a0, a1, a2])
    return confs, agrees, mac_prefix


def test_budget_solver_dominates_shared_quantile():
    """At equal average MACs the per-component coordinate-descent solution
    must be at least as accurate as the shared-quantile fit on every
    budget — strictly better on this heterogeneous population."""
    rng = np.random.default_rng(2)
    confs, agrees, mac_prefix = _heterogeneous_population(rng, 40000)
    hist = ExitHistogram.from_samples(confs, agrees, mac_prefix, 64)
    for budget in (1.5, 2.0, 2.5):
        shared = get_policy(f"budget@{budget}:shared")
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            shared.fit([c for c in confs], mac_prefix)
        edges = edges_from_thresholds(shared.thresholds, 64)
        shared_macs, shared_acc = hist.evaluate(edges)
        res = solve_budget(hist, max(budget, shared_macs),
                           init_edges=edges)
        assert res.avg_macs <= max(budget, shared_macs) + 1e-9
        assert res.agreement > shared_acc, (budget, res, shared_acc)


def test_budget_policy_fit_routes_through_solver_and_deprecates_shared():
    """budget@<macs> + corrects= fits per-component thresholds via the
    solver; the shared-quantile path (no corrects, or :shared) fires a
    one-time DeprecationWarning."""
    rng = np.random.default_rng(4)
    confs, agrees, mac_prefix = _heterogeneous_population(rng, 8000)
    conf_list = [c for c in confs]

    policy_mod._SHARED_QUANTILE_WARNED = False
    pol = get_policy("budget@2.0")
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # solver path must not warn
        ths = pol.fit(conf_list, mac_prefix, corrects=[a for a in agrees])
    assert len(ths) == 3 and ths[-1] == 0.0
    # per-component: the informative and noise components get distinct
    # thresholds (a shared quantile in this population would not)
    assert ths[0] != ths[1]

    legacy = get_policy("budget@2.0:shared")
    with pytest.warns(DeprecationWarning, match="shared-quantile"):
        legacy.fit(conf_list, mac_prefix)
    # one-time: a second shared fit stays quiet
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        get_policy("budget@2.0:shared").fit(conf_list, mac_prefix)
    # solver must not allocate worse than the quantile at its own budget
    hist = ExitHistogram.from_samples(confs, agrees, mac_prefix, 64)
    _, acc_solver = hist.evaluate(edges_from_thresholds(ths, 64))
    _, acc_shared = hist.evaluate(
        edges_from_thresholds(legacy.thresholds, 64))
    assert acc_solver >= acc_shared


# ---------------------------------------------------------------------------
# telemetry: joint-cell layout, device/host bit-match, stream invariance
# ---------------------------------------------------------------------------

def test_joint_cell_layout_matches_host_reference():
    """Device-side cell flattening (accumulate_*'s C-order) must agree with
    ExitHistogram.from_samples' np.ravel_multi_index layout bit for bit."""
    rng = np.random.default_rng(0)
    n_m, bins, B = 3, 8, 64
    conf = rng.random((n_m, B))
    pred = rng.integers(0, 5, (n_m, B)).astype(np.int32)
    tel = init_telemetry(n_m, bins, mac_weights=(1.0, 2.0, 3.0))
    tel = accumulate_prefill(tel, pack_rider(jnp.asarray(pred),
                                             jnp.asarray(conf), bins),
                             jnp.ones((B,), bool))
    host = telemetry_to_host(tel)
    agrees = (pred[:-1] == pred[-1]).astype(np.float64)
    ref = ExitHistogram.from_samples(conf[:-1], agrees, (1.0, 2.0, 3.0),
                                     bins)
    np.testing.assert_array_equal(
        host["shadow_count"].reshape(ref.counts.shape), ref.counts)
    np.testing.assert_array_equal(
        host["shadow_agree"].reshape(ref.agree.shape), ref.agree)
    # merge: two lanes sum counters, carry mac_weights
    merged = merge_telemetry([tel, tel])
    np.testing.assert_array_equal(merged["shadow_count"],
                                  2 * host["shadow_count"])
    np.testing.assert_array_equal(merged["mac_weights"],
                                  host["mac_weights"])


def test_device_telemetry_bitmatches_host_recompute(tiny_model):
    """The device while_loop accumulates telemetry inside its carry and
    merges across lanes/chunks; the per-token host runtime is its step-by-
    step recompute.  Same traffic → bit-identical counters, and the exit
    counter must equal a numpy recompute from the decoded streams."""
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(0.02, 0.0),
                                      exit_mode="cond_batch"))
    h = _drive(cfg, model, params, "host")
    d = _drive(cfg, model, params, "device")
    th = merge_telemetry(h.lane_telemetry())
    td = merge_telemetry(d.lane_telemetry())
    for k in th:
        np.testing.assert_array_equal(th[k], td[k])
    assert th["steps"] > 0 and th["shadow_steps"] > 0
    # exit_counts recompute: every decode-step exit of every request (the
    # first recorded token per request is the prefill decision, which
    # feeds only the shadow counters)
    decode_exits = [e for r in h.finished.values()
                    for e in r["exit_depths"][1:]]
    np.testing.assert_array_equal(
        th["exit_counts"], np.bincount(decode_exits, minlength=2))
    # MAC counter prices those exits with the engine's prefix
    np.testing.assert_allclose(
        th["mac_spent"],
        np.asarray(h.mac_prefix, np.float64)[decode_exits].sum(),
        rtol=1e-6)


def test_telemetry_leaves_token_streams_identical(tiny_model):
    """Telemetry accumulation and the shadow full-depth pass change WHAT
    EXECUTES, never what is produced: token streams with autotune on must
    equal the plain engine's bit for bit."""
    model, params = tiny_model
    cascade = dict(thresholds=(0.02, 0.0), exit_mode="cond_batch")
    on = _drive(_tiny_autotune(cascade=cascade), model, params, "device")
    off = _drive(_tiny(**cascade), model, params, "device")
    assert on.finished.keys() == off.finished.keys()
    for rid in on.finished:
        assert on.finished[rid]["tokens"] == off.finished[rid]["tokens"]
        assert (on.finished[rid]["exit_depths"]
                == off.finished[rid]["exit_depths"])


@pytest.mark.parametrize("measure", ["softmax_max", "patience@2"])
def test_shadow_pass_commits_nothing_at_mixed_exits(measure):
    """The sharp version of stream invariance: a 3-component cascade at a
    genuinely mixed-exit operating point with an aggressive shadow rate.
    The shadow pass must OBSERVE the skipped depth (rider only), never
    commit its KV writes or streak advances — a committed shadow run
    diverges these streams within a few tokens."""
    cfg = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32").with_cascade(
        n_components=3, exit_boundaries=(1, 2), exit_mode="cond_batch",
        thresholds=(0.021, 0.021, 0.0), confidence=measure)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    on_cfg = cfg.with_autotune(enabled=True, bins=16, shadow_every=3)
    off = _drive(cfg, model, params, "host", max_new=20)
    on = _drive(on_cfg, model, params, "host", max_new=20)
    tel = merge_telemetry(on.lane_telemetry())
    # the operating point must actually be mixed, or this test is vacuous
    assert tel["exit_counts"][0] > 0 and tel["exit_counts"][1:].sum() > 0
    assert tel["shadow_steps"] > 0
    for rid in off.finished:
        assert on.finished[rid]["tokens"] == off.finished[rid]["tokens"]
        assert (on.finished[rid]["exit_depths"]
                == off.finished[rid]["exit_depths"])


def test_shadow_schedule_and_live_histogram(tiny_model):
    """The shadow pass fires on the deterministic t-schedule and the live
    confidence histogram rows cover exactly the samples still undecided
    when each component ran."""
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(1.1, 0.0),
                                      exit_mode="cond_batch"))
    eng = _drive(cfg, model, params, "host", n_req=2, max_new=8)
    tel = merge_telemetry(eng.lane_telemetry())
    # threshold 1.1: nobody exits early -> everyone reaches both
    # components every step
    assert tel["conf_hist"][0].sum() == tel["steps"]
    assert tel["conf_hist"][1].sum() == tel["steps"]
    assert tel["exit_counts"][0] == 0
    # shadow: every shadow_every-th decode position plus one per prefill
    # slot; with threshold 1.1 shadow forcing changes nothing but must
    # still record
    assert tel["shadow_steps"] > 0
    assert tel["shadow_count"].sum() == tel["shadow_steps"]


def test_device_tick_adds_no_host_syncs(tiny_model, monkeypatch):
    """Telemetry rides the device loop carry: a decode chunk still syncs
    exactly once (the existing device_get), telemetry on or off."""
    model, params = tiny_model

    def count_syncs(cfg):
        eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                   n_lanes=1, cache_len=32,
                                   runtime="device", chunk=4)
        rng = np.random.default_rng(0)
        for i in range(2):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=6))
        calls = {"get": 0, "chunks": 0}
        real_get = jax.device_get
        real_run = eng.loop.run_chunk

        def wrap_get(x):
            calls["get"] += 1
            return real_get(x)

        def wrap_run(*a, **k):
            calls["chunks"] += 1
            return real_run(*a, **k)

        monkeypatch.setattr(jax, "device_get", wrap_get)
        monkeypatch.setattr(eng.loop, "run_chunk", wrap_run)
        try:
            eng.run(100)
        finally:
            monkeypatch.setattr(jax, "device_get", real_get)
        assert calls["chunks"] > 0
        return calls["get"], calls["chunks"]

    cascade = dict(thresholds=(0.02, 0.0), exit_mode="cond_batch")
    on = count_syncs(_tiny_autotune(cascade=cascade))
    off = count_syncs(_tiny(**cascade))
    assert on[0] == on[1]            # one device_get per chunk, exactly
    assert off[0] == off[1]


# ---------------------------------------------------------------------------
# controller: zero retrace, deterministic streams, guards, end to end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime", ["host", "device"])
def test_threshold_push_causes_zero_new_traces(tiny_model, runtime):
    """Thresholds are DecodeState data: a mid-run push must not grow any
    jit cache, and a re-run with the same push must produce the same
    streams (determinism for fixed telemetry)."""
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(0.02, 0.0),
                                      exit_mode="cond_batch"))

    def run():
        eng = _drive(cfg, model, params, runtime, n_req=4, max_new=8,
                     push_at=3, push=(0.7, 0.0))
        return eng

    eng = run()
    jitted = (eng.loop._jitted if runtime == "device" else eng._decode)
    assert jitted._cache_size() == 1
    assert eng._prefill._cache_size() == 1
    assert eng.current_thresholds() == pytest.approx((0.7, 0.0))
    eng2 = run()
    assert eng.finished.keys() == eng2.finished.keys()
    for rid in eng.finished:
        assert eng.finished[rid]["tokens"] == eng2.finished[rid]["tokens"]


def test_push_requires_autotune_graphs(tiny_model):
    model, params = tiny_model
    eng = CascadeServingEngine(_tiny(), model, params, lane_batch=2,
                               n_lanes=1, cache_len=32)
    with pytest.raises(ValueError, match="autotune"):
        eng.push_thresholds((0.5, 0.0))
    with pytest.raises(ValueError, match="autotune"):
        CascadeServingEngine(_tiny(), model, params, lane_batch=2,
                             n_lanes=1, cache_len=32, autotune=True)


def test_controller_end_to_end_with_engine(tiny_model, tmp_path):
    """autotune=True wires a controller from cfg.autotune; it resolves
    from live telemetry, pushes without retracing, persists an artifact,
    and a fresh engine warm-starts from it."""
    model, params = tiny_model
    cfg = _tiny_autotune(mac_budget=1.0, resolve_every=4, min_shadow=4,
                         hysteresis=0.0,
                         cascade=dict(thresholds=(0.5, 0.0),
                                      exit_mode="cond_batch"))
    ctrl = ThresholdController(cfg, (1.0, 2.0), artifact_dir=str(tmp_path))
    eng = _drive(cfg, model, params, "device", n_req=6, max_new=8,
                 autotune=ctrl)
    assert ctrl.resolves >= 1 and ctrl.pushes >= 1
    st = eng.stats()["autotune"]
    assert st["controller"]["resolves"] == ctrl.resolves
    assert st["thresholds"] == list(eng.current_thresholds())
    assert eng.loop._jitted._cache_size() == 1      # pushes never retrace
    art = load_artifact(str(tmp_path), cfg)
    assert art is not None
    assert tuple(art.thresholds) == tuple(ctrl.thresholds)
    # warm start: a new engine begins at the artifact's thresholds
    ctrl2 = ThresholdController(cfg, (1.0, 2.0),
                                artifact_dir=str(tmp_path))
    eng2 = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                n_lanes=1, cache_len=32, autotune=ctrl2)
    assert eng2.current_thresholds() == tuple(art.thresholds)


def test_controller_min_sample_and_hysteresis_guards(tiny_model):
    model, params = tiny_model
    cfg = _tiny_autotune(cascade=dict(thresholds=(0.5, 0.0),
                                      exit_mode="cond_batch"))

    class FakeEngine:
        def __init__(self, tel):
            self._tel = tel
            self.pushed = []

        def lane_telemetry(self):
            return [self._tel]

        def current_thresholds(self):
            return (0.5, 0.0)

        def push_thresholds(self, ths):
            self.pushed.append(tuple(ths))

    # thin evidence: below min_shadow -> no resolve
    tel = init_telemetry(2, cfg.autotune.bins, mac_weights=(1.0, 2.0))
    ctrl = ThresholdController(cfg, (1.0, 2.0), min_shadow=10**6)
    assert ctrl.update(FakeEngine(telemetry_to_host(tel))) is None
    assert ctrl.resolves == 0
    # hysteresis: a solve that lands where we already are is not pushed
    rng = np.random.default_rng(0)
    B = 512
    conf = rng.random((2, B))
    pred = np.zeros((2, B), np.int32)            # always agree
    tel = accumulate_prefill(tel, pack_rider(jnp.asarray(pred),
                                             jnp.asarray(conf),
                                             cfg.autotune.bins),
                             jnp.ones((B,), bool))
    host = telemetry_to_host(tel)
    ctrl = ThresholdController(cfg, (1.0, 2.0), min_shadow=1,
                               hysteresis=10.0)   # nothing moves this far
    fe = FakeEngine(host)
    assert ctrl.update(fe) is None
    assert ctrl.resolves == 1 and ctrl.skipped_small == 1 and not fe.pushed
    # force bypasses hysteresis but not the evidence requirement
    assert ctrl.update(fe, force=True) is not None
    assert fe.pushed


def test_controller_drift_reset_is_persistent():
    """A detected distribution shift discards the pre-drift history from
    that resolve AND all later ones — not just the one that noticed."""
    cfg = _tiny_autotune(epsilon=0.05, mac_budget=0.0)
    bins = cfg.autotune.bins

    def window(conf_bin, agree_pairs):
        """One telemetry window: live conf mass at ``conf_bin``, shadow
        mass given as [(bin, count, agree_count), ...]."""
        d = {"conf_hist": np.zeros((2, bins), np.float32),
             "exit_counts": np.zeros(2, np.float32),
             "mac_weights": np.asarray([1.0, 2.0], np.float32),
             "steps": np.float32(0), "mac_spent": np.float32(0),
             "shadow_count": np.zeros(bins, np.float32),
             "shadow_agree": np.zeros((1, bins), np.float32),
             "shadow_steps": np.float32(0)}
        d["conf_hist"][:, conf_bin] = 100.0
        for b, n, a in agree_pairs:
            d["shadow_count"][b] += n
            d["shadow_agree"][0, b] += a
            d["shadow_steps"] += n
        return d

    def plus(a, b):
        return {k: (a[k] if k == "mac_weights" else a[k] + b[k]) for k in a}

    class FakeEngine:
        cum = None

        def lane_telemetry(self):
            return [self.cum]

        def current_thresholds(self):
            return None

        def push_thresholds(self, ths):
            self.pushed = tuple(ths)

    # distribution A: confident-and-right at bin 14.  distribution B:
    # bin-14 confidence is now WRONG; the agreeing mass moved to bin 3
    # but not enough of it to clear ε — B-only calibration must refuse
    # early exits, while A-diluted data would still allow them.
    A = window(14, [(14, 2000, 2000)])
    B = window(3, [(14, 100, 0), (3, 900, 900)])
    ctrl = ThresholdController(cfg, (1.0, 2.0), min_shadow=1,
                               hysteresis=0.0)
    eng = FakeEngine()
    eng.cum = A
    assert ctrl.update(eng) is not None          # resolve 1: A only
    assert ctrl.thresholds[0] <= 14 / bins       # exits allowed
    eng.cum = plus(A, B)
    assert ctrl.update(eng) is not None          # resolve 2: drift -> B only
    assert ctrl.drift_resets == 1
    assert ctrl.thresholds[0] > 14 / bins        # exits refused
    eng.cum = plus(plus(A, B), B)
    ths3 = ctrl.update(eng)                      # resolve 3: still B only
    assert ctrl.drift_resets == 1                # no new drift
    assert ths3 is None or ths3[0] > 14 / bins   # stale A stays excluded
    assert ctrl.thresholds[0] > 14 / bins


# ---------------------------------------------------------------------------
# artifacts, holdout calibrator
# ---------------------------------------------------------------------------

def test_artifact_roundtrip_and_key_guard(tmp_path):
    cfg = _tiny_autotune()
    art = CalibrationArtifact(
        config_key=config_key(cfg), thresholds=(0.25, 0.0),
        direction="epsilon", target=0.05, bins=16,
        mac_prefix=(1.0, 2.0), agreement=0.97, avg_macs=1.4,
        shadow_steps=128.0, edges=(4,))
    path = save_artifact(str(tmp_path), art)
    assert os.path.exists(path)
    got = load_artifact(str(tmp_path), cfg)
    assert got == art
    with open(path) as f:
        assert json.load(f)["version"] == 1
    # a different cascade -> different key -> no artifact
    other = cfg.with_cascade(thresholds=(0.9, 0.0), exit_mode="select")
    assert config_key(other) == config_key(cfg)   # thresholds don't key
    other = cfg.with_cascade(confidence="entropy")
    assert load_artifact(str(tmp_path), other) is None
    # tampered key refuses
    with open(path) as f:
        raw = json.load(f)
    raw["config_key"] = "0" * 64
    with open(path, "w") as f:
        json.dump(raw, f)
    with pytest.raises(ValueError, match="calibrated for"):
        load_artifact(str(tmp_path), cfg)


def test_artifact_cross_process_warm_start(tmp_path):
    """The fleet contract: a solve saved by one process warm-starts a
    controller in another process that only shares the config SPEC.  The
    two processes never share objects — each reconstructs its ModelConfig
    independently, and config_key must land on the same digest."""
    def fresh_cfg():
        # an independent construction chain == "another process"
        return _tiny_autotune()

    cfg_a = fresh_cfg()
    art = CalibrationArtifact(
        config_key=config_key(cfg_a), thresholds=(0.375, 0.0),
        direction="epsilon", target=0.05, bins=16,
        mac_prefix=(1.0, 2.0), agreement=0.95, avg_macs=1.3,
        shadow_steps=512.0, edges=(6,), source="fleet")
    path = save_artifact(str(tmp_path), art)
    cfg_b = fresh_cfg()
    assert cfg_a is not cfg_b
    assert config_key(cfg_a) == config_key(cfg_b)
    ctrl = ThresholdController(cfg_b, (1.0, 2.0),
                               artifact_dir=str(tmp_path))
    assert ctrl.thresholds == (0.375, 0.0)
    assert ctrl.warm_artifact.source == "fleet"
    # pre-fleet artifact files carry no "source" key; they load with the
    # engine default (format is forward-compatible, not versioned away)
    with open(path) as f:
        raw = json.load(f)
    raw.pop("source")
    with open(path, "w") as f:
        json.dump(raw, f)
    assert load_artifact(str(tmp_path), cfg_a).source == "engine"


def test_config_key_ignores_ordering_and_nonsemantic_fields():
    """config_key is a digest of the cascade's calibration identity:
    insensitive to dict ordering (sort_keys by construction) and to every
    knob that does not change what the telemetry measures — serving
    shapes, dtype, thresholds (the OUTPUT of a solve), autotune guard
    settings.  Semantic knobs must change it."""
    cfg = _tiny_autotune()
    key = config_key(cfg)
    # ordering: the digest is over a sort_keys dump of the identity dict,
    # so any permutation of the same fields hashes identically
    import hashlib
    ident = {
        "version": 1,
        "name": cfg.name,
        "n_layers": cfg.n_layers,
        "vocab_size": cfg.vocab_size,
        "segments": [list(s) for s in cfg.segments],
        "n_components": cfg.cascade.n_components,
        "confidence": cfg.cascade.confidence,
        "bins": cfg.autotune.bins,
    }
    reordered = dict(reversed(list(ident.items())))
    assert (hashlib.sha256(
        json.dumps(reordered, sort_keys=True).encode()).hexdigest() == key)
    # non-semantic: same key
    assert config_key(cfg.replace(dtype="bfloat16")) == key
    assert config_key(cfg.replace(use_kernels=True)) == key
    assert config_key(cfg.with_cascade(thresholds=(0.9, 0.0),
                                       exit_mode="select")) == key
    assert config_key(cfg.with_autotune(epsilon=0.4, min_shadow=999,
                                        resolve_every=3)) == key
    # semantic: different key
    assert config_key(cfg.with_cascade(confidence="entropy")) != key
    assert config_key(cfg.with_autotune(bins=8)) != key
    assert config_key(cfg.replace(name="other")) != key


def test_threshold_for_epsilon_validation_split():
    """α* comes from the stats arrays; the threshold is picked on the
    validation curve — a validation set with worse tail accuracy forces a
    higher threshold than the stats set alone would."""
    conf = np.linspace(0.01, 1.0, 100)
    correct = (conf >= 0.5).astype(np.float64)
    th_self, a_star = threshold_for_epsilon(conf, correct, 0.0)
    assert a_star == 1.0 and th_self == pytest.approx(0.5)
    # validation says the 0.5-0.7 band is actually wrong
    val_correct = (conf >= 0.7).astype(np.float64)
    th_val, a_star2 = threshold_for_epsilon(conf, correct, 0.0,
                                            val_conf=conf,
                                            val_correct=val_correct)
    assert a_star2 == 1.0                       # still from the stats set
    assert th_val == pytest.approx(0.7)         # selected on validation
    with pytest.raises(ValueError, match="val_correct"):
        threshold_for_epsilon(conf, correct, 0.0, val_conf=conf)


def test_holdout_calibrator_registry_and_split():
    rng = np.random.default_rng(0)
    N = 4000
    conf = [rng.random(N), rng.random(N), np.ones(N)]
    corr = [(rng.random(N) < 0.3 + 0.7 * c).astype(np.float64)
            for c in conf[:-1]] + [np.ones(N)]
    res = calibrate_thresholds(conf, corr, 0.05, relative_to="holdout")
    assert len(res.thresholds) == 3 and res.thresholds[-1] == 0.0
    # explicit validation split is honored without internal splitting
    res2 = get_calibrator("holdout@0.3").calibrate(
        conf, corr, 0.05, val_confidences=conf, val_corrects=corr)
    assert len(res2.thresholds) == 3
    # bad specs refuse
    with pytest.raises(ValueError):
        get_calibrator("holdout@1.5")
    with pytest.raises(ValueError):
        get_calibrator("holdout@0.5:bogus")
