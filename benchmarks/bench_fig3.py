"""Figure 3 reproduction: accuracy vs average MACs curve swept over
ε ∈ {20%, …, 1%, 0%} (the paper's grid) — now backed by a measured
wall-clock column: the calibrated thresholds are re-run through a staged
evaluation where deeper components only process still-undecided samples,
so the reported speedup is elapsed time, not just analytic MACs."""
import numpy as np

from benchmarks._shared import N_CLASSES, trained_cascade
from repro.core.policy import get_calibrator
from repro.core.resnet_trainer import (collect_logits, evaluate_tradeoff,
                                       evaluate_wallclock, score_logits)

EPSILONS = [0.20, 0.15, 0.10, 0.08, 0.06, 0.04, 0.02, 0.01, 0.0]
WALLCLOCK_EPSILONS = (0.10, 0.02)


def run(quick: bool = False):
    model, report, (train, val, test) = trained_cascade()
    epsilons = EPSILONS[::4] if quick else EPSILONS
    sweep = evaluate_tradeoff(model, report.params, report.state, val, test,
                              epsilons, N_CLASSES,
                              measure="softmax_max", calibrator="self")
    rows = []
    accs, macs = [], []
    for eps, res in sweep:
        rows.append((f"fig3/eps={eps:g}", 0.0,
                     f"acc={res.accuracy:.4f};macs={res.avg_macs:.3g}"))
        accs.append(res.accuracy)
        macs.append(res.avg_macs)
    # the paper's qualitative claim: the curve is monotone — less compute,
    # (weakly) less accuracy
    order = np.argsort(macs)
    mono = all(np.diff(np.array(accs)[order]) >= -0.02)  # noise tolerance
    rows.append(("fig3/monotone_tradeoff", 0.0, str(mono)))

    # measured wall-clock at representative ε's: calibrate on val, then time
    # the staged evaluation (deep components see only undecided samples)
    logits_v = collect_logits(model, report.params, report.state, val)
    conf_v, _, corr_v = score_logits(logits_v, val.labels)
    calibrator = get_calibrator("self")
    wc_epsilons = WALLCLOCK_EPSILONS[:1] if quick else WALLCLOCK_EPSILONS
    for eps in wc_epsilons:
        cal = calibrator.calibrate(conf_v, corr_v, eps)
        wc = evaluate_wallclock(model, report.params, report.state, test,
                                cal.thresholds, repeats=1 if quick else 3)
        rows.append((f"fig3/wallclock/eps={eps:g}",
                     wc["t_staged_s"] * 1e6,
                     f"wallclock_speedup={wc['wallclock_speedup']:.3f};"
                     f"exit_fracs={np.round(wc['exit_fractions'], 3).tolist()}"))
    return rows
