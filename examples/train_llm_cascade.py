"""End-to-end LLM driver: train a (reduced) cascade LLM on the synthetic
Markov stream for a few hundred steps with the joint multi-exit loss, then
calibrate confidence thresholds per §5 on held-out tokens and report the
exit distribution + analytic decode speedup at each ε.

This is the paper's full method transplanted onto an autoregressive LM:
difficulty structure in the stream (Markov vs noise positions) is what the
cascade exploits.

    PYTHONPATH=src python examples/train_llm_cascade.py --arch xlstm-350m \
        --steps 300
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.cascade import cascade_evaluate
from repro.core.policy import get_calibrator
from repro.core.confidence import softmax_outputs
from repro.core.macs import segment_macs_per_token
from repro.data.lm_pipeline import SyntheticLMStream
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.model import build_model
from repro.utils import get_logger

log = get_logger("train_llm_cascade")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch)).replace(
        dtype="float32", vocab_size=args.vocab)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = make_optimizer(cfg)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, cfg, opt))
    stream = SyntheticLMStream(cfg.vocab_size, args.seq, args.batch,
                               easy_frac=0.7, seed=0)
    for step, (toks, labels) in zip(range(args.steps), stream):
        batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labels)}
        params, opt_state, loss = step_fn(params, opt_state,
                                          jnp.asarray(step), batch)
        if step % 50 == 0:
            log.info("step %d loss %.4f", step, float(loss))

    # --- calibration (§5) on held-out tokens, per exit -------------------
    fwd = jax.jit(lambda p, t: model.forward_train(p, t)[0])
    confs, preds, labels_all = [[], [], []], [[], [], []], []
    n_ex = cfg.cascade.n_components
    confs, preds = [[] for _ in range(n_ex)], [[] for _ in range(n_ex)]
    for _ in range(4):
        toks, labels = next(stream)
        logits = fwd(params, jnp.asarray(toks))
        for m in range(n_ex):
            out, delta = softmax_outputs(logits[m])
            confs[m].append(np.asarray(delta).reshape(-1))
            preds[m].append(np.asarray(out).reshape(-1))
        labels_all.append(labels.reshape(-1))
    confs = [np.concatenate(c) for c in confs]
    preds = [np.concatenate(p) for p in preds]
    y = np.concatenate(labels_all)
    corrects = [(p == y).astype(float) for p in preds]
    n_cal = len(y) // 2
    mac_prefix = segment_macs_per_token(cfg, kv_len=args.seq)

    print(f"\nper-exit accuracy: "
          f"{[float(np.mean(c)) for c in corrects]}")
    print(f"{'rule':>6} {'eps':>6} {'acc':>8} {'speedup':>8} "
          f"{'thresholds':>22} exit%")
    for rule in ("self", "final"):          # §5 vs beyond-paper cascade-level
        calibrator = get_calibrator(rule)
        for eps in (0.0, 0.01, 0.05, 0.1, 0.2):
            cal = calibrator.calibrate([c[:n_cal] for c in confs],
                                       [c[:n_cal] for c in corrects], eps)
            res = cascade_evaluate([c[n_cal:] for c in confs],
                                   [p[n_cal:] for p in preds], y[n_cal:],
                                   mac_prefix, cal.thresholds)
            print(f"{rule:>6} {eps:6.2f} {res.accuracy:8.4f} "
                  f"{res.speedup:8.3f} "
                  f"{np.round(cal.thresholds, 3)!s:>22} "
                  f"{np.round(res.exit_fractions, 3)}")


if __name__ == "__main__":
    main()
