"""Fleet-tier benchmark: merged-telemetry solve + drain/migration.

Four measurements, persisted to ``BENCH_serving.json`` (under ``fleet``)
by ``benchmarks/run.py`` and gated by ``scripts/check_bench_serving.py``:

* **merged solve == pooled solve** — per-engine fixed-bin histograms
  merged with :func:`repro.autotune.merge_histograms` must reproduce the
  pooled-sample histogram and its epsilon/budget solves EXACTLY (bin
  counts sum — ``bincount(a ++ b) == bincount(a) + bincount(b)``); the
  gate is equality, not tolerance.

* **warm-up** — a 4-engine fleet under one
  :class:`repro.fleet.TelemetryAggregator` reaches its first stable
  threshold push when each member has contributed only ~1/4 of the
  ``min_shadow`` evidence window; a single engine solving alone needs the
  whole window itself.  Gate: the busiest member's shadow evidence at the
  fleet's first push is <= 1/3 of what the single engine needed — the
  acceptance criterion's "1/3 the shadow samples of any single engine
  solving alone".

* **streams identical after push** — once thresholds match, an engine
  that received them through the fleet's ``push_thresholds`` fan-out
  decodes bit-identical streams to an engine pushed directly (the fleet
  adds routing, never semantics).

* **drain** — draining one member of the 4-engine fleet mid-decode
  (``mode="migrate"``) finishes every submitted request with zero drops
  and zero lost tokens: committed prefixes replay into siblings through
  PR 7's ``build_replay`` and every final stream starts with the exact
  tokens the drained member had already committed.
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.fleet import FleetScheduler, TelemetryAggregator
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

N_ENGINES = 4
BINS = 16
MAC_PREFIX = (1.0, 2.0, 3.0)

# set by run(): machine-readable summary merged into BENCH_serving.json
LAST_FLEET_SUMMARY = None


def _cfg(autotune: bool, min_shadow: int = 0):
    cfg = (reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
           .with_cascade(thresholds=(0.5, 0.0), exit_mode="cond_batch"))
    if autotune:
        cfg = cfg.with_autotune(enabled=True, bins=BINS, shadow_every=2,
                                min_shadow=min_shadow, resolve_every=4)
    return cfg.with_fleet(n_engines=N_ENGINES, drain_mode="migrate")


def _engine(cfg, model, params, **kw):
    kw.setdefault("lane_batch", 2)
    kw.setdefault("n_lanes", 1)
    kw.setdefault("cache_len", 64)
    return CascadeServingEngine(cfg, model, params, **kw)


def _requests(cfg, n, max_new, seed=7):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                        np.int32),
                    max_new_tokens=max_new) for i in range(n)]


def _merged_vs_pooled() -> bool:
    """Per-engine histograms merged vs the pooled-sample histogram:
    exact count equality AND exact solve equality, both directions."""
    from repro.autotune import (ExitHistogram, merge_histograms,
                                solve_budget, solve_epsilon)
    rng = np.random.default_rng(0)
    shards, confs, agrees = [], [], []
    for _ in range(N_ENGINES):
        c = rng.random((2, 4000))
        a = (rng.random((2, 4000)) < 0.25 + 0.7 * c).astype(np.float64)
        shards.append(ExitHistogram.from_samples(c, a, MAC_PREFIX, BINS))
        confs.append(c)
        agrees.append(a)
    merged = merge_histograms(shards)
    pooled = ExitHistogram.from_samples(np.concatenate(confs, axis=1),
                                        np.concatenate(agrees, axis=1),
                                        MAC_PREFIX, BINS)
    ok = (np.array_equal(merged.counts, pooled.counts)
          and np.array_equal(merged.agree, pooled.agree))
    for eps in (0.02, 0.05, 0.1):
        ok = ok and (solve_epsilon(merged, eps).edges
                     == solve_epsilon(pooled, eps).edges)
    for budget in (1.8, 2.2):
        ok = ok and (solve_budget(merged, budget).edges
                     == solve_budget(pooled, budget).edges)
    return ok


def _drive_until_push(stepper, pushed, max_ticks=600):
    """Step until ``pushed()`` reports a push; ticks spent, or -1."""
    for tick in range(max_ticks):
        stepper()
        if pushed():
            return tick + 1
    return -1


def _warmup(model, params, min_shadow: int) -> dict:
    """Fleet-of-4 vs single-engine shadow evidence at the first push."""
    from repro.autotune import ThresholdController, merge_telemetry
    cfg = _cfg(autotune=True, min_shadow=min_shadow)

    members = [_engine(cfg, model, params) for _ in range(N_ENGINES)]
    agg = TelemetryAggregator(cfg, members[0].mac_prefix,
                              resolve_every=4, min_shadow=min_shadow,
                              hysteresis=0.0)
    fleet = FleetScheduler(members, aggregator=agg)
    for req in _requests(cfg, 4 * N_ENGINES, max_new=40):
        fleet.submit(req)
    fleet_ticks = _drive_until_push(fleet.step, lambda: agg.pushes > 0)
    per_member = agg.per_member_shadow(fleet)
    fleet_shadow = max(per_member) if per_member else 0.0

    ctrl = ThresholdController(cfg, members[0].mac_prefix,
                               resolve_every=4, min_shadow=min_shadow,
                               hysteresis=0.0)
    single = _engine(cfg, model, params, autotune=ctrl)
    for req in _requests(cfg, 8, max_new=40):
        single.submit(req)
    single_ticks = _drive_until_push(single.step,
                                     lambda: ctrl.pushes > 0)
    tels = single.lane_telemetry()
    single_shadow = (float(merge_telemetry(tels)["shadow_steps"])
                     if tels else 0.0)

    ratio = fleet_shadow / single_shadow if single_shadow else float("inf")
    return {
        "min_shadow": min_shadow,
        "fleet_ticks_to_first_push": fleet_ticks,
        "single_ticks_to_first_push": single_ticks,
        "fleet_max_member_shadow_at_first_push": fleet_shadow,
        "single_shadow_at_first_push": single_shadow,
        "warmup_ratio": ratio,
        "fleet_pushes": agg.pushes,
        "thresholds": (list(agg.thresholds)
                       if agg.thresholds is not None else None),
    }


def _streams_after_push(model, params, thresholds) -> bool:
    """Fan-out push vs direct push: identical streams on identical
    traffic (deterministic host runtime, same params)."""
    cfg = _cfg(autotune=True)
    direct = _engine(cfg, model, params)
    direct.push_thresholds(thresholds)
    for req in _requests(cfg, 6, max_new=8, seed=11):
        direct.submit(req)
    direct.run(300)

    member = _engine(cfg, model, params)
    fleet = FleetScheduler([member])
    fleet.push_thresholds(thresholds)
    for req in _requests(cfg, 6, max_new=8, seed=11):
        fleet.submit(req)
    fleet.run(300)
    return all(fleet.finished[rid]["tokens"] == direct.finished[rid]
               ["tokens"] for rid in direct.finished)


def _drain(model, params, n_requests: int) -> dict:
    """Drain one member of a 4-engine fleet mid-decode; zero drops, zero
    lost tokens (committed prefixes preserved verbatim)."""
    cfg = _cfg(autotune=False)
    fleet = FleetScheduler([_engine(cfg, model, params)
                            for _ in range(N_ENGINES)])
    max_new = 10
    for req in _requests(cfg, n_requests, max_new=max_new):
        fleet.submit(req)
    for _ in range(3):
        fleet.step()
    committed = {}
    for ln in fleet.members[0].lanes:
        for s in ln["slots"]:
            if not s.done and s.request is not None:
                committed[s.request.rid] = list(s.generated)
    summary = fleet.drain(0, mode="migrate")
    fleet.run(600)
    st = fleet.stats()
    preserved = all(
        fleet.finished[rid]["tokens"][:len(pre)] == pre
        and len(fleet.finished[rid]["tokens"]) == max_new
        for rid, pre in committed.items())
    return {
        "submitted": n_requests,
        "finished": st["requests_finished"],
        "dropped": n_requests - st["requests_finished"],
        "requeued": len(summary["requeued"]),
        "migrated": len(summary["migrated"]),
        "completed_at_drain": len(summary["completed"]),
        "prefix_preserved": bool(preserved),
        "discarded_tokens": st["discarded_tokens"],
        "drained": 0 in fleet.drained,
    }


def run(quick: bool = False):
    global LAST_FLEET_SUMMARY
    rows = []
    cfg = _cfg(autotune=False)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    t0 = time.perf_counter()
    exact = _merged_vs_pooled()
    rows.append(("fleet/merged_solve", (time.perf_counter() - t0) * 1e6,
                 f"matches_pooled={exact}"))

    t0 = time.perf_counter()
    warm = _warmup(model, params, min_shadow=24 if quick else 48)
    rows.append(("fleet/warmup", (time.perf_counter() - t0) * 1e6,
                 f"ratio={warm['warmup_ratio']:.3f}"))

    t0 = time.perf_counter()
    streams = (_streams_after_push(model, params, warm["thresholds"])
               if warm["thresholds"] is not None else False)
    rows.append(("fleet/streams_after_push",
                 (time.perf_counter() - t0) * 1e6,
                 f"identical={streams}"))

    t0 = time.perf_counter()
    drain = _drain(model, params, n_requests=8 if quick else 12)
    rows.append(("fleet/drain", (time.perf_counter() - t0) * 1e6,
                 f"dropped={drain['dropped']},"
                 f"migrated={drain['migrated']}"))

    LAST_FLEET_SUMMARY = {
        "n_engines": N_ENGINES,
        "merged_solve_matches_pooled": bool(exact),
        "warmup": warm,
        "streams_identical_after_push": bool(streams),
        "drain": drain,
    }
    return rows
