"""Kernel autotuner: sweep invariants, tile registry, artifact round-trip.

The load-bearing contracts:

* ``tuned_speedup >= 1.0`` on every bench row BY CONSTRUCTION (the default
  tiles are always in the candidate set and both timings come from the same
  sweep) — the BENCH gate relies on this;
* installed tiles flow through the ``kernels/ops.py`` wrappers;
* artifacts round-trip through disk keyed by the tune key, and a key
  mismatch falls back to default tiles WITH a warning (stale tiles are
  never silently installed).
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import KernelTuneConfig, get_config, reduced
from repro.kernels import autotune as at
from repro.kernels.ops import rmsnorm_fused


def _small(monkeypatch):
    """Restrict the sweep to two cheap kernels (test-speed only)."""
    keep = ("rmsnorm", "paged_gather")
    monkeypatch.setattr(at, "DEFAULT_TILES",
                        {k: at.DEFAULT_TILES[k] for k in keep})


def test_sweep_rows_speedup_and_provenance(monkeypatch):
    _small(monkeypatch)
    winners, rows = at.sweep(reps=1)
    assert set(winners) == {"rmsnorm", "paged_gather"}
    assert rows
    for r in rows:
        assert r["tuned_speedup"] >= 1.0, r
        assert r["backend"] in ("interpret", "compiled")
        assert r["platform"]
        assert r["default_us"] > 0 and r["tuned_us"] > 0
        assert r["tiles"] == winners[r["kernel"]]


def test_tile_registry_install_and_reset():
    assert at.tile("rmsnorm", "rt") == 8
    at.install_tiles({"rmsnorm": {"rt": 32}})
    assert at.tile("rmsnorm", "rt") == 32
    # untouched kernels keep their defaults
    assert at.tile("exit_update", "vt") == at.DEFAULT_TILES["exit_update"]["vt"]
    # the ops-layer wrapper actually consumes the installed tile (same
    # output bits — rmsnorm is row-wise, tiling only regroups rows)
    x = jnp.asarray(np.random.default_rng(0).standard_normal((48, 64)),
                    jnp.float32)
    w = jnp.ones((64,), jnp.float32)
    tuned = rmsnorm_fused(x, w, interpret=True)
    at.reset_tiles()
    assert at.tile("rmsnorm", "rt") == 8
    np.testing.assert_array_equal(
        np.asarray(tuned), np.asarray(rmsnorm_fused(x, w, interpret=True)))


def test_artifact_roundtrip_and_load_skips_sweep(tmp_path, monkeypatch):
    _small(monkeypatch)
    art = at.ensure_tuned(artifact_dir=str(tmp_path), reps=1)
    path = at.tile_artifact_path(str(tmp_path), art.config_key)
    with open(path) as f:
        on_disk = at.TileArtifact.from_json(json.load(f))
    assert on_disk.tiles == art.tiles
    assert on_disk.config_key == art.config_key == at.tune_key()
    assert all(r["tuned_speedup"] >= 1.0 for r in on_disk.rows)

    # second call must LOAD, not re-sweep
    def boom(*a, **k):
        raise AssertionError("re-swept despite a matching artifact")
    monkeypatch.setattr(at, "sweep", boom)
    art2 = at.ensure_tuned(artifact_dir=str(tmp_path), reps=1)
    assert art2.tiles == art.tiles
    assert at.current_tiles() == art.tiles


def test_mismatched_key_warns_and_falls_back(tmp_path, caplog):
    key = at.tune_key()
    stale = at.TileArtifact(
        config_key="0" * 64, platform="tpu", interpret=False, shapes="tiny",
        tiles={"rmsnorm": {"rt": 64}}, rows=[])
    # place the stale artifact exactly where this process would look
    path = at.tile_artifact_path(str(tmp_path), key)
    with open(path, "w") as f:
        json.dump(stale.to_json(), f)
    with caplog.at_level("WARNING"):
        assert at.load_tile_artifact(str(tmp_path)) is None
    assert any("falling back to default tiles" in r.getMessage()
               for r in caplog.records)
    # and nothing was installed
    assert at.tile("rmsnorm", "rt") == 8


def test_artifact_version_check():
    d = at.TileArtifact(config_key="x", platform="cpu", interpret=True,
                        shapes="tiny", tiles={}, rows=[]).to_json()
    d["version"] = at.TILE_ARTIFACT_VERSION + 1
    with pytest.raises(ValueError, match="version"):
        at.TileArtifact.from_json(d)


def test_kernel_tune_config():
    cfg = reduced(get_config("qwen2.5-3b"))
    assert cfg.kernel_tune == KernelTuneConfig()
    assert not cfg.kernel_tune.enabled
    on = cfg.with_kernel_tune(enabled=True, megakernel=True,
                              cohort_scatter=True, shapes="serving")
    assert on.kernel_tune.enabled and on.kernel_tune.megakernel
    assert on.kernel_tune.cohort_scatter
    assert cfg.kernel_tune == KernelTuneConfig()  # frozen, not mutated
    with pytest.raises(ValueError):
        KernelTuneConfig(shapes="huge")
