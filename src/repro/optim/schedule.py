"""Learning-rate schedules, including the [HZRS15a] CIFAR schedule the paper cites."""
from __future__ import annotations

import jax.numpy as jnp


def constant_schedule(lr: float):
    def fn(step):
        return jnp.asarray(lr, jnp.float32)
    return fn


def resnet_paper_schedule(base_lr: float = 0.1, total_steps: int = 64000,
                          warmup_steps: int = 0, warmup_lr: float = 0.01):
    """[HZRS15a] §4.2 schedule: lr 0.1, /10 at 50% and 75% of training.

    He et al. additionally warm up ResNet-110 with lr 0.01 until the loss
    drops; we expose a fixed warmup window for the same purpose.
    """
    b1 = int(0.5 * total_steps)
    b2 = int(0.75 * total_steps)

    def fn(step):
        step = jnp.asarray(step)
        lr = jnp.where(step < b1, base_lr,
                       jnp.where(step < b2, base_lr * 0.1, base_lr * 0.01))
        if warmup_steps:
            lr = jnp.where(step < warmup_steps, warmup_lr, lr)
        return lr.astype(jnp.float32)

    return fn


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(jnp.asarray(step, jnp.float32) / max(1, total_steps), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return base_lr * (final_frac + (1 - final_frac) * cos)
    return fn


def warmup_cosine(base_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(1, total_steps - warmup_steps), final_frac)

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(1, warmup_steps)
        return jnp.where(step < warmup_steps, warm, cos(step - warmup_steps))
    return fn
