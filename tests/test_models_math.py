"""Math-level tests: chunked SSD vs sequential recurrence, chunked mLSTM vs
sequential recurrence, chunked attention vs full, MoE routing invariants.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import attend_chunked, attend_chunked_2d, attend_full
from repro.models.moe import capacity, route_topk
from repro.models.ssm import ssd_chunked
from repro.models.xlstm import mlstm_chunked

RNG = np.random.default_rng(7)


def _seq_ssd(x, dt, A, B, C):
    """Sequential oracle for the SSD recurrence."""
    Bsz, S, h, p = x.shape
    n = B.shape[-1]
    state = np.zeros((Bsz, h, p, n))
    ys = np.zeros((Bsz, S, h, p))
    x = np.asarray(x, np.float64) * np.asarray(dt)[..., None]
    dA = np.exp(np.asarray(dt, np.float64) * np.asarray(A))
    for t in range(S):
        state = state * dA[:, t][:, :, None, None] + np.einsum(
            "bhp,bn->bhpn", x[:, t], np.asarray(B)[:, t])
        ys[:, t] = np.einsum("bn,bhpn->bhp", np.asarray(C)[:, t], state)
    return ys, state


@pytest.mark.parametrize("S,chunk", [(32, 8), (64, 16), (40, 8)])
def test_ssd_chunked_matches_sequential(S, chunk):
    Bsz, h, p, n = 2, 3, 4, 5
    if S % chunk:
        pytest.skip("chunk must divide S for the direct call")
    x = jnp.asarray(RNG.standard_normal((Bsz, S, h, p)), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.5, (Bsz, S, h)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(RNG.standard_normal((Bsz, S, n)), jnp.float32)
    C = jnp.asarray(RNG.standard_normal((Bsz, S, n)), jnp.float32)
    y, state = ssd_chunked(x, dt, A, B, C, chunk)
    y_ref, state_ref = _seq_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state), state_ref, rtol=2e-4,
                               atol=2e-4)


def _seq_mlstm(q, k, v, i_pre, f_pre):
    """Sequential stabilized mLSTM oracle."""
    B, S, h, p = q.shape
    scale = 1.0 / np.sqrt(p)
    q = np.asarray(q, np.float64) * scale
    k = np.asarray(k, np.float64)
    v = np.asarray(v, np.float64)
    logf = -np.log1p(np.exp(-np.asarray(f_pre, np.float64)))
    i = np.asarray(i_pre, np.float64)
    C = np.zeros((B, h, p, p))
    n = np.zeros((B, h, p))
    m = np.full((B, h), -1e30)
    out = np.zeros((B, S, h, p))
    for t in range(S):
        m_new = np.maximum(logf[:, t] + m, i[:, t])
        wf = np.exp(logf[:, t] + m - m_new)
        wi = np.exp(i[:, t] - m_new)
        C = wf[..., None, None] * C + wi[..., None, None] * np.einsum(
            "bhp,bhd->bhpd", k[:, t], v[:, t])
        n = wf[..., None] * n + wi[..., None] * k[:, t]
        num = np.einsum("bhp,bhpd->bhd", q[:, t], C)
        qn = np.einsum("bhp,bhp->bh", q[:, t], n)
        denom = np.maximum(np.abs(qn), np.exp(-m_new))
        out[:, t] = num / denom[..., None]
        m = m_new
    return out, (C, n, m)


@pytest.mark.parametrize("S,chunk", [(32, 8), (48, 16)])
def test_mlstm_chunked_matches_sequential(S, chunk):
    B, h, p = 2, 2, 8
    q = jnp.asarray(RNG.standard_normal((B, S, h, p)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((B, S, h, p)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((B, S, h, p)), jnp.float32)
    i_pre = jnp.asarray(RNG.standard_normal((B, S, h)), jnp.float32)
    f_pre = jnp.asarray(RNG.standard_normal((B, S, h)) + 2, jnp.float32)
    hid, (C, n, m) = mlstm_chunked(q, k, v, i_pre, f_pre, chunk)
    hid_ref, (C_ref, n_ref, m_ref) = _seq_mlstm(q, k, v, i_pre, f_pre)
    np.testing.assert_allclose(np.asarray(hid), hid_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(C), C_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(m), m_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 3), st.sampled_from([128, 256]),
       st.sampled_from([0, 32]), st.integers(0, 2 ** 31 - 1))
def test_chunked_attention_property(B, S, window, seed):
    rng = np.random.default_rng(seed)
    H = KV = 2
    hd = 16
    q = jnp.asarray(rng.standard_normal((B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, hd)), jnp.float32)
    pos = jnp.arange(S)
    o_full = attend_full(q, k, v, pos, pos, window=window)
    o_chunk = attend_chunked(q, k, v, pos, pos, window=window, chunk=64)
    o_2d = attend_chunked_2d(q, k, v, pos, pos, window=window,
                             qchunk=64, kchunk=32)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_chunk),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(o_full), np.asarray(o_2d),
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE routing invariants
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(8, 64), st.sampled_from([4, 8]), st.integers(1, 2),
       st.integers(0, 2 ** 31 - 1))
def test_route_topk_invariants(T, E, k, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)
    cap = capacity(T, E, k, 1.25)
    dispatch, combine, aux = route_topk(logits, k, cap)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    # each (expert, slot) holds at most one token
    assert (d.sum(axis=0) <= 1 + 1e-6).all()
    # each token dispatched at most k times, combine weights in [0, 1]
    assert (d.sum(axis=(1, 2)) <= k + 1e-6).all()
    assert (c >= -1e-6).all()
    assert (c.sum(axis=(1, 2)) <= 1 + 1e-6).all()
    # combine nonzero only where dispatch is
    assert (np.abs(c[d == 0]) < 1e-6).all()
    assert np.isfinite(float(aux))


def test_route_topk_no_drop_when_capacity_ample():
    rng = np.random.default_rng(0)
    T, E, k = 32, 4, 2
    logits = jnp.asarray(rng.standard_normal((T, E)), jnp.float32)
    dispatch, combine, _ = route_topk(logits, k, cap=T * k)
    d = np.asarray(dispatch)
    c = np.asarray(combine)
    assert np.allclose(d.sum(axis=(1, 2)), k)        # all k slots dispatched
    np.testing.assert_allclose(c.sum(axis=(1, 2)), 1.0, rtol=1e-5)
