"""The live re-tuning loop: telemetry in, thresholds out.

:class:`ThresholdController` closes the loop the rest of the subsystem
opens: every ``autotune.resolve_every`` engine ticks it merges the lanes'
device-resident telemetry (one batched device_get — telemetry never adds a
per-chunk host sync), builds the joint histogram, runs the coordinate-
descent solver in the configured direction (accuracy budget ε or average-
MAC budget), and pushes the resolved thresholds into the running engine as
plain arrays.  Thresholds are *data* in the carried
:class:`~repro.core.exec.DecodeState` — a push is ``state.replace(...)``
with an identically-shaped array, so the jitted decode programs (host step
and device while_loop alike) never retrace.

Three guards keep a live fleet stable:

* **min-sample** — no resolve until ``min_shadow`` shadow observations
  have accumulated since the last one (thresholds from thin evidence
  oscillate);
* **hysteresis** — a solve whose thresholds moved less than
  ``hysteresis`` from the deployed vector is recorded but not pushed
  (churn costs scheduler warm-up, buys nothing);
* **drift** — the controller compares consecutive resolve windows'
  normalized confidence histograms; when the L1 distance exceeds
  ``drift_tol`` the traffic has shifted and the accumulated history no
  longer describes it, so the solve uses the fresh window only.

With ``artifact_dir`` set, each pushed resolution is persisted as a
config-hash-keyed artifact (:mod:`repro.autotune.artifacts`) and the
constructor warm-starts from a matching artifact if one exists — a
restarted fleet begins at its last calibration, not at the config's
static thresholds.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

from repro.autotune.artifacts import (CalibrationArtifact, config_key,
                                      load_artifact, save_artifact)
from repro.autotune.solver import ExitHistogram, solve_budget, solve_epsilon
from repro.autotune.telemetry import merge_telemetry
from repro.utils import get_logger

log = get_logger("autotune")


class ThresholdController:
    """Periodic telemetry → solver → threshold-push loop for one engine.

    Built either directly or via ``CascadeServingEngine(autotune=True)``;
    defaults come from ``cfg.autotune``.  ``mac_budget > 0`` selects the
    budget direction, else the ε direction.  The engine calls
    :meth:`maybe_update` once per tick; everything else is internal.

    The "engine" the controller drives only needs the three-method surface
    ``lane_telemetry()`` / ``current_thresholds()`` / ``push_thresholds()``
    — :class:`repro.fleet.TelemetryAggregator` subclasses this controller
    and attaches it to a whole :class:`~repro.fleet.FleetScheduler`
    through exactly that surface (``source`` marks the artifacts it
    writes).
    """

    # artifact provenance tag; the fleet aggregator overrides with "fleet"
    source = "engine"

    def __init__(self, cfg, mac_prefix, *, epsilon: Optional[float] = None,
                 mac_budget: Optional[float] = None,
                 resolve_every: Optional[int] = None,
                 min_shadow: Optional[int] = None,
                 hysteresis: Optional[float] = None,
                 drift_tol: Optional[float] = None,
                 artifact_dir: Optional[str] = None):
        at = cfg.autotune
        self.cfg = cfg
        self.mac_prefix = tuple(float(m) for m in mac_prefix)
        self.epsilon = at.epsilon if epsilon is None else float(epsilon)
        self.mac_budget = (at.mac_budget if mac_budget is None
                           else float(mac_budget))
        self.resolve_every = (at.resolve_every if resolve_every is None
                              else int(resolve_every))
        self.min_shadow = at.min_shadow if min_shadow is None else min_shadow
        self.hysteresis = (at.hysteresis if hysteresis is None
                           else float(hysteresis))
        self.drift_tol = at.drift_tol if drift_tol is None else drift_tol
        self.artifact_dir = artifact_dir
        self._tick = 0
        self._snapshot = None          # cumulative host telemetry @ last solve
        self._prev_window_conf = None  # normalized conf_hist of last window
        self._drift_base = None        # counters excluded from every solve
                                       # (cumulative @ the last drift reset)
        self.resolves = 0
        self.pushes = 0
        self.skipped_small = 0
        self.drift_resets = 0
        self.last_result = None
        self.last_shadow = 0.0         # shadow evidence behind the last push
        self.thresholds: Optional[Tuple[float, ...]] = None
        self.warm_artifact = None
        if artifact_dir:
            art = load_artifact(artifact_dir, cfg)
            if art is not None:
                self.warm_artifact = art
                self.thresholds = art.thresholds
                log.info("warm-started thresholds %s from artifact "
                         "(key %s...)", art.thresholds, art.config_key[:12])

    @property
    def direction(self) -> str:
        return "macs" if self.mac_budget else "epsilon"

    # ------------------------------------------------------------------
    def attach(self, engine) -> None:
        """Called once by the engine at construction: push the warm-start
        artifact's thresholds (if any) before the first request."""
        if self.thresholds is not None:
            engine.push_thresholds(self.thresholds)
            self.pushes += 1

    def maybe_update(self, engine):
        """One engine tick.  Returns the pushed thresholds, or None."""
        self._tick += 1
        if self._tick % self.resolve_every:
            return None
        return self.update(engine)

    # ------------------------------------------------------------------
    def _normalized_shadow(self, tel: dict) -> Optional[np.ndarray]:
        """Normalized joint shadow histogram of a window — the drift
        signal.  Shadow observations are full-depth and threshold-
        independent, so the controller's own threshold pushes (which
        reshape the live conf_hist populations) can never masquerade as
        traffic drift."""
        h = np.asarray(tel["shadow_count"], np.float64)
        tot = h.sum()
        if tot <= 0:
            return None
        return h / tot

    @staticmethod
    def _minus(cum: dict, base: Optional[dict]) -> dict:
        if base is None:
            return cum
        return {k: (cum[k] if k == "mac_weights" else cum[k] - base[k])
                for k in cum}

    def update(self, engine, force: bool = False):
        """Merge telemetry, solve, guard, push.  ``force`` bypasses the
        min-sample and hysteresis guards (the calibrate CLI's final
        resolve) — it cannot conjure evidence, so zero shadow samples
        still refuse."""
        tels = engine.lane_telemetry()
        if not tels:
            return None
        cum = merge_telemetry(tels)
        window = self._minus(cum, self._snapshot)
        fresh = float(window["shadow_steps"])
        if float(cum["shadow_steps"]) <= 0:
            return None                      # force cannot conjure evidence
        if not force and fresh < self.min_shadow:
            return None

        wconf = self._normalized_shadow(window)
        if (wconf is not None and self._prev_window_conf is not None
                and wconf.shape == self._prev_window_conf.shape):
            drift = float(np.abs(wconf - self._prev_window_conf).sum()
                          / 2.0)
            if drift > self.drift_tol:
                # the traffic shifted: everything accumulated BEFORE this
                # window no longer describes it.  Rebase the exclusion
                # baseline so the stale history stays out of this AND all
                # future solves (not just the one that noticed).
                self._drift_base = self._snapshot
                self.drift_resets += 1
                log.info("confidence drift %.3f > %.3f: discarding "
                         "pre-drift telemetry from this and future "
                         "resolves", drift, self.drift_tol)
        if wconf is not None:
            self._prev_window_conf = wconf
        self._snapshot = cum

        base = self._minus(cum, self._drift_base)
        hist = ExitHistogram.from_telemetry(base, mac_prefix=self.mac_prefix)
        if self.mac_budget:
            res = solve_budget(hist, self.mac_budget)
        else:
            res = solve_epsilon(hist, self.epsilon)
        self.resolves += 1
        self.last_result = res
        # flight-recorder hook (repro.obs): solver resolves are recorded
        # on the engine/fleet event log even when the hysteresis guard
        # swallows the push — the timeline shows WHY thresholds held still
        obs_log = getattr(engine, "obs_events", None)

        cur = engine.current_thresholds()
        if (not force and cur is not None
                and len(cur) == len(res.thresholds)):
            move = max(abs(a - b)
                       for a, b in zip(res.thresholds[:-1], cur[:-1]))
            if move < self.hysteresis:
                self.skipped_small += 1
                if obs_log is not None:
                    obs_log.add("autotune_resolve", {
                        "pushed": False, "reason": "hysteresis",
                        "thresholds": [float(t) for t in res.thresholds],
                        "agreement": float(res.agreement),
                        "avg_macs": float(res.avg_macs)})
                return None
        engine.push_thresholds(res.thresholds)
        self.pushes += 1
        self.thresholds = res.thresholds
        self.last_shadow = float(base["shadow_steps"])
        if obs_log is not None:
            obs_log.add("autotune_resolve", {
                "pushed": True,
                "thresholds": [float(t) for t in res.thresholds],
                "agreement": float(res.agreement),
                "avg_macs": float(res.avg_macs),
                "shadow_steps": float(base["shadow_steps"])})
        log.info("pushed thresholds %s (%s=%s, agreement %.4f, avg MACs "
                 "%.3g, %d shadow obs)", res.thresholds, self.direction,
                 self.mac_budget or self.epsilon, res.agreement,
                 res.avg_macs, int(float(base["shadow_steps"])))
        if self.artifact_dir:
            self.save_artifact(float(base["shadow_steps"]))
        return res.thresholds

    # ------------------------------------------------------------------
    def save_artifact(self, shadow_steps: float) -> Optional[str]:
        if self.last_result is None:
            return None
        res = self.last_result
        art = CalibrationArtifact(
            config_key=config_key(self.cfg),
            thresholds=tuple(res.thresholds),
            direction=self.direction,
            target=float(self.mac_budget or self.epsilon),
            bins=self.cfg.autotune.bins,
            mac_prefix=self.mac_prefix,
            agreement=float(res.agreement),
            avg_macs=float(res.avg_macs),
            shadow_steps=float(shadow_steps),
            edges=tuple(res.edges),
            source=self.source)
        return save_artifact(self.artifact_dir, art)

    def stats(self) -> dict:
        return {
            "direction": self.direction,
            "target": float(self.mac_budget or self.epsilon),
            "resolves": self.resolves,
            "pushes": self.pushes,
            "skipped_small": self.skipped_small,
            "drift_resets": self.drift_resets,
            "last_shadow_steps": float(self.last_shadow),
            "source": self.source,
            "thresholds": ([float(t) for t in self.thresholds]
                           if self.thresholds is not None else None),
            "agreement": (float(self.last_result.agreement)
                          if self.last_result else None),
            "avg_macs": (float(self.last_result.avg_macs)
                         if self.last_result else None),
        }
