"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures, per threshold / measure, BOTH of:
  (i)  the paper's analytic MAC speedup (§6.2), and
  (ii) measured decode wall-clock per token under ``select`` (fixed graph)
       vs ``cond_batch`` (lax.cond skips exited segments' compute) — the
       ``wallclock_speedup`` column is real elapsed time; jit compilation
       is timed apart by the engine (``compile_seconds``) and a warm-up
       wave + ``reset_metrics()`` keeps the measured wave steady-state.

Also compares the two serving runtimes head-to-head: ``runtime="host"``
(one dispatch + host sync per token) vs ``runtime="device"`` (the
``DeviceDecodeLoop`` while_loop decodes a K-token chunk per dispatch) —
the ``device_speedup`` rows are the dispatch-amortization win at small
lane batches.  The machine-readable summary of those rows is exposed as
``LAST_SERVING_SUMMARY`` (benchmarks/run.py persists it to
``BENCH_serving.json`` so the perf trajectory is tracked across PRs).

All exit decisions route through the one ExitDecider resolved from the
config's registry strings; per-lane decode state (patience streaks
included) rides in the carried DecodeState.
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

LANE_BATCH = 2
CHUNK = 8
# the host-vs-device comparison runs cohort-split skipping (the device
# loop's intended configuration); summary rows record it
N_COHORTS = 2

# set by run(): machine-readable host-vs-device serving summary
LAST_SERVING_SUMMARY = None


def _drive(cfg, model, params, n_req=6, max_new=8, runtime="host",
           chunk=CHUNK):
    """Run a warm-up wave, reset metrics, run the measured wave."""
    rng = np.random.default_rng(0)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=LANE_BATCH,
                               n_lanes=2, cache_len=48, runtime=runtime,
                               chunk=chunk)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range(2 * n_req)]
    for i in range(n_req):                       # wave 1: jit warm-up
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    eng.reset_metrics()
    for i in range(n_req, 2 * n_req):            # wave 2: measured
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    return eng.stats()


def run(quick: bool = False):
    global LAST_SERVING_SUMMARY
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    n_req = 2 if quick else 6
    ths_grid = (0.0, 0.5) if quick else (0.0, 0.5, 1.1)
    for th in ths_grid:
        per_mode = {}
        for mode in ("select", "cond_batch"):
            c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode=mode)
            st = _drive(c, model, params, n_req=n_req)
            per_mode[mode] = st
            rows.append((f"llm_cascade/th={th:g}/{mode}",
                         st["wallclock_us_per_token"] or 0.0,
                         f"analytic={st['analytic_speedup']:.3f};"
                         f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                         f"opportunity={st['skip_opportunity_rate']:.3f}"))
        sel, cb = (per_mode["select"]["wallclock_us_per_token"],
                   per_mode["cond_batch"]["wallclock_us_per_token"])
        wc = (sel / cb) if (sel and cb) else 1.0
        rows.append((f"llm_cascade/th={th:g}/wallclock_speedup", 0.0,
                     f"{wc:.3f}"))
    # alternative measures through the same registry-resolved engine path —
    # patience@2 carries its streaks in the lane DecodeState and still skips
    measures = ("patience@2",) if quick else ("entropy", "patience@2")
    for measure in measures:
        c = cfg.with_cascade(thresholds=(0.5, 0.0), exit_mode="cond_batch",
                             confidence=measure)
        st = _drive(c, model, params, n_req=n_req)
        rows.append((f"llm_cascade/measure={measure}",
                     st["wallclock_us_per_token"] or 0.0,
                     f"analytic={st['analytic_speedup']:.3f};"
                     f"skip_rate={st['cond_batch_skip_rate']:.3f}"))

    # host-vs-device runtime: identical token streams, the device
    # while_loop amortizes dispatch over CHUNK tokens (the win the paper's
    # MAC savings need at small lane batches).  Longer generations than the
    # mode rows above: dispatch amortization is a per-token effect, so the
    # measured wave needs enough decode ticks to dominate timer noise.
    # Exactly at capacity (2 lanes x LANE_BATCH slots): with no queued
    # requests both runtimes admit at the same points, so the compared
    # runs execute bit-identical token streams (queued traffic admits at
    # chunk boundaries in the device runtime and may re-prefill lanes at
    # different points — a documented latency trade, not a fair timing
    # comparison).
    serving_rows = []
    rt_req = 2 * LANE_BATCH
    # quick (CI) mode keeps only th=0 — skipping + amortization, the
    # widest device margin — so the CI strictly-faster gate doesn't flake
    # on the thin pure-amortization margin of the no-skip row
    for th in ((0.0,) if quick else (0.0, 0.5)):
        c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode="cond_batch",
                             n_cohorts=N_COHORTS)
        per_rt = {}
        for rt in ("host", "device"):
            st = _drive(c, model, params, n_req=rt_req, max_new=16,
                        runtime=rt)
            per_rt[rt] = st
            rows.append((f"llm_cascade/th={th:g}/runtime={rt}",
                         st["wallclock_us_per_token"] or 0.0,
                         f"analytic={st['analytic_speedup']:.3f};"
                         f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                         f"opportunity={st['skip_opportunity_rate']:.3f};"
                         f"compile_s={st['compile_seconds']:.2f}"))
        hu = per_rt["host"]["wallclock_us_per_token"]
        du = per_rt["device"]["wallclock_us_per_token"]
        sp = (hu / du) if (hu and du) else 1.0
        rows.append((f"llm_cascade/th={th:g}/device_speedup", 0.0,
                     f"{sp:.3f}"))
        serving_rows.append({
            "threshold": th,
            "host_us_per_token": hu,
            "device_us_per_token": du,
            "device_speedup": sp,
            "realized_skip_rate": per_rt["device"]["cond_batch_skip_rate"],
            "opportunity_rate": per_rt["device"]["skip_opportunity_rate"],
            "mac_speedup": per_rt["device"]["analytic_speedup"],
            "compile_seconds_host": per_rt["host"]["compile_seconds"],
            "compile_seconds_device": per_rt["device"]["compile_seconds"],
        })
    LAST_SERVING_SUMMARY = {
        "bench": "llm_cascade",
        "arch": cfg.name,
        "lane_batch": LANE_BATCH,
        "chunk": CHUNK,
        "n_cohorts": N_COHORTS,
        "quick": bool(quick),
        "rows": serving_rows,
    }
    return rows
