"""Faithful reproduction driver: BT-train CI-RESNET(n) on the synthetic
difficulty-structured dataset, calibrate thresholds per §5, and print the
Table-2 style accuracy/speedup sweep.

Usage: PYTHONPATH=src python examples/paper_reproduction.py [--n-blocks 3]
                        [--epochs 8] [--classes 10] [--out results/repro.json]
"""
import argparse
import json
import sys

import numpy as np

from repro.core.resnet_trainer import (evaluate_tradeoff, train_backtrack,
                                       collect_outputs)
from repro.core.calibration import accuracy_vs_confidence
from repro.data.synth_images import make_image_splits
from repro.models.resnet import CIResNet


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-blocks", type=int, default=3)
    ap.add_argument("--epochs", type=int, default=8)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--train-size", type=int, default=8192)
    ap.add_argument("--out", default="results/repro.json")
    args = ap.parse_args()

    train, val, test = make_image_splits(n_classes=args.classes,
                                         n_train=args.train_size)
    model = CIResNet(n_blocks=args.n_blocks, n_classes=args.classes)
    report = train_backtrack(model, train, n_epochs=args.epochs, test=test)

    epsilons = [0.0, 0.01, 0.02, 0.04, 0.20]
    sweep = evaluate_tradeoff(model, report.params, report.state, val, test,
                              epsilons, args.classes)
    rows = []
    print(f"\ncomponent accuracies (M0, M01, M012): {report.component_acc}")
    print(f"{'eps':>6} {'acc':>8} {'speedup':>8} {'exit%':>20} thresholds")
    for eps, res in sweep:
        print(f"{eps:6.2f} {res.accuracy:8.4f} {res.speedup:8.3f} "
              f"{np.round(res.exit_fractions, 3)!s:>20} "
              f"{np.round(res.thresholds, 3)}")
        rows.append(dict(eps=eps, accuracy=res.accuracy, speedup=res.speedup,
                         exit_fractions=res.exit_fractions.tolist(),
                         thresholds=list(res.thresholds)))
    # Fig-4 linearity check: correlation of alpha_m(delta) with delta
    conf_t, pred_t, corr_t = collect_outputs(model, report.params,
                                             report.state, test)
    linearity = []
    for m in range(3):
        grid, alpha = accuracy_vs_confidence(conf_t[m], corr_t[m])
        if len(grid) > 10:
            r = float(np.corrcoef(grid, alpha)[0, 1])
        else:
            r = float("nan")
        linearity.append(r)
    print("alpha_m(delta) linearity (pearson r):", np.round(linearity, 4))
    with open(args.out, "w") as f:
        json.dump(dict(component_acc=report.component_acc, sweep=rows,
                       linearity=linearity, n_blocks=args.n_blocks,
                       epochs=args.epochs, classes=args.classes), f, indent=2)
    print("wrote", args.out)


if __name__ == "__main__":
    main()
