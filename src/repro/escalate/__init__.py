"""Cross-model escalation: a cascade OF cascades behind one ε-knob.

The paper's intra-model cascade answers a token at the shallowest
component whose softmax confidence clears its threshold.  This package
adds the next level up (Streeter's model-pool cascades; IDK answer-or-
defer): an ordered pool of serving engines where a stage's FINAL
component may abstain — confidence below the stage's escalation
threshold re-routes the request (committed prefix and all) to a bigger
model.  The same calibration machinery that solves intra-model
thresholds solves the escalation threshold too, over one composed joint
histogram with heterogeneous per-stage MAC costs.
"""
from repro.escalate.replay import (build_replay, prefix_compatible,
                                   resolve_share_prefix)
from repro.escalate.router import EscalationRouter
from repro.escalate.tier import ModelCascadeTier, TierThresholdController

__all__ = [
    "build_replay", "prefix_compatible", "resolve_share_prefix",
    "EscalationRouter", "ModelCascadeTier", "TierThresholdController",
]
