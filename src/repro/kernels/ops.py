"""Jit'd public wrappers routing model-layer calls to the Pallas kernels.

``interpret`` resolves through :func:`repro.kernels.backend.resolve_interpret`
(explicit argument > ``REPRO_KERNEL_INTERPRET`` env var > interpret only
off-TPU), so a real TPU deployment never silently runs the interpreter and
CPU CI never tries to Mosaic-compile.  Call sites that route through
``cfg.use_kernels`` pass ``cfg.kernel_interpret`` as the override.

Wrappers adapt the model's (B, S, H, hd) layouts to the kernels' tiled
layouts and fall back to the jnp reference for shapes the kernels don't
support (e.g. head_dim not a multiple of 8 in interpret tests).

Block sizes come from the autotune tile registry
(:func:`repro.kernels.autotune.tile`): each wrapper reads its kernel's
resolved tiles at call time, so ``autotune.install_tiles`` (or
``ensure_tuned``) swaps every downstream kernel onto the tuned shapes with
one inner-jit recompile and zero call-site changes.  Untuned processes get
``DEFAULT_TILES`` — the seeded block sizes, unchanged.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.autotune import tile as _tile
from repro.kernels.backend import resolve_interpret
from repro.kernels.cohort_cache import cohort_scatter, cohort_scatter_tree
from repro.kernels.confidence import confidence as _confidence
from repro.kernels.decode_attention import decode_attention as _decode_attn
from repro.kernels.exit_update import exit_update as _exit_update
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.megakernel import exit_head_update as _exit_head_update
from repro.kernels.paged_gather import paged_gather as _paged_gather
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm


def softmax_confidence_fused(logits, *, interpret=None):
    """(..., V) -> (argmax, δ) — Defs 3.2/3.3 via the fused kernel."""
    shape = logits.shape[:-1]
    V = logits.shape[-1]
    flat = logits.reshape(-1, V)
    idx, conf = _confidence(flat, bt=_tile("confidence", "bt"),
                            vt=_tile("confidence", "vt"),
                            interpret=resolve_interpret(interpret))
    return idx.reshape(shape), conf.reshape(shape)


def rmsnorm_fused(x, w, eps: float = 1e-5, *, interpret=None):
    shape = x.shape
    out = _rmsnorm(x.reshape(-1, shape[-1]), w, eps=eps,
                   rt=_tile("rmsnorm", "rt"),
                   interpret=resolve_interpret(interpret))
    return out.reshape(shape)


def flash_attention_bshd(q, k, v, *, causal=True, window=0, interpret=None):
    """Model layout (B, S, H, hd) + (B, S, KV, hd) -> (B, S, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    S = qt.shape[2]
    # the flash kernel asserts S % tile == 0 (no internal pad); tuned tiles
    # apply only when they divide this sequence, else the seeded defaults
    tq, tk = _tile("flash_attention", "tq"), _tile("flash_attention", "tk")
    if S % tq or S % tk:
        tq, tk = 128, 128
    out = _flash(qt, kt, vt, causal=causal, window=window, tq=tq, tk=tk,
                 interpret=resolve_interpret(interpret))
    return out.transpose(0, 2, 1, 3)


def decode_attention_cache(q, k_cache, v_cache, t, kpos, *, window=0,
                           live=None, interpret=None):
    """Model layout: q (B, 1, H, hd); caches (B, W, KV, hd).

    ``live`` is the per-slot exit mask ((B,) bool, None = all live): dead
    slots' grid cells early-out inside the kernel and their output rows
    zero-fill — the decode-attention FLOPs scale with the number of live
    slots, not the lane batch.
    """
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    qpk = H // KV
    qg = q[:, 0].reshape(B, KV, qpk, hd)
    kc = k_cache.transpose(0, 2, 1, 3)
    vc = v_cache.transpose(0, 2, 1, 3)
    out = _decode_attn(qg, kc, vc, t, kpos, live, window=window,
                       tk=_tile("decode_attention", "tk"),
                       interpret=resolve_interpret(interpret))
    return out.reshape(B, 1, H, hd)


@partial(jax.jit, static_argnames=("W",))
def _take_gather(store, table, W):
    flat = jnp.take(store, table.reshape(-1), axis=0)
    return flat.reshape(table.shape[0], W, store.shape[2], store.shape[3])


def paged_gather(store, table, *, interpret=None):
    """Paged-cache block gather: store (num_blocks, bs, kv, hd) through
    table (B, nblk) -> the slot-logical (B, W, kv, hd) ring view the dense
    decode-attention kernel consumes unchanged (see
    :mod:`repro.kernels.paged_gather` for why attention is NOT re-tiled
    to block granularity).

    The gather has no free tile axis; its autotune knob is implementation
    selection — the scalar-prefetch Pallas kernel vs a plain
    ``jnp.take`` reshape (XLA's fused gather wins on some hosts)."""
    if _tile("paged_gather", "impl") == "take":
        return _take_gather(store, table, table.shape[1] * store.shape[1])
    return _paged_gather(store, table, interpret=resolve_interpret(interpret))


def exit_update_fused(logits, answered, pred, exit_idx, conf, streak, ema,
                      active, *, threshold, m, n_components, patience_k=0,
                      ema_decay=0.0, tel_bins=0, interpret=None):
    """One fused component step of the exit-decision scan (see
    :mod:`repro.kernels.exit_update`): softmax-max confidence + threshold
    gate + patience streak + carry merge + optional DecodeState EMA fold,
    without materializing the softmax.  logits (B, V); all carry vectors
    (B,).  Static ``m``/``n_components``/``patience_k``/``ema_decay``
    fold into the kernel body; ``threshold`` folds too when a float, or
    rides as an operand when a jax scalar (autotune live thresholds — a
    push never retraces).  ``tel_bins > 0`` appends the packed telemetry
    code (raw_pred * bins + conf_bin) computed in the same streaming
    pass."""
    return _exit_update(logits, answered, pred, exit_idx, conf, streak, ema,
                        active, threshold=threshold, m=m,
                        n_components=n_components, patience_k=patience_k,
                        ema_decay=ema_decay, tel_bins=tel_bins,
                        bt=_tile("exit_update", "bt"),
                        vt=_tile("exit_update", "vt"),
                        interpret=resolve_interpret(interpret))


def exit_head_fused(h, norm_w, head, answered, pred, exit_idx, conf, streak,
                    ema, active, *, threshold, m, n_components, patience_k=0,
                    ema_decay=0.0, tel_bins=0, live=None, eps=1e-5,
                    interpret=None):
    """Per-segment exit-head megakernel (see
    :mod:`repro.kernels.megakernel`): rmsnorm + shared-unembed matmul
    streamed over vocab tiles + online confidence + the fused exit-update
    merge, one pallas_call — the (B, V) logits tensor never materializes.
    ``live`` lifts the per-slot exit mask to the megakernel grid: a fully
    dead batch block early-outs before the matmul and its rows pass every
    carry through unchanged."""
    return _exit_head_update(
        h, norm_w, head, answered, pred, exit_idx, conf, streak, ema,
        active, threshold=threshold, m=m, n_components=n_components,
        patience_k=patience_k, ema_decay=ema_decay, tel_bins=tel_bins,
        live=live, eps=eps, bt=_tile("megakernel", "bt"),
        vt=_tile("megakernel", "vt"),
        interpret=resolve_interpret(interpret))
