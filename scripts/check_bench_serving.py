"""CI gate for the host-vs-device serving comparison.

Reads ``BENCH_serving.json`` (written by ``benchmarks/run.py`` whenever the
llm_cascade bench runs) and enforces the dispatch-amortization acceptance
criterion: the device while_loop runtime is strictly faster than the host
per-token runtime on every measured row.  Exit code 1 on violation so CI
can retry once — the quick-mode margin is pure dispatch amortization
(~1.1–1.8x) and a shared runner's scheduler noise can eat it in a single
unlucky run.

    python scripts/check_bench_serving.py [path]
"""
import json
import sys


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json"
    with open(path) as f:
        s = json.load(f)
    if not s.get("rows"):
        print(f"{path}: no serving rows", file=sys.stderr)
        return 1
    ok = True
    for r in s["rows"]:
        if not (r["host_us_per_token"] and r["device_us_per_token"]):
            print(f"missing wallclock in row: {r}", file=sys.stderr)
            ok = False
            continue
        if r["device_speedup"] <= 1.0:
            print(f"device loop not faster (th={r['threshold']}): "
                  f"{r['device_speedup']:.3f}x", file=sys.stderr)
            ok = False
    print("device_speedup:",
          [round(r["device_speedup"], 3) for r in s["rows"]])
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
