"""Fused softmax-confidence Pallas kernel — the paper's hot-spot on TPU.

δ_m = max softmax = exp(max z − logsumexp z) over a vocab of up to 256k per
exit head per decode step.  A naive implementation materializes the (B, V)
f32 softmax in HBM; this kernel streams vocab tiles through VMEM keeping only
running (max, Σexp, argmax) per row — O(B) output, one HBM read of the
logits, zero intermediate HBM traffic.

Grid: (B/Bt, V/Vt), vocab axis innermost so the running scratch accumulates
across the contraction.  Tiles are MXU/VPU aligned (Vt multiple of 128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = -1e30


def _conf_kernel(x_ref, idx_ref, conf_ref, m_s, l_s, a_s, *, n_vtiles, vt):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])
        a_s[...] = jnp.zeros_like(a_s[...])

    x = x_ref[...].astype(jnp.float32)              # (Bt, Vt)
    tile_max = jnp.max(x, axis=-1)                  # (Bt,)
    tile_arg = jnp.argmax(x, axis=-1).astype(jnp.int32) + j * vt
    m_old = m_s[...]
    m_new = jnp.maximum(m_old, tile_max)
    l_s[...] = (l_s[...] * jnp.exp(m_old - m_new)
                + jnp.sum(jnp.exp(x - m_new[:, None]), axis=-1))
    a_s[...] = jnp.where(tile_max > m_old, tile_arg, a_s[...])
    m_s[...] = m_new

    @pl.when(j == n_vtiles - 1)
    def _out():
        idx_ref[...] = a_s[...]
        conf_ref[...] = 1.0 / l_s[...]              # exp(m − lse) = 1/Σe^{x−m}


def confidence(logits, *, bt: int = 8, vt: int = 2048,
               interpret: "bool | None" = None):
    """logits: (B, V) -> (argmax (B,) int32, δ (B,) f32).  ``interpret``
    resolves outside the jit boundary (never baked into the trace)."""
    return _confidence(logits, bt=bt, vt=vt,
                       interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("bt", "vt", "interpret"))
def _confidence(logits, *, bt, vt, interpret):
    B, V = logits.shape
    bt = min(bt, B)
    vt = min(vt, V)
    padB = (-B) % bt
    padV = (-V) % vt
    x = logits
    if padB or padV:
        x = jnp.pad(x, ((0, padB), (0, padV)), constant_values=NEG)
    Bp, Vp = x.shape
    n_vtiles = Vp // vt
    kernel = functools.partial(_conf_kernel, n_vtiles=n_vtiles, vt=vt)
    idx, conf = pl.pallas_call(
        kernel,
        grid=(Bp // bt, n_vtiles),
        in_specs=[pl.BlockSpec((bt, vt), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((bt,), lambda i, j: (i,)),
                   pl.BlockSpec((bt,), lambda i, j: (i,))],
        out_shape=[jax.ShapeDtypeStruct((Bp,), jnp.int32),
                   jax.ShapeDtypeStruct((Bp,), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.float32),
                        pltpu.VMEM((bt,), jnp.int32)],
        interpret=interpret,
    )(x)
    return idx[:B], conf[:B]
