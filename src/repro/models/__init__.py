from repro.models.model import CascadeModel, build_model

__all__ = ["CascadeModel", "build_model"]
