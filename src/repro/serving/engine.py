"""Cascade-aware serving engine: prefill + decode with confidence-thresholded
early exit (Algorithm 1 applied per generated token), KV/state backfill, and
depth-compacted lane batching.

The engine accounts compute analytically in MACs (the paper's own metric,
§6.2): every decode step records which exit answered each sequence and
whether deeper segments were actually skipped (cond_batch) or merely
unselected (select mode), yielding the measured-speedup numbers for the
beyond-paper benchmarks.

Exit decisions route through the shared :class:`repro.core.policy.ExitDecider`
resolved from the config's ``cascade.confidence`` / ``cascade.policy``
registry strings — swapping the measure (entropy, margin, patience@k, a
custom registered one) requires no engine change.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.macs import segment_macs_per_token
from repro.core.policy import ExitDecider
from repro.models.model import CascadeModel, extra_input_shapes
from repro.serving.batching import DepthCompactor
from repro.utils import get_logger

log = get_logger("serving")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    extra: Optional[dict] = None


@dataclasses.dataclass
class _Slot:
    request: Optional[Request] = None
    generated: Optional[List[int]] = None
    exit_depths: Optional[List[int]] = None
    pos: int = 0
    done: bool = True


class CascadeServingEngine:
    """Multi-lane batched decode with cascade early exit.

    Each lane holds ``lane_batch`` sequences sharing one KV cache; lanes step
    independently so the DepthCompactor can group easy (shallow-exit) traffic
    away from hard traffic, letting ``cond_batch`` skips fire.
    """

    def __init__(self, cfg: ModelConfig, model: CascadeModel, params,
                 lane_batch: int = 4, n_lanes: int = 2,
                 cache_len: int = 256):
        self.cfg = cfg
        self.model = model
        self.params = params
        self.lane_batch = lane_batch
        self.n_lanes = n_lanes
        self.cache_len = cache_len
        self.compactor = DepthCompactor(n_lanes, cfg.cascade.n_components)
        self.decider = ExitDecider.from_config(cfg)
        self.lanes = []
        for _ in range(n_lanes):
            self.lanes.append({
                "cache": model.init_cache(lane_batch, cache_len),
                "slots": [_Slot() for _ in range(lane_batch)],
                "pos": 0,
                "policy_state": self.decider.init_state(lane_batch),
            })
        self.queue: List[Request] = []
        self.finished: Dict[int, dict] = {}
        self.mac_prefix = segment_macs_per_token(cfg, cache_len)
        self._macs_spent = 0.0
        self._macs_dense = 0.0
        # population prior for a new request's exit depth, warmed by the
        # prefill exits actually observed (the compactor's depth prediction).
        self._depth_prior = (cfg.cascade.n_components - 1) / 2
        self._prefill = jax.jit(self._prefill_impl)
        self._decode = jax.jit(self._decode_impl)

    # -- jitted cores ---------------------------------------------------
    def _prefill_impl(self, params, tokens, cache, extra):
        return self.model.prefill(params, tokens, cache, extra)

    def _decode_impl(self, params, token, t, cache, extra, policy_state):
        logits, cache = self.model.decode_step(params, token, t, cache, extra)
        d = self.decider.decide(logits, state=policy_state)
        return d.prediction, d.exit_index, d.confidence, cache, d.state

    # -- public API -----------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _predict_depth(self, req: Request) -> float:
        """Expected exit depth for an incoming request: an explicit hint in
        ``req.extra["predicted_depth"]`` (e.g. from an earlier turn's prefill
        exit) wins; otherwise the engine's running prior over observed
        prefill exits."""
        if req.extra and "predicted_depth" in req.extra:
            return float(req.extra["predicted_depth"])
        return self._depth_prior

    def _admit(self):
        while self.queue:
            free = [i for i, lane in enumerate(self.lanes)
                    if any(s.done for s in lane["slots"])]
            if not free:
                break
            req = self.queue.pop(0)
            lane_id = self.compactor.assign(self._predict_depth(req), free)
            lane = self.lanes[lane_id]
            slot = next(s for s in lane["slots"] if s.done)
            slot.request = req
            slot.generated = []
            slot.exit_depths = []
            slot.done = False
            # cache is shared per-lane, so we prefill the whole lane
            # when admission changes (simple + correct).
            lane["dirty"] = True

    def _finish_if_done(self, s: _Slot, lane, lane_id: int):
        if (len(s.generated) >= s.request.max_new_tokens
                or lane["pos"] >= self.cache_len - 1):
            s.done = True
            self.finished[s.request.rid] = {
                "tokens": list(s.generated),
                "exit_depths": list(s.exit_depths),
                "lane": lane_id,
            }

    def _lane_prefill(self, lane, lane_id: int):
        """(Re)prefill a lane: pad contexts to a common length.

        In-flight slots re-prefill with their full context (prompt + tokens
        generated so far) so admission into a sibling slot never truncates a
        live sequence; the token predicted off that context is their normal
        next-step continuation."""
        slots = lane["slots"]
        prompts = [np.concatenate([s.request.prompt,
                                   np.asarray(s.generated, np.int32)])
                   if not s.done else np.zeros((1,), np.int32)
                   for s in slots]
        S = max(len(p) for p in prompts)
        S = max(S, 2)
        toks = np.zeros((self.lane_batch, S), np.int32)
        for i, p in enumerate(prompts):
            toks[i, -len(p):] = p          # left-pad (simplest alignment)
        lane["cache"] = self.model.init_cache(self.lane_batch, self.cache_len)
        extra = self._extra(self.lane_batch)
        logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                      lane["cache"], extra)
        lane["cache"] = cache
        lane["pos"] = S
        decision = self.decider.decide(logits)
        # re-prefill restarts stateful-measure streaks for the lane, but the
        # prefill decision itself counts as the streak's first step
        lane["policy_state"] = (decision.state if decision.state is not None
                                else self.decider.init_state(self.lane_batch))
        tok = np.asarray(decision.prediction)
        exit_idx = np.asarray(decision.exit_index)
        for i, s in enumerate(slots):
            if not s.done:
                if not s.generated:
                    # warm the admission depth prior with the FIRST prefill
                    # exit only (re-prefills of in-flight slots don't
                    # re-count toward the prior)
                    self._depth_prior = (0.8 * self._depth_prior
                                         + 0.2 * float(exit_idx[i]))
                s.generated.append(int(tok[i]))
                s.exit_depths.append(int(exit_idx[i]))
                # the prefill token counts toward max_new_tokens like any
                # decode tick — an in-flight slot near its limit may finish
                self._finish_if_done(s, lane, lane_id)
        lane["dirty"] = False

    def _extra(self, batch):
        shapes = extra_input_shapes(self.cfg, batch)
        if not shapes:
            return None
        return {k: jnp.zeros(v, jnp.float32) for k, v in shapes.items()}

    def step(self):
        """One engine tick: admit, prefill dirty lanes, decode one token."""
        self._admit()
        for lane_id, lane in enumerate(self.lanes):
            if all(s.done for s in lane["slots"]):
                continue
            if lane.get("dirty"):
                self._lane_prefill(lane, lane_id)
                continue
            last = [s.generated[-1] if not s.done else 0
                    for s in lane["slots"]]
            token = jnp.asarray(np.array(last, np.int32)[:, None])
            t = lane["pos"]
            tok, exit_idx, conf, cache, lane["policy_state"] = self._decode(
                self.params, token, jnp.asarray(t, jnp.int32), lane["cache"],
                self._extra(self.lane_batch), lane["policy_state"])
            lane["cache"] = cache
            lane["pos"] = t + 1
            tok = np.asarray(tok)
            exit_idx = np.asarray(exit_idx)
            live = np.array([not s.done for s in lane["slots"]])
            depths = exit_idx[live]
            # analytic MAC accounting (paper §6.2): dense cost vs exit cost
            n_live = int(live.sum())
            self._macs_dense += n_live * self.mac_prefix[-1]
            self._macs_spent += float(
                np.sum(np.asarray(self.mac_prefix)[depths])) if n_live else 0.0
            max_depth = int(depths.max()) if n_live else 0
            skipped = (self.cfg.cascade.n_components - 1) - max_depth
            self.compactor.observe(lane_id, depths, max(0, skipped))
            for i, s in enumerate(lane["slots"]):
                if s.done:
                    continue
                s.generated.append(int(tok[i]))
                s.exit_depths.append(int(exit_idx[i]))
                self._finish_if_done(s, lane, lane_id)

    def run(self, max_ticks: int = 1000):
        for _ in range(max_ticks):
            if not self.queue and all(
                    s.done for ln in self.lanes for s in ln["slots"]):
                break
            self.step()
        return self.finished

    # -- metrics ---------------------------------------------------------
    def speedup(self) -> float:
        """Analytic MAC speedup vs always running the full cascade."""
        if not self._macs_spent:
            return 1.0
        return self._macs_dense / self._macs_spent

    def stats(self) -> dict:
        depths = list(itertools.chain.from_iterable(
            r["exit_depths"] for r in self.finished.values()))
        return {
            "requests_finished": len(self.finished),
            "mean_exit_depth": float(np.mean(depths)) if depths else None,
            "exit_histogram": np.bincount(
                depths, minlength=self.cfg.cascade.n_components).tolist()
            if depths else None,
            "analytic_speedup": self.speedup(),
            "cond_batch_skip_rate": self.compactor.skip_rate(),
        }
