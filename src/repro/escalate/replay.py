"""Prefix replay for cross-model escalation.

When a stage defers a request, everything it already committed is real
output the tier keeps — the next stage must decode *from that context*,
not re-answer it.  Two stages can share the context only when the
committed token IDs are valid input to both: we auto-detect that as
equal ``vocab_size`` AND equal ``family`` (same tokenizer id space, same
architectural family — a draft and verifier trained as a pair).  When
they are compatible, the committed prefix rides into the next stage as
extra PROMPT positions (prefilled in one dispatch — the paged runtime's
``prefill_into`` path — instead of decoded one-by-one) and the request's
remaining budget shrinks by what already stands.  When they are not, the
committed tokens are meaningless to the next stage: it restarts from the
original prompt with the original budget, and the tier discards the
draft's output from the final record (Streeter-style model-pool
fallback: the escalated model re-answers from scratch).
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


def prefix_compatible(cfg_a: ModelConfig, cfg_b: ModelConfig) -> bool:
    """Can stage ``b`` consume tokens stage ``a`` committed?"""
    return (cfg_a.vocab_size == cfg_b.vocab_size
            and cfg_a.family == cfg_b.family)


def resolve_share_prefix(cfg_from: ModelConfig,
                         cfg_to: ModelConfig) -> bool:
    """Apply ``cfg_from.escalation.share_prefix``: explicit wins, ``None``
    auto-detects via :func:`prefix_compatible`.  Forcing ``True`` across
    incompatible configs is an error — the next stage would prefill token
    IDs from a different vocabulary."""
    share = cfg_from.escalation.share_prefix
    if share is None:
        return prefix_compatible(cfg_from, cfg_to)
    if share and not prefix_compatible(cfg_from, cfg_to):
        raise ValueError(
            "escalation.share_prefix=True across incompatible stages "
            f"(vocab {cfg_from.vocab_size} vs {cfg_to.vocab_size}, family "
            f"{cfg_from.family!r} vs {cfg_to.family!r}) — the committed "
            "tokens are not valid next-stage input")
    return bool(share)


def build_replay(prompt: np.ndarray, committed: List[int],
                 max_new_tokens: int, share_prefix: bool
                 ) -> Tuple[np.ndarray, int, int]:
    """The next stage's (prompt, max_new_tokens, replayed_len).

    ``committed`` is every token the tier has kept so far (all earlier
    stages' prefixes concatenated).  Shared prefix: the committed tokens
    append to the prompt, the budget shrinks by their count, and
    ``replayed_len`` tells the receiving engine how many trailing prompt
    positions are replay (for the escalation-accounting split in
    ``stats()``).  Unshared: the original prompt and full budget come
    back and the caller must discard ``committed``."""
    prompt = np.asarray(prompt, np.int32)
    if not share_prefix or not committed:
        return prompt, int(max_new_tokens), 0
    new_prompt = np.concatenate(
        [prompt, np.asarray(committed, np.int32)])
    remaining = int(max_new_tokens) - len(committed)
    if remaining <= 0:
        raise ValueError(
            f"nothing left to decode: {len(committed)} committed tokens "
            f">= budget {max_new_tokens} (a fully-committed request "
            "finishes, it does not escalate)")
    return new_prompt, remaining, len(committed)
