"""Multi-pod dry-run: prove every (architecture × input shape × mesh) lowers
and compiles under the production sharding, and extract roofline inputs.

MUST set the device-count flag before any jax import side effects.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_configs  # noqa: E402
from repro.core.macs import model_flops  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.shard_rules import (batch_spec, cache_spec,  # noqa: E402
                                      decode_state_spec, param_spec,
                                      to_shardings)
from repro.launch.steps import (make_batch_structs,  # noqa: E402
                                make_decode_state_struct, make_optimizer,
                                make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models.model import build_model, extra_input_shapes  # noqa: E402

DTYPE_BITS = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8": 1,
              "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
              "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# long-context window for full-attention archs (the spec's sliding-window
# carve-out); SSM archs keep their recurrent state instead.
LONG_WINDOW = 8192
SKIP = {("whisper-tiny", "long_500k"):
        "enc-dec target positions are bounded (<=448); 500k decode is "
        "architecturally meaningless for an ASR decoder (DESIGN.md)"}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * DTYPE_BITS.get(dtype, 4)


_OP_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\][^=]*?\b(" + "|".join(COLLECTIVES) + r")\(")


def parse_collectives(hlo_text: str):
    """Approximate per-device wire bytes of every collective in the compiled
    HLO.  Result-shape based; all-reduce counted 2x (ring = reduce-scatter +
    all-gather)."""
    out = {op: 0 for op in COLLECTIVES}
    counts = {op: 0 for op in COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        b = _shape_bytes(dtype, dims)
        out[op] += 2 * b if op == "all-reduce" else b
        counts[op] += 1
    return out, counts


def adjust_config(cfg, shape, unroll: bool = False, exit_mode: str = "select"):
    if shape.name == "long_500k" and cfg.family not in ("ssm",):
        if cfg.attn_window == 0 or cfg.attn_window > LONG_WINDOW:
            cfg = cfg.replace(attn_window=min(cfg.attn_window or LONG_WINDOW,
                                              LONG_WINDOW))
    if shape.kind == "decode":
        # "select" is the fixed-graph roofline shape; "cond_batch" costs the
        # lax.cond segment-skipping program (both lower the same DecodeState)
        cfg = cfg.with_cascade(exit_mode=exit_mode)
    if unroll:
        cfg = cfg.replace(scan_unroll=True)
    return cfg


def lower_combo(arch: str, shape_name: str, multi_pod: bool,
                unroll: bool = False, cfg_override=None,
                param_mode: str = "default", kv_dtype=None,
                exit_mode: str = "select"):
    """Build, lower, compile one combination; return the roofline record."""
    shape = INPUT_SHAPES[shape_name]
    cfg = cfg_override or adjust_config(get_config(arch), shape, unroll,
                                        exit_mode)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    rec = {"arch": arch, "shape": shape_name, "param_mode": param_mode,
           "mesh": "2x16x16" if multi_pod else "16x16", "ok": False}
    t0 = time.time()

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_spec = param_spec(params_s, cfg, mesh, mode=param_mode)
    p_shard = to_shardings(mesh, p_spec)
    scalar = NamedSharding(mesh, P())

    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(cfg)
            opt_s = jax.eval_shape(opt.init, params_s)
            o_shard = to_shardings(mesh, param_spec(opt_s, cfg, mesh))
            batch_structs = make_batch_structs(cfg, shape.global_batch,
                                               shape.seq_len)
            b_shard = jax.tree_util.tree_map(
                lambda s: NamedSharding(mesh, batch_spec(
                    cfg, mesh, shape.global_batch, len(s.shape))),
                batch_structs)
            step_fn = make_train_step(model, cfg, opt)
            jitted = jax.jit(step_fn, in_shardings=(p_shard, o_shard, scalar,
                                                    b_shard))
            lowered = jitted.lower(params_s, opt_s,
                                   jax.ShapeDtypeStruct((), jnp.int32),
                                   batch_structs)
            n_tokens = shape.global_batch * shape.seq_len
            training = True
        elif shape.kind == "prefill":
            cache_s = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_shard = to_shardings(mesh, cache_spec(cache_s, cfg, mesh,
                                                    shape.global_batch))
            tok_s = jax.ShapeDtypeStruct((shape.global_batch, shape.seq_len),
                                         jnp.int32)
            t_shard = NamedSharding(mesh, batch_spec(cfg, mesh,
                                                     shape.global_batch, 2))
            extra_s, e_shard = _extra(cfg, shape.global_batch, mesh)
            step_fn = make_prefill_step(model, cfg)
            jitted = jax.jit(step_fn, in_shardings=(p_shard, t_shard, c_shard,
                                                    e_shard))
            lowered = jitted.lower(params_s, tok_s, cache_s, extra_s)
            n_tokens = shape.global_batch * shape.seq_len
            training = False
        else:  # decode
            cache_s = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len,
                                         dtype=kv_dtype))
            c_shard = to_shardings(mesh, cache_spec(cache_s, cfg, mesh,
                                                    shape.global_batch))
            tok_s = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
            t_shard = NamedSharding(mesh, batch_spec(cfg, mesh,
                                                     shape.global_batch, 2))
            # the carried DecodeState lowers alongside the cache, so
            # stateful measures (patience streaks) cost correctly
            state_s = make_decode_state_struct(cfg, shape.global_batch)
            s_shard = to_shardings(mesh, decode_state_spec(
                state_s, cfg, mesh, shape.global_batch))
            extra_s, e_shard = _extra(cfg, shape.global_batch, mesh)
            step_fn = make_serve_step(model, cfg)
            jitted = jax.jit(step_fn, in_shardings=(p_shard, t_shard,
                                                    c_shard, s_shard,
                                                    e_shard))
            lowered = jitted.lower(params_s, tok_s, cache_s, state_s,
                                   extra_s)
            n_tokens = shape.global_batch
            training = False

        rec["t_lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["t_compile_s"] = round(time.time() - t1, 1)

        try:
            mem = compiled.memory_analysis()
            rec["memory"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(mem, k)}
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}
        try:
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            rec["flops"] = float(cost.get("flops", 0.0))
            rec["hlo_bytes"] = float(cost.get("bytes accessed", 0.0))
        except Exception as e:  # pragma: no cover
            rec["flops"] = rec["hlo_bytes"] = -1.0
            rec["cost_error"] = str(e)
        coll, counts = parse_collectives(compiled.as_text())
        rec["collective_bytes"] = coll
        rec["collective_counts"] = counts
        rec["model_flops"] = model_flops(cfg, n_tokens, training)
        rec["n_tokens"] = n_tokens
        rec["ok"] = True
    return rec


def _extra(cfg, batch, mesh):
    shapes = extra_input_shapes(cfg, batch)
    if not shapes:
        return None, None
    structs = {k: jax.ShapeDtypeStruct(v, jnp.float32)
               for k, v in shapes.items()}
    shards = {k: NamedSharding(mesh, batch_spec(cfg, mesh, batch, len(v)))
              for k, v in shapes.items()}
    return structs, shards


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll layer scans for exact cost analysis")
    ap.add_argument("--param-mode", default="default",
                    choices=["default", "serve1d", "serve2d"],
                    help="parameter sharding layout (see shard_rules.py)")
    ap.add_argument("--exit-mode", default="select",
                    choices=["select", "cond_batch"],
                    help="decode execution mode: fixed roofline graph vs "
                         "lax.cond segment skipping")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args()
    archs = ([a for a in list_configs() if a != "ci-resnet18"]
             if args.arch == "all" else [args.arch])
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            tag = (f"{arch}__{shape}__{'mp' if args.multi_pod else 'sp'}"
                   + ("_unroll" if args.unroll else "")
                   + (f"_{args.param_mode}" if args.param_mode != "default"
                      else "")
                   + (f"_{args.exit_mode}" if args.exit_mode != "select"
                      else ""))
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path):
                print("skip (exists)", tag)
                continue
            if (arch, shape) in SKIP:
                rec = {"arch": arch, "shape": shape, "ok": True,
                       "skipped": SKIP[(arch, shape)]}
            else:
                try:
                    rec = lower_combo(arch, shape, args.multi_pod,
                                      unroll=args.unroll,
                                      param_mode=args.param_mode,
                                      exit_mode=args.exit_mode)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "ok": False,
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            status = "OK" if rec.get("ok") else "FAIL"
            print(f"{status} {tag} "
                  f"flops={rec.get('flops', 0):.3g} "
                  f"compile={rec.get('t_compile_s', 0)}s", flush=True)


if __name__ == "__main__":
    main()
