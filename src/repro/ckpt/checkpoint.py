"""Flat-npz pytree checkpointing (orbax is not available offline).

A checkpoint is a directory of ``step_<n>.npz`` files; each pytree leaf is
stored under its slash-joined key path so restoration is structure-checked.
Atomic via write-to-temp + rename.  Works for params, optimizer state, and
cascade thresholds alike (anything jax.tree_util can flatten with string keys).
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import numpy as np

from repro.utils import path_str


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[path_str(path)] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, like: Any, step: Optional[int] = None) -> Any:
    """Restore into the structure of ``like`` (shape- and key-checked)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}.npz")
    with np.load(path) as data:
        stored = dict(data)
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_keys, leaf in paths_leaves:
        key = path_str(path_keys)
        if key not in stored:
            raise KeyError(f"checkpoint {path} missing leaf {key}")
        arr = stored[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs model {np.shape(leaf)}")
        leaves.append(arr.astype(leaf.dtype) if hasattr(leaf, "dtype") else arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)
