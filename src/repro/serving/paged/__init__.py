"""Skip-aware paged KV cache for the cascade serving engine.

``BlockPool`` owns the physical block free list, ``PagedCascadeCache``
builds the shared stores and per-lane block tables and books the
per-slot allocations.  See the package modules and DESIGN.md for the
layout contract.
"""
from repro.serving.paged.cache import PagedCascadeCache
from repro.serving.paged.pool import TRASH_BLOCK, BlockPool

__all__ = ["BlockPool", "PagedCascadeCache", "TRASH_BLOCK"]
