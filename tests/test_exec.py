"""Staged execution: DecodeState-carrying decode, cond_batch == select
equivalence, real segment skipping, and stateful measures through the
launch serve step (jit + sharding)."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced
from repro.core.exec import DecodeState, StagedExecutor, init_decode_state
from repro.core.policy import BudgetPolicy, ExitDecider
from repro.launch.shard_rules import (batch_spec, cache_spec,
                                      decode_state_spec, param_spec,
                                      to_shardings)
from repro.launch.steps import (make_decode_state, make_decode_state_struct,
                                make_prefill_step, make_serve_step)
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request


def _greedy_drive(cfg, params, toks, n_steps=6, donate=True):
    """Prefill + greedy decode through the staged executor; returns
    (tokens, exit_indices, segments_run)."""
    model = build_model(cfg)
    ex = StagedExecutor(model, cfg)
    cache = model.init_cache(toks.shape[0], 32)
    step = jax.jit(ex.decode_step,
                   donate_argnums=(2, 3) if donate else ())
    d, cache, state = ex.prefill(params, toks, cache)
    tokens, exits = [np.asarray(d.prediction)], [np.asarray(d.exit_index)]
    for _ in range(n_steps):
        d, cache, state = step(params, d.prediction[:, None], cache, state)
        tokens.append(np.asarray(d.prediction))
        exits.append(np.asarray(d.exit_index))
    return np.array(tokens), np.array(exits), np.asarray(state.segments_run)


@pytest.mark.parametrize("measure", ["softmax_max", "patience@2"])
@pytest.mark.parametrize("th", [0.0, 0.6, 1.1])
def test_cond_batch_matches_select_exactly(measure, th):
    """The acceptance contract: identical tokens and exit indices across
    execution modes, for stateless AND stateful measures, while cond_batch
    provably skips exited segments (its executed-segment counters stay 0)."""
    base = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    base = base.with_cascade(thresholds=(th, 0.0), confidence=measure)
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 8)), jnp.int32)

    t_sel, e_sel, run_sel = _greedy_drive(
        base.with_cascade(exit_mode="select"), params, toks)
    t_cb, e_cb, run_cb = _greedy_drive(
        base.with_cascade(exit_mode="cond_batch"), params, toks)
    np.testing.assert_array_equal(t_sel, t_cb)
    np.testing.assert_array_equal(e_sel, e_cb)
    # select mode always computes everything
    assert run_sel[0] == run_sel[1] == 6
    if th == 0.0:
        # everyone exits at component 0 → the deep segment's compute counter
        # never advanced: lax.cond executed only the backfill branch
        assert run_cb[1] < run_sel[1]
        if measure == "softmax_max":
            assert run_cb[1] == 0
    else:
        assert run_cb[1] <= run_sel[1]


def test_cond_batch_skips_wallclock_and_flops():
    """cond_batch must actually terminate early: with a heavy deep segment
    and thresholds that exit everyone at component 0, the executed-segment
    trace shows zero deep-segment runs, and measured step time does not
    exceed the fixed select graph (lenient bound — CI timers are noisy; the
    counters are the authoritative skip evidence)."""
    base = reduced(get_config("qwen2.5-3b"), n_layers=8, d_model=512,
                   d_ff=2048, n_heads=8, n_kv_heads=2).replace(
                       dtype="float32")
    base = base.with_cascade(thresholds=(0.0, 0.0))
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, base.vocab_size, (2, 8)), jnp.int32)

    def timed(mode, n_steps=20):
        cfg = base.with_cascade(exit_mode=mode)
        ex = StagedExecutor(build_model(cfg), cfg)
        cache = ex.model.init_cache(2, 64)
        step = jax.jit(ex.decode_step, donate_argnums=(2, 3))
        d, cache, state = ex.prefill(params, toks, cache)
        d, cache, state = step(params, d.prediction[:, None], cache, state)
        jax.block_until_ready(d.prediction)           # exclude compile
        best = np.inf
        for _ in range(3):
            t0 = time.perf_counter()
            for _ in range(n_steps):
                d, cache, state = step(params, d.prediction[:, None], cache,
                                       state)
            jax.block_until_ready(d.prediction)
            best = min(best, (time.perf_counter() - t0) / n_steps)
        return best, np.asarray(state.segments_run)

    t_sel, run_sel = timed("select")
    t_cb, run_cb = timed("cond_batch")
    assert run_sel[1] > 0 and run_cb[1] == 0      # deep segment never ran
    assert t_cb <= t_sel * 1.25                    # and it isn't slower


def test_patience_serve_step_state_survives_jit_and_sharding():
    """A patience@k config serves through the launch step: the DecodeState
    (streak counters) must survive jit with explicit shardings — if the
    state were re-initialized per step, the streak would never reach k and
    component 0 could never answer."""
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(confidence="patience@2", thresholds=(0.0, 0.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    cache = model.init_cache(2, 32)

    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    params_spec = param_spec(jax.eval_shape(lambda: params), cfg, mesh)
    cache_spec_t = cache_spec(jax.eval_shape(lambda: cache), cfg, mesh, 2)
    state = make_decode_state(cfg, 2)
    state_spec = decode_state_spec(jax.eval_shape(lambda: state), cfg,
                                   mesh, 2)
    tok_sh = NamedSharding(mesh, batch_spec(cfg, mesh, 2, 2))

    prefill = make_prefill_step(model, cfg)
    _, exit0, _, cache, state = prefill(params, toks, cache, None)
    assert int(np.max(np.asarray(exit0))) == 1    # streak 1 < k: final answers

    serve = jax.jit(make_serve_step(model, cfg),
                    in_shardings=(to_shardings(mesh, params_spec), tok_sh,
                                  to_shardings(mesh, cache_spec_t),
                                  to_shardings(mesh, state_spec), None))
    token = jnp.zeros((2, 1), jnp.int32)
    exits = []
    for _ in range(3):
        tok, exit_idx, conf, cache, state = serve(params, token, cache,
                                                  state, None)
        exits.append(int(np.max(np.asarray(exit_idx))))
        token = tok[:, None]
    # streak reached k on the first decode step and stays satisfied only
    # because the carried state survived jit + sharding
    assert exits == [0, 0, 0]
    assert isinstance(state, DecodeState)
    assert int(np.asarray(state.policy)[0].min()) >= 2
    assert int(state.t) == toks.shape[1] + 3


def test_decode_state_spec_structure_production_mesh():
    """decode_state_spec must cover every DecodeState leaf on the production
    mesh, batch-sharding the per-sequence leaves."""
    from tests.test_sharding import _abstract_mesh
    mesh = _abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("qwen2.5-3b").with_cascade(confidence="patience@3")
    struct = make_decode_state_struct(cfg, 128)
    spec = decode_state_spec(struct, cfg, mesh, 128)
    flat_struct = jax.tree_util.tree_leaves(struct)
    flat_spec = jax.tree_util.tree_leaves(
        spec, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_struct) == len(flat_spec)
    assert spec.active == P("data")
    assert spec.ema_conf == P("data")
    assert spec.policy == P(None, "data")
    assert spec.t == P() and spec.segments_run == P()
    # indivisible batch degrades to replication
    spec1 = decode_state_spec(make_decode_state_struct(cfg, 1), cfg, mesh, 1)
    assert spec1.active == P(None)


def test_engine_modes_agree_end_to_end():
    """The serving engine generates identical streams in select and
    cond_batch modes (same requests, same exits) while cond_batch records a
    real skip rate."""
    base = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    base = base.with_cascade(thresholds=(0.0, 0.0))
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, base.vocab_size, 6).astype(np.int32)
               for _ in range(4)]

    def run(mode):
        cfg = base.with_cascade(exit_mode=mode)
        eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                   n_lanes=2, cache_len=32)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(), max_new_tokens=5))
        eng.run(100)
        return eng

    sel = run("select")
    cb = run("cond_batch")
    assert sel.finished.keys() == cb.finished.keys()
    for rid in sel.finished:
        assert sel.finished[rid]["tokens"] == cb.finished[rid]["tokens"]
        assert (sel.finished[rid]["exit_depths"]
                == cb.finished[rid]["exit_depths"])
    assert sel.stats()["cond_batch_skip_rate"] == 0.0
    assert cb.stats()["cond_batch_skip_rate"] == 1.0
    assert cb.stats()["wallclock_us_per_token"] > 0


def test_budget_policy_explicit_override_warns_and_wins():
    """ROADMAP follow-up (a): a fitted BudgetPolicy no longer silently
    ignores per-call thresholds — the override is honored with a warning."""
    rng = np.random.default_rng(5)
    confs = [rng.random(500) for _ in range(3)]
    pol = BudgetPolicy("")
    pol.fit(confs, [1.0, 2.0, 4.0], mac_budget=2.0)
    dec = ExitDecider("softmax_max", policy=pol)
    logits = [jnp.asarray(rng.standard_normal((4, 8)), jnp.float32)
              for _ in range(3)]
    with pytest.warns(UserWarning, match="per-call override"):
        d = dec.decide(logits, thresholds=(0.0, 0.0, 0.0))
    np.testing.assert_array_equal(np.asarray(d.exit_index), 0)
    # without the override the fitted thresholds still rule
    d_fit = dec.decide(logits)
    assert int(np.asarray(d_fit.exit_index).max()) >= 0


def test_compactor_owns_population_depth_prior():
    """ROADMAP follow-up (c): one population depth prior, in the compactor."""
    from repro.serving.batching import DepthCompactor
    c = DepthCompactor(n_lanes=2, n_components=3, ema=0.8)
    assert c.predict_depth() == pytest.approx(1.0)     # (n_c - 1) / 2
    assert c.predict_depth(hint=2.5) == 2.5            # hint wins
    for _ in range(20):
        c.observe_prefill_exit(0.0)
    assert c.predict_depth() < 0.05                    # EMA converged

    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(thresholds=(0.0, 0.0))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=1,
                               cache_len=32)
    assert not hasattr(eng, "_depth_prior")            # duplicate EMA is gone
    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0, prompt=rng.integers(
        0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=3))
    eng.run(50)
    # threshold 0 ⇒ prefill exits at 0 ⇒ the prior moved toward 0
    assert eng.compactor.predict_depth() < 1.0


def test_model_decode_wrapper_matches_executor():
    """CascadeModel.decode is the staged executor (cached), not a third
    decode implementation."""
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg = cfg.with_cascade(thresholds=(0.0, 0.0), exit_mode="cond_batch")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    ex = StagedExecutor(model, cfg)

    d0, cache_a, st_a = ex.prefill(params, toks, model.init_cache(2, 32))
    _, cache_b, st_b = ex.prefill(params, toks, model.init_cache(2, 32))
    tok = d0.prediction[:, None]
    da, _, st_a = model.decode(params, tok, cache_a, st_a)
    db, _, st_b = ex.decode_step(params, tok, cache_b, st_b)
    np.testing.assert_array_equal(np.asarray(da.prediction),
                                  np.asarray(db.prediction))
    np.testing.assert_array_equal(np.asarray(da.exit_index),
                                  np.asarray(db.exit_index))
    np.testing.assert_array_equal(np.asarray(st_a.segments_run),
                                  np.asarray(st_b.segments_run))
    cached = model._staged_executor
    model.decode(params, tok, cache_a, st_a)
    assert model._staged_executor is cached      # executor built once


def test_decode_state_pytree_roundtrip():
    dec = ExitDecider("patience@2", thresholds=(0.5, 0.0))
    st = init_decode_state(dec, batch=3, n_components=2, t=7)
    leaves, treedef = jax.tree_util.tree_flatten(st)
    st2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert int(st2.t) == 7 and st2.policy.shape == (2, 3)
    st3 = st.replace(t=jnp.asarray(9, jnp.int32))
    assert int(st3.t) == 9 and int(st.t) == 7
