"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures, per threshold / measure, BOTH of:
  (i)  the paper's analytic MAC speedup (§6.2), and
  (ii) measured decode wall-clock per token under ``select`` (fixed graph)
       vs ``cond_batch`` (lax.cond skips exited segments' compute) — the
       ``wallclock_speedup`` column is real elapsed time; jit compilation
       is timed apart by the engine (``compile_seconds``) and a warm-up
       wave + ``reset_metrics()`` keeps the measured wave steady-state.

The serving sweep is the skip-aware hot-path ablation (persisted to
``BENCH_serving.json`` by ``benchmarks/run.py``): at every threshold, with
``n_cohorts=2`` and ``use_kernels=True``,

* ``runtime=host`` vs ``runtime=device`` — the ``DeviceDecodeLoop``
  while_loop amortizes per-token dispatch (``device_speedup``);
* ``cohort_layout=copy`` vs ``cohort_layout=major`` — the per-segment
  slice+concat cohort path vs the cohort-major layout that splits once and
  scatters cache results back in place (``layout_speedup``), with the two
  layouts' token streams asserted bit-identical (``streams_identical``);
* kernels on vs off — the exit-masked decode-attention + fused exit-update
  Pallas fast path vs the plain jnp path (``kernel_speedup``; on CPU CI the
  kernels run interpreted, so this column is only meaningful on real
  hardware — it is recorded, not gated);
* ``cache_layout=dense`` vs ``cache_layout=paged`` — bit-identity at
  capacity (``paged_streams_identical``), then an EQUAL-MEMORY admission
  burst: the paged engine runs twice the slots inside the dense slab's
  byte budget (slots claim blocks only for their actual span), so its
  admission wait (ticks from submit to admit — deterministic, not
  wall-clock) and peak cache bytes must beat the dense layout
  (``check_bench_serving.py`` gates both, plus the exit-reclamation
  counters recorded per row).

The cross-model escalation ablation (``repro.escalate``) rides in the same
summary under an ``escalation`` section: a 2-stage tier (2-layer draft →
4-layer target, same vocab) is pinned bit-identical to the standalone
engines at both escalation corners (``never``/``always``), then a
matched-accuracy operating point is solved on a labeled two-stage
population priced with the REAL per-stage analytic MAC prefixes
(``segment_macs_per_token``) composed by ``compose_mac_prefix`` — the gate
requires the solved tier to spend strictly fewer average MACs than
big-only at no accuracy loss (``check_bench_serving.py``).

All exit decisions route through the one ExitDecider resolved from the
config's registry strings; per-lane decode state (patience streaks
included) rides in the carried DecodeState.
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

LANE_BATCH = 2
CHUNK = 8
# the serving ablation runs cohort-split skipping (the device loop's
# intended configuration); summary rows record it
N_COHORTS = 2
# serving-ablation lane shape: larger than the mode rows above so the
# layout delta (cache copies per segment per step) clears timer noise
SERVE_LANE_BATCH = 4
SERVE_CACHE_LEN = 256
# paged-cache ablation shape: 16-position blocks over the 256-position ring
PAGED_BLOCK = 16
# the full threshold sweep persisted to BENCH_serving.json — at least 3
# operating points so the perf trajectory tracks the cascade, not one row:
# 0.0 exits everyone at component 0 (max skipping), 0.02 sits inside the
# random-init confidence band (~0.02–0.03 over a 512 vocab) for genuinely
# mixed per-slot exits, 1.1 never exits early (the dense ceiling)
SERVE_THRESHOLDS = (0.0, 0.02, 1.1)

# set by run(): machine-readable serving-ablation summary
LAST_SERVING_SUMMARY = None


def _drive(cfg, model, params, n_req=6, max_new=8, runtime="host",
           chunk=CHUNK, lane_batch=LANE_BATCH, n_lanes=2, cache_len=48,
           waves=1):
    """Run a warm-up wave, reset metrics, run ``waves`` measured waves.

    Returns the engine (callers read ``stats()`` and the finished token
    streams).  Prompts are seeded per request id, so two runs with the
    same shape execute identical traffic; every wave is submitted exactly
    at capacity so nothing queues (queueing admits at chunk boundaries in
    the device runtime and would legitimately diverge the streams).
    """
    rng = np.random.default_rng(0)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=lane_batch,
                               n_lanes=n_lanes, cache_len=cache_len,
                               runtime=runtime, chunk=chunk)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range((waves + 1) * n_req)]
    for i in range(n_req):                       # wave 1: jit warm-up
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    eng.reset_metrics()
    for w in range(1, waves + 1):                # measured waves
        for i in range(w * n_req, (w + 1) * n_req):
            eng.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=max_new))
        eng.run(300)
    return eng


def _streams(eng):
    return {rid: tuple(r["tokens"]) for rid, r in eng.finished.items()}


def _escalation_ablation(rows, quick):
    """Cross-model escalation tier (repro.escalate) ablation.

    Two halves, both deterministic:

    (i)  REAL tier parity corners — a 2-stage tier (2-layer draft,
         4-layer target, shared vocab) run at escalation=0.0 must stream
         bit-identical to the draft alone, and at escalation=1.1 with
         the draft's intra thresholds at the 1.1 sentinel (every token
         reaches the final component, then defers at token 0, so the
         committed prefix is empty) bit-identical to the target alone.
         A mid-threshold run (median of the draft's observed final
         confidences) records the replay accounting: escalations,
         replayed-prefix prefill positions, discarded draft tokens.

    (ii) matched-accuracy MACs — the heterogeneous-cost solve on a
         labeled synthetic two-stage population priced with the REAL
         per-stage analytic prefixes (``segment_macs_per_token`` on the
         two configs, chained by ``compose_mac_prefix`` with a replay
         overhead).  The population encodes the regime escalation
         exploits (the paper's §5 calibration: the draft is *right* when
         it is *confident*, and there the cheap answer beats the target's
         flat accuracy), so ``solve_epsilon(ε=0)`` must find thresholds
         whose average MACs are strictly below always-running the target
         at no accuracy loss — gated by ``check_bench_serving.py``.
         Costs are normalized to target-final = 1.0.
    """
    from repro.autotune import (ExitHistogram, compose_mac_prefix,
                                solve_epsilon, split_tier_thresholds)
    from repro.core.macs import segment_macs_per_token
    from repro.escalate import ModelCascadeTier

    cfg_s = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    cfg_b = reduced(get_config("qwen2.5-3b"),
                    n_layers=4).replace(dtype="float32")
    m_s = build_model(cfg_s)
    p_s = m_s.init(jax.random.PRNGKey(0))
    m_b = build_model(cfg_b)
    p_b = m_b.init(jax.random.PRNGKey(1))

    n_req, max_new, cache_len, lane_batch = 4, 6, 32, 4
    prng = np.random.default_rng(3)
    prompts = [prng.integers(0, cfg_s.vocab_size, 6).astype(np.int32)
               for _ in range(n_req)]

    def alone(cfg, model, params):
        eng = CascadeServingEngine(cfg, model, params,
                                   lane_batch=lane_batch, n_lanes=1,
                                   cache_len=cache_len)
        for i in range(n_req):
            eng.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=max_new))
        eng.run(300)
        return eng

    def tier_run(ths0, esc_th):
        e0 = CascadeServingEngine(
            cfg_s.with_cascade(thresholds=ths0)
                 .with_escalation(enabled=True, threshold=esc_th),
            m_s, p_s, lane_batch=lane_batch, n_lanes=1,
            cache_len=cache_len)
        e1 = CascadeServingEngine(
            cfg_b.with_cascade(thresholds=(0.5, 0.0)),
            m_b, p_b, lane_batch=lane_batch, n_lanes=1,
            cache_len=cache_len)
        tier = ModelCascadeTier([e0, e1])
        for i in range(n_req):
            tier.submit(Request(rid=i, prompt=prompts[i],
                                max_new_tokens=max_new))
        fin = tier.run(400)
        return tier, {rid: tuple(r["tokens"]) for rid, r in fin.items()}

    small = alone(cfg_s.with_cascade(thresholds=(0.5, 0.0)), m_s, p_s)
    big = alone(cfg_b.with_cascade(thresholds=(0.5, 0.0)), m_b, p_b)
    _, never_streams = tier_run((0.5, 0.0), 0.0)
    _, always_streams = tier_run((1.1, 0.0), 1.1)
    never_ok = _streams(small) == never_streams
    always_ok = _streams(big) == always_streams
    rows.append(("llm_cascade/escalation/parity", 0.0,
                 f"never_identical={never_ok};"
                 f"always_identical={always_ok}"))

    # mid threshold: the median observed final confidence splits the
    # draft's answers roughly in half between commit and defer
    confs = [c for r in small.finished.values() for c in r["confs"]]
    mid_th = float(np.median(confs))
    mid_tier, _ = tier_run((0.5, 0.0), mid_th)
    mst = mid_tier.stats()
    esc1 = mst["stages"][1]["escalation"]
    rows.append(("llm_cascade/escalation/mid", 0.0,
                 f"th={mid_th:.4g};"
                 f"escalations={mst['escalations_total']};"
                 f"replayed_prefill={esc1['prefill_positions_replayed']};"
                 f"discarded={mst['discarded_draft_tokens']}"))

    # --- matched-accuracy solve on real per-stage MAC prefixes ---------
    p0 = segment_macs_per_token(cfg_s, cache_len)
    p1 = segment_macs_per_token(cfg_b, cache_len)
    scale = p1[-1]
    # replay overhead: re-prefilling the committed prefix into the target,
    # amortized per escalated token — priced at 10% of the target's depth
    overhead = 0.1 * p1[-1]
    prefix = [x / scale
              for x in compose_mac_prefix([p0, p1], [overhead])]
    n_samples = 4096 if quick else 16384
    srng = np.random.default_rng(7)
    z = srng.uniform(size=n_samples)            # latent token difficulty

    def noisy(base, slope, sd):
        return np.clip(base - slope * z
                       + srng.normal(0.0, sd, size=n_samples),
                       0.0, 0.999)

    c0i = noisy(0.90, 0.80, 0.08)               # draft intra confidence
    c0f = noisy(1.05, 1.00, 0.05)               # escalation axis
    c1i = noisy(1.00, 0.70, 0.08)               # target intra confidence
    u = srng.uniform(size=(4, n_samples))
    a0i = (u[0] < 0.35 + 0.55 * c0i).astype(np.float64)
    a0f = (u[1] < 0.55 + 0.44 * c0f).astype(np.float64)  # calibrated draft
    a1i = (u[2] < 0.50 + 0.42 * c1i).astype(np.float64)
    a1f = (u[3] < 0.92 - 0.10 * z).astype(np.float64)    # flat-ish target
    hist = ExitHistogram.from_samples(
        confidences=[c0i, c0f, c1i],
        agrees=[a0i, a0f, a1i, a1f],            # final row => labeled
        mac_prefix=prefix, bins=32)
    res = solve_epsilon(hist, 0.0)
    tier_macs, tier_acc = hist.evaluate(res.edges)
    ths0, esc_th, ths1 = split_tier_thresholds(res.thresholds, len(p0))
    big_macs, big_acc = 1.0, float(a1f.mean())
    small_macs, small_acc = p0[-1] / scale, float(a0f.mean())
    rows.append(("llm_cascade/escalation/tier", 0.0,
                 f"avg_macs={tier_macs:.3f};accuracy={tier_acc:.3f};"
                 f"feasible={res.feasible}"))
    rows.append(("llm_cascade/escalation/big_only", 0.0,
                 f"avg_macs={big_macs:.3f};accuracy={big_acc:.3f}"))
    rows.append(("llm_cascade/escalation/small_only", 0.0,
                 f"avg_macs={small_macs:.3f};accuracy={small_acc:.3f}"))
    return {
        "draft_layers": cfg_s.n_layers,
        "target_layers": cfg_b.n_layers,
        "never_streams_identical": bool(never_ok),
        "always_streams_identical": bool(always_ok),
        "mid_threshold": mid_th,
        "mid_escalations": mst["escalations_total"],
        "mid_replayed_prefill": esc1["prefill_positions_replayed"],
        "mid_discarded_draft_tokens": mst["discarded_draft_tokens"],
        "epsilon": 0.0,
        "feasible": bool(res.feasible),
        "tier_avg_macs": float(tier_macs),
        "tier_accuracy": float(tier_acc),
        "big_avg_macs": float(big_macs),
        "big_accuracy": float(big_acc),
        "small_avg_macs": float(small_macs),
        "small_accuracy": float(small_acc),
        "thresholds_stage0": list(ths0),
        "escalation_threshold": float(esc_th),
        "thresholds_stage1": list(ths1),
        "mac_prefix": list(prefix),
        "n_samples": n_samples,
        "bins": 32,
    }


def run(quick: bool = False):
    global LAST_SERVING_SUMMARY
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    n_req = 2 if quick else 6
    ths_grid = (0.0, 0.5) if quick else (0.0, 0.5, 1.1)
    for th in ths_grid:
        per_mode = {}
        for mode in ("select", "cond_batch"):
            c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode=mode)
            st = _drive(c, model, params, n_req=n_req).stats()
            per_mode[mode] = st
            rows.append((f"llm_cascade/th={th:g}/{mode}",
                         st["wallclock_us_per_token"] or 0.0,
                         f"analytic={st['analytic_speedup']:.3f};"
                         f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                         f"opportunity={st['skip_opportunity_rate']:.3f}"))
        sel, cb = (per_mode["select"]["wallclock_us_per_token"],
                   per_mode["cond_batch"]["wallclock_us_per_token"])
        wc = (sel / cb) if (sel and cb) else 1.0
        rows.append((f"llm_cascade/th={th:g}/wallclock_speedup", 0.0,
                     f"{wc:.3f}"))
    # alternative measures through the same registry-resolved engine path —
    # patience@2 carries its streaks in the lane DecodeState and still skips
    measures = ("patience@2",) if quick else ("entropy", "patience@2")
    for measure in measures:
        c = cfg.with_cascade(thresholds=(0.5, 0.0), exit_mode="cond_batch",
                             confidence=measure)
        st = _drive(c, model, params, n_req=n_req).stats()
        rows.append((f"llm_cascade/measure={measure}",
                     st["wallclock_us_per_token"] or 0.0,
                     f"analytic={st['analytic_speedup']:.3f};"
                     f"skip_rate={st['cond_batch_skip_rate']:.3f}"))

    # ------------------------------------------------------------------
    # the skip-aware hot-path ablation (persisted to BENCH_serving.json):
    # host-vs-device x cohort-layout x kernels, full threshold sweep.
    # A 3-component cascade on a 3-layer reduced config: two deep segments,
    # so the copy layout pays its per-segment slice+concat twice per step —
    # the copy overhead the cohort-major layout deletes.  Exactly at
    # capacity (2 lanes x SERVE_LANE_BATCH slots): with no queued requests
    # every compared run admits at the same points, so identical-semantics
    # runs (copy vs major at equal n_cohorts) execute bit-identical token
    # streams (asserted below, recorded per row as streams_identical).
    scfg = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32").with_cascade(
            n_components=3, exit_boundaries=(1, 2), exit_mode="cond_batch",
            n_cohorts=N_COHORTS)
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(1))
    serving_rows = []
    rt_req = 2 * SERVE_LANE_BATCH
    # many short waves beat few long ones: the engines interleave at wave
    # granularity, so shorter waves = finer interleave = better cancellation
    # of machine-load drift between the compared variants
    max_new = 12 if quick else 16
    waves = 6 if quick else 8
    # the four compared engines per threshold; measured waves run
    # INTERLEAVED across them (host load drifts on multi-second scales —
    # back-to-back runs would hand whole waves of drift to one variant).
    # the kernels-on cohort-major variants additionally run the per-segment
    # megakernel + cohort cache scatter (cfg.kernel_tune) — streams_identical
    # below therefore pins megakernel-vs-unfused end to end, since "copy"
    # keeps the plain kernel path
    variants = (("host", "host", "major", True, True),
                ("major", "device", "major", True, True),
                ("copy", "device", "copy", True, False),
                ("nokernel", "device", "major", False, False))

    def serve_ablation(th):
        engines = {}
        for name, runtime, layout, kernels, tune in variants:
            c = scfg.replace(use_kernels=kernels).with_cascade(
                thresholds=(th, th, 0.0), cohort_layout=layout)
            if tune:
                c = c.with_kernel_tune(megakernel=True, cohort_scatter=True)
            eng = _drive(c, smodel, sparams, n_req=rt_req, max_new=max_new,
                         runtime=runtime, lane_batch=SERVE_LANE_BATCH,
                         cache_len=SERVE_CACHE_LEN, waves=0)
            engines[name] = eng
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, scfg.vocab_size, 8).astype(np.int32)
                   for _ in range((waves + 1) * rt_req)]
        for w in range(1, waves + 1):            # interleaved measured waves
            for name, eng in engines.items():
                for i in range(w * rt_req, (w + 1) * rt_req):
                    eng.submit(Request(rid=i, prompt=prompts[i],
                                       max_new_tokens=max_new))
                eng.run(300)
        stats = {}
        for name, runtime, layout, kernels, _tune in variants:
            st = engines[name].stats()
            stats[name] = st
            rows.append((
                f"llm_cascade/th={th:g}/runtime={runtime}/layout={layout}/"
                f"kernels={'on' if kernels else 'off'}",
                st["wallclock_us_per_token"] or 0.0,
                f"analytic={st['analytic_speedup']:.3f};"
                f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                f"opportunity={st['skip_opportunity_rate']:.3f};"
                f"compile_s={st['compile_seconds']:.2f}"))
        return engines, stats

    def paged_ablation(th, dense_host_eng):
        """Dense vs paged KV layout at one threshold.

        Two measurements: (i) bit-identity at capacity — a paged engine
        with the SAME lane shape sees the same traffic as the ablation's
        host engine and must produce identical token streams; (ii) an
        equal-memory admission burst — the paged engine runs twice the
        slots inside the dense slab's byte budget (its pool is capped at
        the dense-equivalent block count), so queued requests admit
        sooner (fewer ticks submit->admit) and the block pool's peak
        occupancy stays below the always-resident dense slab.  Both burst
        metrics are deterministic tick/byte counts, not wall-clock."""
        base = scfg.replace(use_kernels=True).with_cascade(
            thresholds=(th, th, 0.0), cohort_layout="major")
        paged = base.with_paged_cache(layout="paged",
                                      block_size=PAGED_BLOCK)
        e_par = _drive(paged, smodel, sparams, n_req=rt_req,
                       max_new=max_new, runtime="host",
                       lane_batch=SERVE_LANE_BATCH,
                       cache_len=SERVE_CACHE_LEN, waves=waves)
        identical = _streams(dense_host_eng) == _streams(e_par)
        # the paged parity engine auto-sized its pool to the dense
        # equivalent of THIS lane shape (+ trash block) — reuse that as
        # the equal-memory cap for the double-slot burst engine
        pool_cap = e_par.pcache.pool.num_blocks
        big = base.with_paged_cache(layout="paged", block_size=PAGED_BLOCK,
                                    num_blocks=pool_cap)
        burst = 3 * rt_req

        def admission(cfg_, lane_batch):
            eng = CascadeServingEngine(cfg_, smodel, sparams,
                                       lane_batch=lane_batch, n_lanes=2,
                                       cache_len=SERVE_CACHE_LEN,
                                       runtime="host")
            arng = np.random.default_rng(0)
            for i in range(burst):
                eng.submit(Request(
                    rid=i,
                    prompt=arng.integers(0, scfg.vocab_size,
                                         8).astype(np.int32),
                    max_new_tokens=max_new))
            eng.run(600)
            assert len(eng.finished) == burst
            return eng.stats()

        ad = admission(base, SERVE_LANE_BATCH)
        ap = admission(big, 2 * SERVE_LANE_BATCH)
        st_par = e_par.stats()
        out = {
            "paged_streams_identical": identical,
            "paged_us_per_token": st_par["wallclock_us_per_token"],
            "dense_peak_cache_bytes": ad["memory"]["peak_cache_bytes"],
            "paged_peak_cache_bytes": ap["memory"]["peak_cache_bytes"],
            "paged_pool_blocks": ap["memory"]["num_blocks"],
            "paged_peak_blocks": ap["memory"]["peak_blocks_used"],
            "paged_reclaimed_by_exit": ap["memory"]["reclaimed_by_exit"],
            "paged_reclaimed_at_retire":
                ap["memory"]["reclaimed_at_retire"],
            "dense_admission_wait_mean": ad["admission_wait_mean"],
            "paged_admission_wait_mean": ap["admission_wait_mean"],
        }
        rows.append((
            f"llm_cascade/th={th:g}/cache_layout=paged",
            st_par["wallclock_us_per_token"] or 0.0,
            f"streams_identical={identical};"
            f"admission_wait={out['paged_admission_wait_mean']:.2f}"
            f"_vs_dense={out['dense_admission_wait_mean']:.2f};"
            f"peak_bytes={out['paged_peak_cache_bytes']}"
            f"_vs_dense={out['dense_peak_cache_bytes']};"
            f"reclaimed_by_exit={out['paged_reclaimed_by_exit']}"))
        return out

    # execution-backend provenance: a kernel_speedup row measured through
    # the Pallas interpreter (CPU CI) must never be read as a compiled
    # number — check_bench_serving gates compiled rows strictly and treats
    # interpret rows as advisory
    from repro.serving.runtime import kernel_provenance
    provenance = kernel_provenance(scfg.replace(use_kernels=True))
    for th in SERVE_THRESHOLDS:
        engines, stats = serve_ablation(th)
        paged_row = paged_ablation(th, engines["host"])
        host_st, major_st = stats["host"], stats["major"]
        copy_st, off_st = stats["copy"], stats["nokernel"]
        identical = _streams(engines["major"]) == _streams(engines["copy"])
        hu = host_st["wallclock_us_per_token"]
        du = major_st["wallclock_us_per_token"]
        cu = copy_st["wallclock_us_per_token"]
        ou = off_st["wallclock_us_per_token"]
        device_speedup = (hu / du) if (hu and du) else 1.0
        layout_speedup = (cu / du) if (cu and du) else 1.0
        kernel_speedup = (ou / du) if (ou and du) else 1.0
        rows.append((f"llm_cascade/th={th:g}/device_speedup", 0.0,
                     f"{device_speedup:.3f}"))
        rows.append((f"llm_cascade/th={th:g}/layout_speedup", 0.0,
                     f"{layout_speedup:.3f};streams_identical={identical}"))
        rows.append((f"llm_cascade/th={th:g}/kernel_speedup", 0.0,
                     f"{kernel_speedup:.3f}"))
        serving_rows.append({
            "threshold": th,
            "host_us_per_token": hu,
            "device_us_per_token": du,
            "device_speedup": device_speedup,
            "copy_us_per_token": cu,
            "major_us_per_token": du,
            "layout_speedup": layout_speedup,
            "kernels_off_us_per_token": ou,
            "kernel_speedup": kernel_speedup,
            "streams_identical": identical,
            "realized_skip_rate": major_st["cond_batch_skip_rate"],
            "opportunity_rate": major_st["skip_opportunity_rate"],
            "mac_speedup": major_st["analytic_speedup"],
            "compile_seconds_host": host_st["compile_seconds"],
            "compile_seconds_device": major_st["compile_seconds"],
            **provenance,
            **paged_row,
        })
    escalation = _escalation_ablation(rows, quick)
    LAST_SERVING_SUMMARY = {
        "bench": "llm_cascade",
        "arch": scfg.name,
        "lane_batch": SERVE_LANE_BATCH,
        "cache_len": SERVE_CACHE_LEN,
        "chunk": CHUNK,
        "n_cohorts": N_COHORTS,
        "n_components": scfg.cascade.n_components,
        "use_kernels": True,
        "megakernel": True,
        "cohort_scatter": True,
        "paged_block_size": PAGED_BLOCK,
        "quick": bool(quick),
        "rows": serving_rows,
        "escalation": escalation,
    }
    return rows
