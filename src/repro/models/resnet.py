"""CI-RESNET(n) — the paper's experimental architecture (Fig. 2), in JAX.

RESNET(n) = 3x3 stem conv (32 filters) + 3 ResNet modules of n blocks
(first block of modules 1,2 subsamples with stride 2) + GAP + FC + softmax.
CI-RESNET(n) adds classifier branches after modules 0 and 1 with the paper's
*classifier enhancement*: GAP → FC(width → enhance_dim) → ReLU →
FC(enhance_dim → n_c) — a constant-overhead widening ("1.5% more parameters,
0.01% more computation" for n=18).

Module widths are (16, 32, 64) — the classic [HZRS15a] CIFAR ResNet profile.
The paper's text says the stem has 32 filters, but its *reported speedups*
(×2.953 max on SVHN ⇒ MAC(M_{0,1,2})/MAC(M_0) ≈ 3) require near-equal
per-module MAC costs, which only the halving-width/halving-resolution profile
(16, 32, 64) provides.  We follow the measured ratios (they are what the
reproduction validates) and record the stem discrepancy in DESIGN.md.

BatchNorm carries running statistics; ``apply`` takes ``train`` and returns
updated BN state.  Weight init: N(0, sqrt(2/k)) per [HZRS15b], as the paper
specifies.  Components are *nested prefixes*: component m reuses the feature
map of component m−1 (the paper's cascade reuse property), exposed through
``component_apply`` for Algorithm-1 sequential inference.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

WIDTHS = (16, 32, 64)
BN_MOMENTUM = 0.9


def _conv_init(key, k, c_in, c_out):
    fan_in = k * k * c_in
    std = math.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (k, k, c_in, c_out), jnp.float32) * std


def _fc_init(key, c_in, c_out):
    std = math.sqrt(2.0 / c_in)
    return jax.random.normal(key, (c_in, c_out), jnp.float32) * std


def _bn_init(c):
    return {"scale": jnp.ones((c,)), "bias": jnp.zeros((c,))}


def _bn_state(c):
    return {"mean": jnp.zeros((c,)), "var": jnp.ones((c,))}


def conv2d(x, w, stride=1):
    return lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(x, params, state, train: bool, eps=1e-5):
    if train:
        mean = jnp.mean(x, axis=(0, 1, 2))
        var = jnp.var(x, axis=(0, 1, 2))
        new_state = {
            "mean": BN_MOMENTUM * state["mean"] + (1 - BN_MOMENTUM) * mean,
            "var": BN_MOMENTUM * state["var"] + (1 - BN_MOMENTUM) * var,
        }
    else:
        mean, var = state["mean"], state["var"]
        new_state = state
    y = (x - mean) * lax.rsqrt(var + eps) * params["scale"] + params["bias"]
    return y, new_state


class CIResNet:
    def __init__(self, n_blocks: int, n_classes: int, enhance_dim: int = 128):
        self.n = n_blocks
        self.n_classes = n_classes
        self.enhance_dim = enhance_dim

    # ------------------------------------------------------------------
    def init(self, key) -> Tuple[Dict, Dict]:
        keys = iter(jax.random.split(key, 16 + 6 * 3 * self.n))
        params: Dict[str, Any] = {"stem": {"w": _conv_init(next(keys), 3, 3,
                                                           WIDTHS[0]),
                                           "bn": _bn_init(WIDTHS[0])}}
        state: Dict[str, Any] = {"stem": _bn_state(WIDTHS[0])}
        for mod in range(3):
            c_in = WIDTHS[mod - 1] if mod else WIDTHS[0]
            c_out = WIDTHS[mod]
            blocks_p, blocks_s = [], []
            for b in range(self.n):
                ci = c_in if b == 0 else c_out
                stride = 2 if (b == 0 and mod > 0) else 1
                bp = {"conv1": _conv_init(next(keys), 3, ci, c_out),
                      "bn1": _bn_init(c_out),
                      "conv2": _conv_init(next(keys), 3, c_out, c_out),
                      "bn2": _bn_init(c_out)}
                bs = {"bn1": _bn_state(c_out), "bn2": _bn_state(c_out)}
                if stride == 2 or ci != c_out:
                    bp["proj"] = _conv_init(next(keys), 1, ci, c_out)
                blocks_p.append(bp)
                blocks_s.append(bs)
            params[f"module{mod}"] = blocks_p
            state[f"module{mod}"] = blocks_s
        # classifiers: enhanced heads 0,1; plain head 2
        for m in range(2):
            params[f"head{m}"] = {
                "w1": _fc_init(next(keys), WIDTHS[m], self.enhance_dim),
                "b1": jnp.zeros((self.enhance_dim,)),
                "w2": _fc_init(next(keys), self.enhance_dim, self.n_classes),
                "b2": jnp.zeros((self.n_classes,)),
            }
        params["head2"] = {"w": _fc_init(next(keys), WIDTHS[2], self.n_classes),
                           "b": jnp.zeros((self.n_classes,))}
        return params, state

    # ------------------------------------------------------------------
    def _block(self, bp, bs, x, stride, train):
        y, s1 = batchnorm(conv2d(x, bp["conv1"], stride), bp["bn1"],
                          bs["bn1"], train)
        y = jax.nn.relu(y)
        y, s2 = batchnorm(conv2d(y, bp["conv2"]), bp["bn2"], bs["bn2"], train)
        if "proj" in bp:
            x = conv2d(x, bp["proj"], stride)
        out = jax.nn.relu(x + y)
        return out, {"bn1": s1, "bn2": s2}

    def _module(self, params, state, x, mod, train):
        new_states = []
        for b, (bp, bs) in enumerate(zip(params[f"module{mod}"],
                                         state[f"module{mod}"])):
            stride = 2 if (b == 0 and mod > 0) else 1
            x, ns = self._block(bp, bs, x, stride, train)
            new_states.append(ns)
        return x, new_states

    def _head(self, params, m, x):
        feat = jnp.mean(x, axis=(1, 2))                # GAP
        if m < 2:
            h = params[f"head{m}"]
            z = jax.nn.relu(feat @ h["w1"] + h["b1"])
            return z @ h["w2"] + h["b2"]
        h = params["head2"]
        return feat @ h["w"] + h["b"]

    # ------------------------------------------------------------------
    def apply(self, params, state, x, train: bool = False):
        """x: (B,32,32,3).  Returns ([logits_m]*3, new_state)."""
        new_state: Dict[str, Any] = {}
        y, s = batchnorm(conv2d(x, params["stem"]["w"]), params["stem"]["bn"],
                         state["stem"], train)
        new_state["stem"] = s
        y = jax.nn.relu(y)
        logits = []
        for mod in range(3):
            y, ns = self._module(params, state, y, mod, train)
            new_state[f"module{mod}"] = ns
            logits.append(self._head(params, mod, y))
        return logits, new_state

    # ------------------------------------------------------------------
    def component_fns(self, params, state):
        """Per-component functions for Algorithm 1: component m consumes the
        feature map produced by component m−1 (nested-prefix reuse)."""
        def make(m):
            def fn(x, carry):
                if m == 0:
                    y, _ = batchnorm(conv2d(x, params["stem"]["w"]),
                                     params["stem"]["bn"], state["stem"],
                                     False)
                    y = jax.nn.relu(y)
                else:
                    y = carry
                y, _ = self._module(params, state, y, m, False)
                return self._head(params, m, y), y
            return fn
        return [make(m) for m in range(3)]
