from repro.serving.engine import CascadeServingEngine, Request
from repro.serving.batching import DepthCompactor

__all__ = ["CascadeServingEngine", "Request", "DepthCompactor"]
