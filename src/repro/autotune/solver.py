"""Histogram-space threshold solver: coordinate descent over δ̂_m.

The §5 routine (`repro.core.calibration`) tunes each component's threshold
against its OWN accuracy curve, independently — but the cascade is a
pipeline: raising δ̂_0 changes the sample population component 1 sees, so
the per-component optima do not compose into the cascade optimum (the
framing of Streeter, *Approximation Algorithms for Cascading Prediction
Models*, 2018, and the joint-beats-independent result of Enomoto & Eda,
*Learning to Cascade*, 2021).  This module solves the joint problem in
histogram space, in both directions the serving system needs:

* :func:`solve_epsilon` — target accuracy degradation ε → thresholds
  (generalizing §5: the constraint is the *cascade's* agreement with the
  full-depth model, not each component's self-accuracy);
* :func:`solve_budget` — target average-MAC budget → thresholds (the
  per-component search that dominates ``BudgetPolicy``'s shared exit
  quantile at equal budget: the shared-quantile solution is one of the
  solver's starting points, and coordinate moves only ever improve the
  objective, so the result is never worse).

Everything operates on an :class:`ExitHistogram` — the joint fixed-bin
histogram of the routing components' confidences with per-component
agreement counts, either accumulated live on device
(:class:`repro.autotune.telemetry.ExitTelemetry`) or built from raw
samples (:meth:`ExitHistogram.from_samples`, the host-recompute
reference the device accumulation is tested against).  Thresholds live on
the bin grid: edge index e ∈ [0, bins] maps to δ = e/bins (e = bins means
"never exit", deployed as the repo's sentinel 1.1), and the binning rule
``bin = min(floor(c·bins), bins-1)`` makes the bin gate ``bin >= e``
*exactly* equivalent to the engine's ``conf >= δ`` gate.

A coordinate sweep marginalizes the joint histogram once (O(cells)) into
per-bin profiles — count, exit-here agreement, continue-downstream MACs and
agreement — after which every candidate edge is a prefix/suffix sum:
O(bins) per swept coordinate, no re-scan of the data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np

MAX_SWEEPS = 64
# feasibility slop for float comparisons on count sums
_EPS = 1e-9


def thresholds_from_edges(edges: Sequence[int], bins: int) -> Tuple[float, ...]:
    """Bin-edge indices (routing components) → deployable threshold vector
    (the final component's threshold is always 0; e == bins → never exit,
    deployed as the sentinel 1.1 like ``threshold_for_epsilon``)."""
    out = [1.1 if e >= bins else float(e) / bins for e in edges]
    return tuple(out) + (0.0,)


def edges_from_thresholds(thresholds: Sequence[float], bins: int
                          ) -> Tuple[int, ...]:
    """Quantize a deployed threshold vector (routing components; a trailing
    final-component 0 is ignored) onto the bin grid: the smallest edge whose
    gate ``bin >= e`` admits no sample the real gate ``conf >= δ`` rejects."""
    ths = list(thresholds)
    if len(ths) >= 2 and ths[-1] == 0.0:
        ths = ths[:-1]
    out = []
    for t in ths:
        if t > 1.0:
            out.append(bins)
        else:
            out.append(int(np.clip(np.ceil(t * bins - _EPS), 0, bins)))
    return tuple(out)


@dataclasses.dataclass
class SolveResult:
    thresholds: Tuple[float, ...]   # n_components, final forced to 0.0
    edges: Tuple[int, ...]          # routing-component bin edges
    avg_macs: float                 # expected MACs/sample on the histogram
    agreement: float                # expected agreement with the final comp
    sweeps: int                     # coordinate sweeps until convergence
    feasible: bool                  # constraint met (False = best effort)


@dataclasses.dataclass
class ExitHistogram:
    """Joint routing-confidence histogram + agreement counts (host numpy).

    counts      (bins,) * n_routing — joint cell counts (C-order,
                component 0 slowest-varying, matching the device layout).
    agree       (n_routing,) + counts.shape — per component, how many of
                the cell's samples had that component agreeing with final.
    mac_prefix  (n_routing + 1,) — analytic MACs of answering at each
                component (the paper's §6.2 currency).
    bins        histogram resolution.
    """

    counts: np.ndarray
    agree: np.ndarray
    mac_prefix: np.ndarray
    bins: int
    # per-cell correctness counts of the FINAL component.  None = the
    # agreement-with-final proxy, under which the final component is
    # correct by definition; set from real labels in offline fits
    # (BudgetPolicy.fit / the benchmarks) so the constraint targets true
    # cascade accuracy instead.
    final_agree: Optional[np.ndarray] = None

    def __post_init__(self):
        self.counts = np.asarray(self.counts, np.float64)
        self.agree = np.asarray(self.agree, np.float64)
        self.mac_prefix = np.asarray(self.mac_prefix, np.float64)
        r = self.counts.ndim
        if self.counts.shape != (self.bins,) * r:
            raise ValueError(f"counts shape {self.counts.shape} is not "
                             f"(bins,)*{r} with bins={self.bins}")
        if self.agree.shape != (r,) + self.counts.shape:
            raise ValueError(f"agree shape {self.agree.shape} != "
                             f"{(r,) + self.counts.shape}")
        if self.mac_prefix.shape != (r + 1,):
            raise ValueError(f"mac_prefix shape {self.mac_prefix.shape} != "
                             f"({r + 1},)")
        if self.final_agree is not None:
            self.final_agree = np.asarray(self.final_agree, np.float64)
            if self.final_agree.shape != self.counts.shape:
                raise ValueError(
                    f"final_agree shape {self.final_agree.shape} != "
                    f"{self.counts.shape}")

    # ------------------------------------------------------------------
    @property
    def n_routing(self) -> int:
        return self.counts.ndim

    @property
    def n_components(self) -> int:
        return self.counts.ndim + 1

    @property
    def total(self) -> float:
        return float(self.counts.sum())

    @property
    def final_accuracy(self) -> float:
        """Accuracy of always answering at the final component — 1.0 under
        the agreement proxy, the labeled rate when final_agree is set."""
        if self.final_agree is None:
            return 1.0
        tot = self.total
        return float(self.final_agree.sum()) / tot if tot else 1.0

    def _agree_ext(self) -> np.ndarray:
        """(n_components,) + cells: per-component correct-answer counts,
        with the final row the proxy (counts) or the labeled correctness."""
        final = (self.counts if self.final_agree is None
                 else self.final_agree)
        return np.concatenate([self.agree, final[None]], axis=0)

    # ------------------------------------------------------------------
    @classmethod
    def from_samples(cls, confidences, agrees, mac_prefix,
                     bins: int) -> "ExitHistogram":
        """Build from raw per-sample vectors — the host-recompute reference
        for the device accumulation (same binning, same C-order cells).

        confidences: (n_routing, N) or (n_components, N) — a final-
        component confidence row never routes and is dropped.  agrees:
        same leading dim; when an (n_components, N) correctness matrix is
        given, the final row becomes the labeled ``final_agree`` (true
        accuracy) instead of the agreement proxy.
        """
        conf = np.asarray(confidences, np.float64)
        agr = np.asarray(agrees, np.float64)
        n_m = len(mac_prefix)
        if conf.shape[0] == n_m:
            conf = conf[:-1]
        final_row = None
        if agr.shape[0] == n_m:
            final_row = agr[-1]
            agr = agr[:-1]
        r = n_m - 1
        if conf.shape[0] != r or agr.shape != conf.shape:
            raise ValueError(
                f"need ({r}, N) routing confidences/agreements for "
                f"{n_m} components; got {conf.shape} / {agr.shape}")
        # bin in f32 exactly like the device (telemetry.conf_to_bin /
        # the fused kernel): the f32-vs-f64 product can round across an
        # integer at bin edges for non-power-of-two bin counts, which
        # would break the bit-match contract with device accumulation
        b = np.clip((conf.astype(np.float32)
                     * np.float32(bins)).astype(np.int64), 0, bins - 1)
        flat = np.ravel_multi_index(tuple(b), (bins,) * r)
        cells = bins ** r
        counts = np.bincount(flat, minlength=cells).astype(np.float64)
        agree = np.stack([np.bincount(flat, weights=agr[m], minlength=cells)
                          for m in range(r)])
        final_agree = (None if final_row is None else np.bincount(
            flat, weights=final_row, minlength=cells).reshape((bins,) * r))
        return cls(counts=counts.reshape((bins,) * r),
                   agree=agree.reshape((r,) + (bins,) * r),
                   mac_prefix=np.asarray(mac_prefix, np.float64), bins=bins,
                   final_agree=final_agree)

    @classmethod
    def from_telemetry(cls, tel, mac_prefix=None,
                       bins: Optional[int] = None) -> "ExitHistogram":
        """Build from accumulated telemetry (an ExitTelemetry pytree or the
        host counter dict from ``telemetry_to_host``/``merge_telemetry``).
        ``mac_prefix`` defaults to the carried ``mac_weights``.

        The routing-axis count is the telemetry's ``shadow_agree`` row
        count: ``n_components - 1`` normally, ``n_components`` when the
        telemetry was accumulated under ``autotune.route_final`` (the
        final component's confidence is itself a routing axis — the
        escalation tier's defer decision).  Route-final telemetry needs an
        explicit ``mac_prefix`` of ``n_components + 1`` entries: the extra
        final entry prices *deferring past* the final component (next
        stage's cost), which no single engine can know."""
        if not isinstance(tel, dict):
            from repro.autotune.telemetry import telemetry_to_host
            tel = telemetry_to_host(tel)
        n_m = tel["exit_counts"].shape[0]
        r = tel["shadow_agree"].shape[0]
        if mac_prefix is None:
            if r != n_m - 1:
                raise ValueError(
                    "route_final telemetry needs an explicit mac_prefix "
                    f"of {r + 1} entries (the final entry prices the "
                    "next escalation stage)")
            mac_prefix = tel["mac_weights"]
            if not np.any(np.asarray(mac_prefix)):
                raise ValueError(
                    "telemetry carries zero mac_weights; pass mac_prefix= "
                    "(repro.core.macs.segment_macs_per_token)")
        cells = tel["shadow_count"].shape[0]
        if bins is None:
            bins = int(round(cells ** (1.0 / r))) if r else int(cells)
        if bins ** r != cells:
            raise ValueError(f"{cells} cells is not bins^{r} for any "
                             f"integer bins (got bins={bins})")
        return cls(
            counts=np.asarray(tel["shadow_count"],
                              np.float64).reshape((bins,) * r),
            agree=np.asarray(tel["shadow_agree"],
                             np.float64).reshape((r,) + (bins,) * r),
            mac_prefix=np.asarray(mac_prefix, np.float64), bins=bins)

    # ------------------------------------------------------------------
    def marginal(self, m: int) -> Tuple[np.ndarray, np.ndarray]:
        """(count_b, agree_b) of component m's confidence, marginalized
        over the other routing components — the §5-style view."""
        axes = tuple(a for a in range(self.n_routing) if a != m)
        return (self.counts.sum(axis=axes) if axes else self.counts.copy(),
                self.agree[m].sum(axis=axes) if axes
                else self.agree[m].copy())

    def _exit_map(self, edges: np.ndarray) -> np.ndarray:
        """Answering component per cell under bin-edge thresholds."""
        grids = np.indices(self.counts.shape)
        exceeds = grids >= edges.reshape((-1,) + (1,) * self.n_routing)
        first = np.argmax(exceeds, axis=0)
        return np.where(exceeds.any(axis=0), first, self.n_routing)

    def evaluate(self, edges: Sequence[int]) -> Tuple[float, float]:
        """(avg MACs per sample, agreement fraction) of the cascade under
        the given routing-edge thresholds."""
        edges = np.asarray(edges, np.int64)
        ex = self._exit_map(edges)
        total = self.total
        if total <= 0:
            return float(self.mac_prefix[-1]), 1.0
        macs = float((self.counts * self.mac_prefix[ex]).sum()) / total
        agr = float(np.take_along_axis(self._agree_ext(), ex[None],
                                       axis=0)[0].sum()) / total
        return macs, agr

    # ------------------------------------------------------------------
    def coordinate_profile(self, edges: Sequence[int], m: int
                           ) -> Tuple[np.ndarray, np.ndarray]:
        """Total (MAC, agreement) counts as a function of edge e_m, holding
        every other edge fixed: arrays of shape (bins + 1,) indexed by the
        candidate edge.  One O(cells) marginalization + O(bins) sums —
        the inner loop of every coordinate sweep.
        """
        edges = np.asarray(edges, np.int64)
        r = self.n_routing
        grids = np.indices(self.counts.shape)
        # reaches m: no earlier component exits
        reach = np.ones(self.counts.shape, bool)
        for j in range(m):
            reach &= grids[j] < edges[j]
        # if not exiting at m: first later exit, else final
        cont = np.full(self.counts.shape, r, np.int64)
        for j in range(r - 1, m, -1):
            cont = np.where(grids[j] >= edges[j], j, cont)
        agree_ext = self._agree_ext()
        # cells that never reach m keep their current-edge outcome
        ex = self._exit_map(edges)
        not_reach = ~reach
        macs_other = float((self.counts * self.mac_prefix[ex])[not_reach]
                           .sum())
        agree_other = float(np.take_along_axis(agree_ext, ex[None],
                                               axis=0)[0][not_reach].sum())
        # group reaching cells by b_m
        bsel = grids[m][reach]
        w = self.counts[reach]
        cnt = np.bincount(bsel, weights=w, minlength=self.bins)
        agr_exit = np.bincount(bsel, weights=self.agree[m][reach],
                               minlength=self.bins)
        cont_mac = np.bincount(bsel, weights=w * self.mac_prefix[cont[reach]],
                               minlength=self.bins)
        cont_agr = np.bincount(
            bsel, weights=np.take_along_axis(agree_ext, cont[None],
                                             axis=0)[0][reach],
            minlength=self.bins)
        # edge e: bins >= e exit here (suffix), bins < e continue (prefix)
        suf_cnt = np.concatenate([np.cumsum(cnt[::-1])[::-1], [0.0]])
        suf_agr = np.concatenate([np.cumsum(agr_exit[::-1])[::-1], [0.0]])
        pre_mac = np.concatenate([[0.0], np.cumsum(cont_mac)])
        pre_agr = np.concatenate([[0.0], np.cumsum(cont_agr)])
        macs_e = macs_other + self.mac_prefix[m] * suf_cnt + pre_mac
        agree_e = agree_other + suf_agr + pre_agr
        return macs_e, agree_e


# ---------------------------------------------------------------------------
# fleet: merging per-engine histograms
# ---------------------------------------------------------------------------

def merge_histograms(hists: Sequence[ExitHistogram]) -> ExitHistogram:
    """Merge per-engine histograms into one fleet histogram.

    Fixed-bin joint histograms over the SAME grid merge by elementwise
    addition — ``bincount(a ++ b) == bincount(a) + bincount(b)`` — so the
    merged histogram is *exactly* the histogram of the pooled samples, and
    a solve on it is exactly the pooled-sample solve (no approximation;
    `tests/test_fleet.py` and the fleet bench pin equality, not
    tolerance).  This is what makes one fleet-wide resolve K-fold faster
    to warm up than K per-engine resolves: the min_shadow evidence window
    fills from every engine's shadow sampler at once.

    Requires identical bins / routing-axis count / mac_prefix across
    members (homogeneous fleet — same model config, which the
    TelemetryAggregator enforces via ``config_key`` equality).
    ``final_agree`` must be set on all members or none; mixing a labeled
    member with proxy members would silently blend two different accuracy
    definitions.
    """
    if not hists:
        raise ValueError("merge_histograms needs at least one histogram")
    h0 = hists[0]
    for i, h in enumerate(hists[1:], start=1):
        if h.bins != h0.bins or h.n_routing != h0.n_routing:
            raise ValueError(
                f"histogram {i} has grid (bins={h.bins}, "
                f"n_routing={h.n_routing}) != member 0's (bins={h0.bins}, "
                f"n_routing={h0.n_routing}); fleet merge needs one grid")
        if not np.allclose(h.mac_prefix, h0.mac_prefix):
            raise ValueError(
                f"histogram {i} has mac_prefix {h.mac_prefix.tolist()} != "
                f"member 0's {h0.mac_prefix.tolist()}; a fleet merge is "
                "only meaningful across engines paying the same costs")
        if (h.final_agree is None) != (h0.final_agree is None):
            raise ValueError(
                "final_agree set on some members but not others — labeled "
                "and proxy accuracy definitions cannot merge")
    return ExitHistogram(
        counts=np.sum([h.counts for h in hists], axis=0),
        agree=np.sum([h.agree for h in hists], axis=0),
        mac_prefix=h0.mac_prefix.copy(),
        bins=h0.bins,
        final_agree=(None if h0.final_agree is None else
                     np.sum([h.final_agree for h in hists], axis=0)))


# ---------------------------------------------------------------------------
# cross-model escalation: heterogeneous (stage, component) composition
# ---------------------------------------------------------------------------

def compose_mac_prefix(stage_prefixes: Sequence[Sequence[float]],
                       replay_overheads: Optional[Sequence[float]] = None
                       ) -> Tuple[float, ...]:
    """MAC prefix of a multi-stage escalation tier, one entry per
    (stage, component) in stage-major order.

    ``stage_prefixes[s]`` is stage s's own per-component analytic prefix
    (``repro.core.macs.segment_macs_per_token`` on *that stage's* config —
    the per-stage heterogeneous costs).  Answering at stage s component j
    costs everything spent getting there: the FULL depth of every earlier
    stage (a deferred token was answered at the earlier stage's final
    component before the tier rejected it) plus that stage's per-token
    replay overhead (the escalated prefix is re-prefilled into the next
    stage — ``replay_overheads[s]`` amortizes it per decoded token; 0
    when prefix replay is free or disabled), plus ``stage_prefixes[s][j]``.
    """
    if not stage_prefixes:
        raise ValueError("need at least one stage prefix")
    over = list(replay_overheads) if replay_overheads is not None else \
        [0.0] * (len(stage_prefixes) - 1)
    if len(over) != len(stage_prefixes) - 1:
        raise ValueError(
            f"need {len(stage_prefixes) - 1} replay overheads for "
            f"{len(stage_prefixes)} stages, got {len(over)}")
    out, cum = [], 0.0
    for s, prefix in enumerate(stage_prefixes):
        prefix = [float(p) for p in prefix]
        if not prefix:
            raise ValueError(f"stage {s} has an empty mac prefix")
        out.extend(cum + p for p in prefix)
        cum += prefix[-1] + (over[s] if s < len(over) else 0.0)
    return tuple(out)


def compose_escalation(h0: ExitHistogram, h1: ExitHistogram, *,
                       stage_agree: float = 1.0,
                       mac_prefix=None) -> ExitHistogram:
    """Compose a draft stage's route-final histogram with the next stage's
    histogram into one joint tier histogram the unchanged
    :func:`solve_epsilon` / :func:`solve_budget` can search.

    ``h0`` must carry the stage's FINAL confidence as its last routing
    axis (telemetry accumulated under ``autotune.route_final``): in the
    tier, answering at stage 0's final component is itself a routed
    decision, and the threshold the solver assigns to that axis IS the
    escalation threshold.  ``h1`` is the next stage's ordinary histogram
    (its final component is the tier's authority).

    Two measurable quantities bridge the stages:

    * stage independence — the joint cell distribution factorizes as
      ``counts = c0 ⊗ (c1 / Σc1)``: which stage-1 confidence cell a token
      lands in is taken as independent of its stage-0 cell.  When stage 1
      has no shadow evidence yet the stage-1 factor degrades to uniform
      with zero agreement mass, so the solver routes nothing into stage
      1's intra exits until evidence arrives (deferral itself — the
      stage-1 FINAL — stays the proxy-perfect authority).
    * ``stage_agree`` — P(stage-0's answer == tier final answer at the
      same context), measured online by the tier router from rejected
      tokens vs their stage-1 regenerations.  Every stage-0 agree row is
      chained through it (``P(m = tier) ≈ P(m = stage-0 final) ·
      stage_agree`` — conditional-independence lower bound); the route-
      final row is stage-0 final's self-agreement, so scaling it makes it
      exactly the escalation axis's answer-here agreement.

    ``mac_prefix`` (``h0.n_routing + h1.n_routing + 1`` entries — build it
    with :func:`compose_mac_prefix`) replaces both stages' own prefixes.
    """
    if h0.bins != h1.bins:
        raise ValueError(
            f"stage histograms disagree on bins: {h0.bins} vs {h1.bins}")
    if h0.final_agree is not None or h1.final_agree is not None:
        raise ValueError(
            "compose_escalation composes agreement-proxy histograms; "
            "labeled final_agree stages are not composable (the label "
            "would need the joint (stage0, stage1) sample)")
    bins = h0.bins
    r0, r1 = h0.n_routing, h1.n_routing
    r = r0 + r1
    from repro.autotune.telemetry import MAX_CELLS
    if bins ** r > MAX_CELLS:
        raise ValueError(
            f"composed histogram would need {bins ** r} cells "
            f"(bins={bins}, {r} routing axes); lower autotune.bins "
            f"(cap {MAX_CELLS})")
    if mac_prefix is None:
        raise ValueError("compose_escalation needs the composed "
                         "mac_prefix (see compose_mac_prefix)")
    mac_prefix = np.asarray(mac_prefix, np.float64)
    if mac_prefix.shape != (r + 1,):
        raise ValueError(f"mac_prefix shape {mac_prefix.shape} != "
                         f"({r + 1},)")
    sa = float(stage_agree)
    if not 0.0 <= sa <= 1.0:
        raise ValueError(f"stage_agree must be in [0, 1], got {sa}")

    c0 = h0.counts.reshape(-1)
    c1 = h1.counts.reshape(-1)
    s1 = float(c1.sum())
    cells1 = c1.shape[0]
    if s1 > 0:
        p1 = c1 / s1
        a1 = h1.agree.reshape(r1, -1) / s1
    else:
        p1 = np.full(cells1, 1.0 / cells1)
        a1 = np.zeros((r1, cells1))

    counts = np.outer(c0, p1)
    agree = np.empty((r, c0.shape[0], cells1))
    a0 = h0.agree.reshape(r0, -1) * sa
    for m in range(r0):
        agree[m] = np.outer(a0[m], p1)
    for j in range(r1):
        agree[r0 + j] = np.outer(c0, a1[j])
    shape = (bins,) * r
    return ExitHistogram(counts=counts.reshape(shape),
                         agree=agree.reshape((r,) + shape),
                         mac_prefix=mac_prefix, bins=bins)


def split_tier_thresholds(thresholds: Sequence[float], n_components0: int
                          ) -> Tuple[Tuple[float, ...], float,
                                     Tuple[float, ...]]:
    """Split a composed-tier solve's threshold vector back into deployable
    pieces: (stage-0 intra thresholds, escalation threshold, stage-1
    thresholds).  The solved vector has one entry per (stage, component)
    routing axis plus the forced final 0.0; stage 0's final axis is the
    escalation threshold, and its intra vector gets its final 0.0 back
    (within stage 0 the final component always answers — whether that
    answer *stands* is the escalation decision)."""
    ths = tuple(float(t) for t in thresholds)
    k0 = int(n_components0)
    if len(ths) < k0 + 2:
        raise ValueError(
            f"composed threshold vector of {len(ths)} entries cannot "
            f"split at n_components0={k0}")
    return ths[:k0 - 1] + (0.0,), ths[k0 - 1], ths[k0:]


# ---------------------------------------------------------------------------
# coordinate descent
# ---------------------------------------------------------------------------

def _descend(hist: ExitHistogram, edges, *, minimize_macs: bool,
             constraint: float) -> Tuple[np.ndarray, int, bool]:
    """Coordinate descent from ``edges``.

    minimize_macs=True : minimize MACs subject to agreement >= constraint
                         (counts; the ε direction).
    minimize_macs=False: maximize agreement subject to MACs <= constraint
                         (counts; the budget direction).

    A feasible current edge is always among the sweep candidates, so the
    objective is monotone across sweeps — the returned point is never worse
    than the starting point.
    """
    edges = np.asarray(edges, np.int64).copy()
    r = hist.n_routing
    sweeps = 0
    for sweeps in range(1, MAX_SWEEPS + 1):
        changed = False
        for m in range(r):
            macs_e, agree_e = hist.coordinate_profile(edges, m)
            if minimize_macs:
                feas = agree_e >= constraint - _EPS
                primary, secondary = macs_e, -agree_e
            else:
                feas = macs_e <= constraint + _EPS
                primary, secondary = -agree_e, macs_e
            if feas.any():
                cand = np.where(feas, primary, np.inf)
                best_p = cand.min()
                tie = np.where(np.isclose(cand, best_p, rtol=0, atol=_EPS),
                               secondary, np.inf)
                best = int(np.argmin(tie))
                cur = int(edges[m])
                # keep the current edge on exact ties (no churn)
                if (feas[cur] and np.isclose(cand[cur], best_p, rtol=0,
                                             atol=_EPS)
                        and np.isclose(tie[cur], tie[best], rtol=0,
                                       atol=_EPS)):
                    best = cur
            else:
                # infeasible everywhere along this coordinate: move toward
                # feasibility (max agreement / min MACs respectively)
                best = int(np.argmax(agree_e) if minimize_macs
                           else np.argmin(macs_e))
            if best != edges[m]:
                edges[m] = best
                changed = True
        if not changed:
            break
    macs, agr = hist.evaluate(edges)
    total = max(hist.total, 1.0)
    # ``constraint`` is in counts (profiles sum counts); evaluate() returns
    # per-sample rates — normalize before the final feasibility verdict
    ok = (agr * total >= constraint - _EPS if minimize_macs
          else macs <= constraint / total + _EPS)
    return edges, sweeps, bool(ok)


def _result(hist: ExitHistogram, edges, sweeps: int,
            feasible: bool) -> SolveResult:
    macs, agr = hist.evaluate(edges)
    return SolveResult(
        thresholds=thresholds_from_edges(edges, hist.bins),
        edges=tuple(int(e) for e in edges),
        avg_macs=macs, agreement=agr, sweeps=sweeps, feasible=feasible)


def _corner_starts(hist: ExitHistogram):
    """Specialist starting points: route exits through ONE component
    (e_m = 0, everyone else never exits).  Coordinate descent can be
    locally optimal at allocation-tying points (the shared quantile is
    one); single-component corners are the classic escape hatches for
    cascade threshold allocation (cf. Streeter 2018's single-policy
    candidates)."""
    r = hist.n_routing
    starts = []
    for m in range(r):
        e = np.full(r, hist.bins, np.int64)
        e[m] = 0
        starts.append(e)
    return starts


def independent_epsilon_edges(hist: ExitHistogram,
                              epsilon: float) -> Tuple[int, ...]:
    """The §5 rule, per component on the marginal histograms: δ_m(ε) =
    min{δ : α_m(δ) >= α*_m − ε}, with α_m(δ) the agreement rate over
    samples with conf_m >= δ.  Exactly
    :func:`repro.core.calibration.threshold_for_epsilon` evaluated on
    binned data (and therefore exact whenever the confidences are
    bin-edge-quantized)."""
    out = []
    for m in range(hist.n_routing):
        cnt, agr = hist.marginal(m)
        suf_c = np.concatenate([np.cumsum(cnt[::-1])[::-1], [0.0]])
        suf_a = np.concatenate([np.cumsum(agr[::-1])[::-1], [0.0]])
        with np.errstate(invalid="ignore", divide="ignore"):
            alpha = np.where(suf_c > 0, suf_a / np.maximum(suf_c, 1e-300),
                             0.0)
        alpha_star = alpha.max() if len(alpha) else 0.0
        ok = alpha[:hist.bins] >= alpha_star - epsilon - _EPS
        if not ok.any():
            out.append(hist.bins)
            continue
        e = int(np.argmax(ok))
        # §5 returns the minimum over OBSERVED confidences; edges below
        # the first populated bin admit the same set, and the lowest
        # observed value lives in that bin — snap up so bin-edge-
        # quantized data reproduces threshold_for_epsilon exactly
        if cnt.any():
            e = max(e, int(np.argmax(cnt > 0)))
        out.append(e)
    return tuple(out)


def solve_epsilon(hist: ExitHistogram, epsilon: float,
                  mode: str = "joint") -> SolveResult:
    """Target accuracy degradation ε → thresholds.

    ``mode="independent"`` is the paper's §5 per-component rule on the
    marginal histograms.  ``mode="joint"`` (default) minimizes average
    MACs subject to the CASCADE's accuracy being >= (final-component
    accuracy − ε) — the agreement proxy makes that 1 − ε — by coordinate
    descent seeded from the independent solution (when feasible) and from
    never-exit (always feasible), so the joint answer never spends more
    MACs than a feasible independent answer at the same ε.
    """
    if mode not in ("joint", "independent"):
        raise ValueError(f"mode must be 'joint' or 'independent', "
                         f"got {mode!r}")
    base = hist.final_accuracy
    ind = independent_epsilon_edges(hist, epsilon)
    if mode == "independent":
        macs, agr = hist.evaluate(ind)
        ok = agr >= base - epsilon - _EPS
        return _result(hist, np.asarray(ind), 0, ok)
    total = hist.total
    need = (base - epsilon) * total
    starts = [np.full(hist.n_routing, hist.bins, np.int64)]  # never exit
    starts += _corner_starts(hist)
    _, ind_agr = hist.evaluate(ind)
    if ind_agr * total >= need - _EPS:
        starts.insert(0, np.asarray(ind, np.int64))
    best = None
    for s in starts:
        edges, sweeps, ok = _descend(hist, s, minimize_macs=True,
                                     constraint=need)
        res = _result(hist, edges, sweeps, ok)
        if best is None or (res.feasible, -res.avg_macs) > (
                best.feasible, -best.avg_macs):
            best = res
    return best


def solve_budget(hist: ExitHistogram, mac_budget: float,
                 init_edges: Optional[Sequence[int]] = None) -> SolveResult:
    """Target average-MAC budget → thresholds: maximize agreement with the
    full-depth model subject to avg MACs <= budget, by coordinate descent.

    Starts from all-exit-at-0 (always budget-feasible when the budget is
    achievable at all) and, when given, from ``init_edges`` — pass the
    quantized shared-quantile solution here and the result provably spends
    <= its MACs at >= its agreement (coordinate moves only improve)."""
    budget = float(mac_budget)
    total = hist.total
    cap = budget * max(total, 1.0)
    starts = [np.zeros(hist.n_routing, np.int64)]
    starts += _corner_starts(hist)
    if init_edges is not None:
        init = np.asarray(init_edges, np.int64)
        macs, _ = hist.evaluate(init)
        if macs <= budget + _EPS:
            starts.insert(0, init)
    best = None
    for s in starts:
        edges, sweeps, ok = _descend(hist, s, minimize_macs=False,
                                     constraint=cap)
        res = _result(hist, edges, sweeps, ok)
        key = (res.feasible, res.agreement, -res.avg_macs)
        if best is None or key > (best.feasible, best.agreement,
                                  -best.avg_macs):
            best = res
    return best
