"""Analytic MAC / FLOP accounting.

The paper measures computational effort in MACs "obtained analytically by
summing up the linear operations in the convolutional layers and the fully
connected layers, excluding activations and batch normalization" (§6.2).
``resnet_macs`` follows that scope exactly.

For the LLM zoo, ``segment_macs_per_token`` gives decode-time MACs of each
cascade segment (the quantity the early exit saves), and ``model_flops``
gives the roofline MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.models.blocks import layer_kinds


# ---------------------------------------------------------------------------
# CI-ResNet (paper scope: conv + fc only)
# ---------------------------------------------------------------------------

def conv_macs(k: int, c_in: int, c_out: int, h_out: int, w_out: int) -> int:
    return k * k * c_in * c_out * h_out * w_out


def resnet_component_macs(n_blocks: int, n_classes: int,
                          widths=(16, 32, 64), image_hw: int = 32,
                          enhance_dim: int = 128) -> List[float]:
    """Cumulative MACs after components 0,1,2 of CI-RESNET(n) (per image).

    Component m = stem + modules 0..m + its classifier.  Matches resnet.py.
    """
    macs_prefix = []
    total = conv_macs(3, 3, widths[0], image_hw, image_hw)      # stem
    hw = image_hw
    for mod in range(3):
        c_in = widths[mod - 1] if mod else widths[0]
        c_out = widths[mod]
        stride = 1 if mod == 0 else 2
        if stride == 2:
            hw //= 2
        # first block (possibly strided, with projection shortcut if needed)
        total += conv_macs(3, c_in, c_out, hw, hw)
        total += conv_macs(3, c_out, c_out, hw, hw)
        if stride == 2 or c_in != c_out:
            total += conv_macs(1, c_in, c_out, hw, hw)
        for _ in range(n_blocks - 1):
            total += 2 * conv_macs(3, c_out, c_out, hw, hw)
        # classifier branch for this component
        if mod < 2 and enhance_dim:
            head = c_out * enhance_dim + enhance_dim * n_classes
        else:
            head = c_out * n_classes
        macs_prefix.append(total + head)
    return [float(m) for m in macs_prefix]


# ---------------------------------------------------------------------------
# LLM zoo
# ---------------------------------------------------------------------------

def _layer_macs_per_token(cfg: ModelConfig, kind: str, kv_len: int) -> float:
    """Decode-time MACs of one layer for one new token, KV length kv_len."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads
    eff_kv = min(kv_len, cfg.attn_window) if cfg.attn_window else kv_len

    def attn():
        proj = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        scores = H * hd * eff_kv * 2             # qk + pv
        return proj + scores

    def mlp(ff):
        mults = 3 if cfg.act == "swiglu" else 2
        return mults * d * ff

    def moe():
        router = d * cfg.n_experts
        return router + cfg.top_k * mlp(cfg.d_ff)

    def mamba():
        from repro.models.ssm import dims
        d_inner, n_heads, conv_ch = dims(cfg)
        in_p = d * (2 * d_inner + 2 * cfg.ssm_state + n_heads)
        conv = cfg.ssm_conv * conv_ch
        state = 2 * d_inner * cfg.ssm_state      # state update + C readout
        out_p = d_inner * d
        return in_p + conv + state + out_p

    def mlstm():
        from repro.models.xlstm import mlstm_dims
        d_inner, h, p = mlstm_dims(cfg)
        up = d * 2 * d_inner
        qkv = 3 * d_inner * d_inner
        cell = 3 * h * p * p                     # C update + readout
        down = d_inner * d
        return up + qkv + cell + down

    def slstm():
        p = d // cfg.n_heads
        rec = 4 * cfg.n_heads * p * p
        return d * 4 * d + rec + d * (4 * d) // 3 + ((4 * d) // 3) * d

    def xattn():
        T = cfg.n_image_tokens or cfg.n_audio_frames
        proj = d * (H * hd) + (H * hd) * d       # q and o only at decode
        scores = H * hd * T * 2
        return proj + scores + mlp(cfg.d_ff)

    table = {
        "dense": lambda: attn() + mlp(cfg.d_ff),
        "moe": lambda: attn() + moe(),
        "mamba": mamba,
        "attn_shared": lambda: attn() + mlp(cfg.d_ff),
        "mlstm": mlstm,
        "slstm": slstm,
        "xattn": xattn,
        "encdec": lambda: 2 * attn() + mlp(cfg.d_ff),
    }
    return float(table[kind]())


def exit_head_macs(cfg: ModelConfig) -> float:
    e = cfg.cascade.enhance_dim
    enh = 2 * cfg.d_model * e if e else 0
    return float(enh + cfg.d_model * cfg.vocab_size)


def segment_macs_per_token(cfg: ModelConfig, kv_len: int) -> List[float]:
    """Cumulative decode MACs after each cascade component (incl. its head)."""
    kinds = layer_kinds(cfg)
    prefix = []
    total = 0.0
    for si, (start, end) in enumerate(cfg.segments):
        for i in range(start, end):
            total += _layer_macs_per_token(cfg, kinds[i], kv_len)
        prefix.append(total + exit_head_macs(cfg))
    return prefix


def param_count(cfg: ModelConfig) -> float:
    """Approximate parameter count N (for 6·N·D roofline accounting)."""
    kinds = layer_kinds(cfg)
    total = cfg.vocab_size * cfg.d_model        # embed
    total += cfg.vocab_size * cfg.d_model       # untied lm head
    d, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.n_heads, cfg.n_kv_heads

    def attn_p():
        return d * H * hd + 2 * d * KV * hd + H * hd * d

    def mlp_p(ff):
        return (3 if cfg.act == "swiglu" else 2) * d * ff

    from repro.models.ssm import dims as ssm_dims
    from repro.models.xlstm import mlstm_dims
    per = {
        "dense": lambda: attn_p() + mlp_p(cfg.d_ff),
        "moe": lambda: attn_p() + d * cfg.n_experts
                       + cfg.n_experts * mlp_p(cfg.d_ff),
        "mamba": lambda: (lambda di, nh, cc: d * (2 * di + 2 * cfg.ssm_state + nh)
                          + cfg.ssm_conv * cc + di * d)(*ssm_dims(cfg)),
        "attn_shared": lambda: 6 * 16 * d,       # LoRA only; shared block once
        "mlstm": lambda: (lambda di, h, p: d * 2 * di + 3 * di * di + 2 * di * cfg.n_heads
                          + di * d)(*mlstm_dims(cfg)),
        "slstm": lambda: d * 4 * d + 4 * d * (d // cfg.n_heads)
                         + d * (4 * d) // 3 + ((4 * d) // 3) * d,
        "xattn": lambda: attn_p() + mlp_p(cfg.d_ff),
        "encdec": lambda: 2 * attn_p() + mlp_p(cfg.d_ff),
    }
    for k in kinds:
        total += per[k]()
    if cfg.family == "hybrid":
        total += attn_p() + mlp_p(cfg.d_ff)      # the shared block itself
    if cfg.family == "audio":
        total += cfg.encoder_layers * (attn_p() + mlp_p(cfg.d_ff))
    return float(total)


def active_param_count(cfg: ModelConfig) -> float:
    """Active parameters per token (MoE: top_k of n_experts)."""
    if not cfg.n_experts:
        return param_count(cfg)
    d = cfg.d_model
    expert_p = (3 if cfg.act == "swiglu" else 2) * d * cfg.d_ff
    inactive = (cfg.n_experts - cfg.top_k) * expert_p * cfg.n_layers
    return param_count(cfg) - inactive


def model_flops(cfg: ModelConfig, n_tokens: int, training: bool) -> float:
    """MODEL_FLOPS = (6 if training else 2) · N_active · tokens."""
    mult = 6.0 if training else 2.0
    return mult * active_param_count(cfg) * n_tokens
