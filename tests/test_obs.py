"""Observability (repro.obs): flight recorder, metrics, trace export.

Pins the subsystem's contracts: every admitted request gets exactly ONE
terminal span (across host/device runtimes, dense/paged layouts,
escalation tiers, and a fleet drain — where a migrated request's flight
must span BOTH members), recorder-on token streams are bit-identical to
recorder-off, the Prometheus exposition round-trips through the parser
(and through a real HTTP socket), the ring buffer bounds memory while
lifetime counters stay lossless, and the Chrome trace-event export
passes the schema validator.
"""
import json
import urllib.request

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.obs import (EventLog, FlightRecorder, MetricsRegistry,
                       MetricsServer, export_trace, parse_prometheus,
                       trace_events, validate_trace_events)
from repro.obs.recorder import TERMINAL_KINDS, quantiles
from repro.serving import CascadeServingEngine, Request


def _tiny(**cascade):
    """Mixed-exit operating point on a 3-component cascade — exits must
    span depths for the stream-parity tests to mean anything."""
    cfg = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32")
    kw = dict(n_components=3, exit_boundaries=(1, 2),
              exit_mode="cond_batch", thresholds=(0.021, 0.021, 0.0))
    kw.update(cascade)
    return cfg.with_cascade(**kw)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = _tiny()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _engine(cfg, model, params, **kw):
    kw.setdefault("lane_batch", 2)
    kw.setdefault("n_lanes", 1)
    kw.setdefault("cache_len", 32)
    kw.setdefault("chunk", 4)
    return CascadeServingEngine(cfg, model, params, **kw)


def _submit(engine, cfg, n, max_new=4, seed=3, prompt_len=6):
    rng = np.random.default_rng(seed)
    for i in range(n):
        engine.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                       prompt_len).astype(np.int32),
            max_new_tokens=max_new))


def _terminals(flight_dict):
    return [s for s in flight_dict["spans"]
            if s["name"] in TERMINAL_KINDS]


# ---------------------------------------------------------------------------
# recorder unit behavior: span assembly, ring bounds, event log
# ---------------------------------------------------------------------------

def test_recorder_span_tree_and_ring_bounds():
    """10 flights through a max_flights=4 recorder: the ring keeps the
    newest 4, eviction is counted, and the reservoirs' lifetime
    count/sum survive eviction (quantiles describe the ring only)."""
    rec = FlightRecorder(max_flights=4, max_events=8, reservoir=4)
    for rid in range(10):
        rec.on_submit(rid, tick=rid)
        rec.on_admit(rid, lane=0, slot=rid % 2, cohort=0,
                     predicted_depth=1.5, wait_ticks=2, tick=rid + 2)
        rec.on_chunk(0, t0=float(rid), seconds=0.01, steps=1,
                     entries=[(rid, [7], [1], [0.5])])
        rec.on_finish(rid, "exit", {"n_tokens": 1, "macs": 100.0})
    st = rec.stats()
    assert st["flights_live"] == 0
    assert st["flights_done"] == 4
    assert st["flights_evicted"] == 6
    assert rec.dump(0) is None                   # evicted
    f = rec.dump(9)
    assert [s["name"] for s in f["spans"]] == \
        ["queue_wait", "admit", "chunk", "exit"]
    assert f["terminal"] == "exit"
    assert len(_terminals(f)) == 1
    lat = rec.latency()
    # lifetime count is lossless even though the reservoir holds only 4
    assert lat["e2e_seconds"]["count"] == 10
    assert lat["admission_wait_ticks"]["count"] == 10
    assert lat["admission_wait_ticks"]["p50"] == 2.0
    assert len(rec.reservoirs["e2e_seconds"].values()) == 4


def test_recorder_rejects_unknown_terminal_and_event_log_bounds():
    rec = FlightRecorder(max_flights=2, max_events=3)
    rec.on_submit(0, tick=0)
    with pytest.raises(ValueError):
        rec.on_finish(0, "vanished")
    log = EventLog(maxlen=3)
    for i in range(5):
        log.add("tick", {"i": i})
    assert len(log) == 3
    assert log.dropped == 2
    assert log.counts["tick"] == 5               # lifetime, not ring


def test_quantiles_interpolation():
    q = quantiles([1.0, 2.0, 3.0, 4.0])
    assert q["count"] == 4 and q["sum"] == 10.0
    assert q["p50"] == 2.5
    assert quantiles([]) is None


# ---------------------------------------------------------------------------
# engine integration: every admitted rid -> exactly one terminal span
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime,layout", [
    ("host", "dense"), ("device", "dense"),
    ("host", "paged"), ("device", "paged"),
])
def test_engine_flight_completeness(tiny_model, runtime, layout):
    model, params = tiny_model
    cfg = _tiny().with_obs()
    if layout == "paged":
        cfg = cfg.with_paged_cache(layout="paged", block_size=8)
    eng = _engine(cfg, model, params, runtime=runtime)
    n = 5                                        # > lane capacity: queueing
    _submit(eng, cfg, n)
    eng.run(300)
    assert eng.stats()["requests_finished"] == n
    assert eng.flight.stats()["flights_live"] == 0
    for rid in range(n):
        f = eng.dump_flight(rid)
        assert f is not None, f"rid {rid} not recorded"
        assert f["terminal"] == "exit"
        assert len(_terminals(f)) == 1
        names = [s["name"] for s in f["spans"]]
        assert names[0] == "queue_wait" and names[1] == "admit"
        assert any(n_ in ("prefill", "reprefill") for n_ in names)
        assert "chunk" in names
        # flight-level context: placement + kernel provenance
        assert f["attrs"]["lane"] is not None
        assert f["attrs"]["kernel_backend"] in ("interpret", "compiled")
    lat = eng.latency_stats()
    assert lat["e2e_seconds"]["count"] == n
    assert lat["admission_wait_ticks"]["count"] == n


def test_streams_bit_identical_recorder_on_vs_off(tiny_model):
    model, params = tiny_model
    base = _tiny()
    outs = {}
    for key, cfg in (("off", base), ("on", base.with_obs())):
        eng = _engine(cfg, model, params, runtime="device")
        _submit(eng, base, 4, max_new=6)
        eng.run(300)
        outs[key] = {r: tuple(v["tokens"]) for r, v in eng.finished.items()}
    assert outs["on"] == outs["off"]
    assert len(outs["on"]) == 4


def test_engine_ring_bounds_memory(tiny_model):
    model, params = tiny_model
    cfg = _tiny().with_obs(enabled=True, max_flights=3)
    eng = _engine(cfg, model, params)
    n = 8
    _submit(eng, cfg, n)
    eng.run(300)
    st = eng.flight.stats()
    assert st["flights_done"] == 3
    assert st["flights_evicted"] == n - 3
    assert len(eng.flights()) == 3
    # latency distributions still cover all n requests
    assert eng.latency_stats()["e2e_seconds"]["count"] == n


def test_threshold_push_lands_on_event_log(tiny_model):
    model, params = tiny_model
    # pushes need autotune-enabled decode graphs (thresholds as carry data)
    cfg = _tiny().with_obs().with_autotune(enabled=True)
    eng = _engine(cfg, model, params)
    _submit(eng, cfg, 2)
    for _ in range(2):
        eng.step()
    eng.push_thresholds((0.3, 0.3, 0.0))
    eng.run(300)
    assert eng.flight.events.counts["threshold_push"] == 1
    # and it shows up in the scrape as a counter
    samples = parse_prometheus(eng.scrape())
    push = [s for s in samples
            if s["name"] == "repro_threshold_push_total"]
    assert push and push[0]["value"] == 1.0


# ---------------------------------------------------------------------------
# metrics: registry, prometheus round-trip, HTTP server
# ---------------------------------------------------------------------------

def test_registry_renders_and_parses():
    reg = MetricsRegistry()
    reg.counter("repro_x_total", "Things.", 3, {"kind": "a"})
    reg.counter("repro_x_total", "Things.", 2, {"kind": "a"})
    reg.gauge("repro_depth", "Depth.", 1.5)
    reg.summary("repro_lat_seconds", "Latency.", [0.1, 0.2, 0.3],
                count=100, total=20.0)
    samples = parse_prometheus(reg.render_text())
    by = {(s["name"], tuple(sorted(s["labels"].items()))): s["value"]
          for s in samples}
    assert by[("repro_x_total", (("kind", "a"),))] == 5.0
    assert by[("repro_depth", ())] == 1.5
    assert by[("repro_lat_seconds_count", ())] == 100.0
    assert by[("repro_lat_seconds_sum", ())] == 20.0
    q50 = [s for s in samples if s["name"] == "repro_lat_seconds"
           and s["labels"].get("quantile") == "0.5"]
    assert q50 and abs(q50[0]["value"] - 0.2) < 1e-9
    with pytest.raises(ValueError):
        parse_prometheus("repro_bad{unclosed 1.0")


def test_engine_scrape_parses_and_server_round_trips(tiny_model):
    model, params = tiny_model
    cfg = _tiny().with_obs()
    eng = _engine(cfg, model, params)
    _submit(eng, cfg, 3)
    eng.run(300)
    samples = parse_prometheus(eng.scrape())
    names = {s["name"] for s in samples}
    assert "repro_requests_finished_total" in names
    assert "repro_request_latency_seconds_count" in names
    assert "repro_exit_component_total" in names
    with MetricsServer(0, eng.scrape, scrape_json=eng.scrape_json,
                       flights=eng.flights, flight=eng.dump_flight,
                       trace=lambda: trace_events([eng.flight])) as srv:
        base = f"http://127.0.0.1:{srv.port}"
        body = urllib.request.urlopen(base + "/metrics",
                                      timeout=10).read().decode()
        assert parse_prometheus(body) == samples
        mj = json.loads(urllib.request.urlopen(
            base + "/metrics.json", timeout=10).read())
        assert mj["repro_requests_finished_total"]["type"] == "counter"
        fl = json.loads(urllib.request.urlopen(
            base + "/flights/0", timeout=10).read())
        assert fl["rid"] == 0 and fl["terminal"] == "exit"
        tr = json.loads(urllib.request.urlopen(
            base + "/trace", timeout=10).read())
        validate_trace_events(tr["traceEvents"])
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(base + "/flights/999", timeout=10)
        assert ei.value.code == 404


# ---------------------------------------------------------------------------
# escalation: one flight per stage, annotated with the escalation context
# ---------------------------------------------------------------------------

def test_escalation_flight_spans_both_stages():
    from repro.escalate import ModelCascadeTier
    # stage-0 intra thresholds at the never-exit sentinel: every token is
    # answered at the final component, so escalation threshold 1.1 defers
    # EVERY request at its first token
    cfg0 = _tiny(thresholds=(1.1, 1.1, 0.0)).with_obs() \
        .with_escalation(enabled=True, threshold=1.1)
    cfg1 = reduced(get_config("qwen2.5-3b"),
                   n_layers=4).replace(dtype="float32") \
        .with_cascade(n_components=2, exit_boundaries=(2,),
                      thresholds=(1.1, 0.0)).with_obs()
    engines = []
    for s, cfg in enumerate((cfg0, cfg1)):
        model = build_model(cfg)
        engines.append(_engine(cfg, model,
                               model.init(jax.random.PRNGKey(s)),
                               lane_batch=4))
    tier = ModelCascadeTier(engines)
    _submit(tier, cfg0, 3)
    tier.run(400)
    st = tier.stats()
    assert st["requests_finished"] == 3
    assert st["escalations_total"] == 3          # 1.1 = always defer
    for rid in range(3):
        stages = tier.dump_flight(rid)
        by_stage = {d["stage"]: d for d in stages}
        assert set(by_stage) == {0, 1}
        assert by_stage[0]["terminal"] == "escalate"
        assert by_stage[1]["terminal"] == "exit"
        assert len(_terminals(by_stage[0])) == 1
        assert len(_terminals(by_stage[1])) == 1
        # the tier stamps routing context on the SOURCE flight; the
        # target engine stamps provenance at its escalated admission
        assert by_stage[0]["attrs"]["escalated_to_stage"] == 1
        assert by_stage[1]["attrs"]["escalated_from"] == rid
        assert by_stage[1]["attrs"]["replayed"] >= 0


# ---------------------------------------------------------------------------
# fleet: drain/migration visible, migrated flights span both members
# ---------------------------------------------------------------------------

def test_fleet_drain_flights_and_trace(tiny_model):
    from repro.fleet import FleetScheduler
    model, params = tiny_model
    cfg = _tiny().with_obs().with_fleet(n_engines=2, drain_mode="migrate")
    members = [_engine(cfg, model, params, runtime="device", chunk=2)
               for _ in range(2)]
    fleet = FleetScheduler(members)
    n = 6
    _submit(fleet, cfg, n, max_new=8)
    for _ in range(2):
        fleet.step()
    summary = fleet.drain(0, mode="migrate")
    fleet.run(500)
    st = fleet.stats()
    assert st["requests_finished"] == n
    assert st["discarded_tokens"] == 0
    migrated = summary["migrated"]
    assert migrated, "drain must catch in-flight work for this test"
    # exactly one terminal per member flight; migrated span both members
    for rid in range(n):
        fl = fleet.dump_flight(rid)
        assert fl is not None
        for m in fl["members"]:
            assert len(_terminals(m)) == 1
    for rid in migrated:
        fl = fleet.dump_flight(rid)
        kinds = {m["member"]: m["terminal"] for m in fl["members"]}
        assert len(kinds) == 2
        assert sorted(kinds.values()) == ["exit", "migrate"]
        target = [m for m in fl["members"]
                  if m["terminal"] == "exit"][0]
        assert target["attrs"].get("migrated") is True
    assert fleet.events.counts["drain"] == 1
    # member health surfaces through stats
    ms = st["members"][0]
    assert ms["healthy"] is True
    assert ms["consecutive_failures"] == 0
    # fleet scrape parses, with per-member + merged labels
    samples = parse_prometheus(fleet.scrape())
    members_seen = {s["labels"].get("member") for s in samples
                    if s["name"] == "repro_requests_finished_total"}
    assert members_seen == {"0", "1"}
    # every rid finalizes exactly once somewhere, plus one terminal on
    # the source member per migration/requeue
    merged = [s for s in samples
              if s["name"] == "repro_request_latency_seconds_count"
              and s["labels"].get("member") == "merged"]
    assert merged and merged[0]["value"] == float(
        n + len(migrated) + len(summary["requeued"]))
    healthy = [s for s in samples
               if s["name"] == "repro_fleet_member_healthy"]
    assert len(healthy) == 2
    # trace export validates with the drain instant present
    evs = fleet.trace_events()
    validate_trace_events(evs, require_names=("drain",))
    assert any(e["ph"] == "i" and e["name"].startswith("migrate ")
               for e in evs)


# ---------------------------------------------------------------------------
# trace schema validator
# ---------------------------------------------------------------------------

def test_validate_trace_events_rejects_malformed():
    ok = [{"ph": "X", "name": "chunk", "pid": 1, "tid": 0,
           "ts": 0.0, "dur": 1.0, "args": {}}]
    validate_trace_events(ok)
    with pytest.raises(ValueError):
        validate_trace_events([{**ok[0], "ph": "B"}])
    with pytest.raises(ValueError):
        validate_trace_events([{**ok[0], "ts": -1.0}])
    with pytest.raises(ValueError):
        validate_trace_events([dict(ok[0], ph="i")])    # missing scope
    with pytest.raises(ValueError):
        validate_trace_events([{**ok[0],
                                "args": {"bad": object()}}])
    with pytest.raises(ValueError, match="missing"):
        validate_trace_events(ok, require_names=("drain",))


def test_export_trace_writes_validated_doc(tiny_model, tmp_path):
    model, params = tiny_model
    cfg = _tiny().with_obs()
    eng = _engine(cfg, model, params)
    _submit(eng, cfg, 2)
    eng.run(300)
    path = tmp_path / "trace.json"
    doc = export_trace(str(path), [("engine", eng.flight)])
    on_disk = json.loads(path.read_text())
    assert on_disk["traceEvents"] == doc["traceEvents"]
    assert on_disk["displayTimeUnit"] == "ms"
    names = {e["name"] for e in on_disk["traceEvents"]}
    assert any(n.startswith("chunk ") for n in names)
    assert any(n.startswith("exit ") for n in names)
