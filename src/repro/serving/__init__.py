from repro.serving.engine import CascadeServingEngine, Request, select_exit
from repro.serving.batching import DepthCompactor

__all__ = ["CascadeServingEngine", "Request", "select_exit", "DepthCompactor"]
