"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures (i) the serving engine's analytic MAC speedup at several thresholds,
(ii) alternative registered confidence measures (entropy — the BranchyNet
[TMK16] baseline the paper argues against — and PABEE-style patience) on the
same engine, and (iii) the cond_batch skip rate with depth-compacted lanes.
All exit decisions route through the one ExitDecider resolved from the
config's registry strings.
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request


def _drive(cfg, model, params, tag, rows, n_req=6):
    rng = np.random.default_rng(0)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                               n_lanes=2, cache_len=48)
    for i in range(n_req):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 8).astype(np.int32), max_new_tokens=8))
    t0 = time.time()
    eng.run(300)
    dt = (time.time() - t0) * 1e6
    st = eng.stats()
    rows.append((f"llm_cascade/{tag}/speedup",
                 dt / max(1, st["requests_finished"]),
                 f"{st['analytic_speedup']:.3f}"))
    rows.append((f"llm_cascade/{tag}/skip_rate", 0.0,
                 f"{st['cond_batch_skip_rate']:.3f}"))
    return st


def run():
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    for th in (0.0, 0.5, 1.1):
        c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode="select")
        _drive(c, model, params, f"th={th:g}", rows)
    # alternative measures through the same registry-resolved engine path
    for measure in ("entropy", "patience@2"):
        c = cfg.with_cascade(thresholds=(0.5, 0.0), exit_mode="select",
                             confidence=measure)
        _drive(c, model, params, f"measure={measure}", rows)
    return rows
