"""Step builders shared by train.py, serve.py, and dryrun.py.

``make_train_step``: joint-loss cascade training step (fwd + bwd + AdamW).
``make_prefill_step`` / ``make_serve_step``: inference steps; serve_step is
ONE new token against a KV/state cache (what the decode shapes lower).
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.policy import ExitDecider
from repro.core.training import cascade_loss
from repro.models.model import CascadeModel, extra_input_shapes
from repro.optim import adamw
from repro.optim.optimizer import Optimizer, apply_updates


def make_optimizer(cfg: ModelConfig) -> Optimizer:
    return adamw(lr=3e-4, weight_decay=0.1)


def make_train_step(model: CascadeModel, cfg: ModelConfig,
                    optimizer: Optimizer):
    def train_step(params, opt_state, step, batch):
        def loss_fn(p):
            logits, aux = model.forward_train(p, batch["tokens"],
                                              batch.get("extra"))
            return cascade_loss(logits, batch["labels"],
                                cfg.cascade.loss_mode or "joint",
                                joint_weights=cfg.cascade.joint_weights,
                                aux=aux, aux_coef=cfg.router_aux_coef)
        loss, grads = jax.value_and_grad(loss_fn)(params)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        return params, opt_state, loss
    return train_step


def make_prefill_step(model: CascadeModel, cfg: ModelConfig):
    decider = ExitDecider.from_config(cfg)

    def prefill_step(params, tokens, cache, extra):
        logits, cache = model.prefill(params, tokens, cache, extra)
        d = decider.decide(logits)
        return d.prediction, d.exit_index, d.confidence, cache
    return prefill_step


def make_serve_step(model: CascadeModel, cfg: ModelConfig):
    decider = ExitDecider.from_config(cfg)
    if decider.measure.stateful:
        # the fixed (params, token, t, cache, extra) signature the dry-run
        # lowers has no slot for streak state; silently re-initializing it
        # every step would disable early exit for patience@k
        raise NotImplementedError(
            f"measure {decider.measure.name!r} is stateful; the launch serve "
            "step cannot thread its decode state — serve stateful measures "
            "through CascadeServingEngine instead")

    def serve_step(params, token, t, cache, extra):
        logits, cache = model.decode_step(params, token, t, cache, extra)
        d = decider.decide(logits)
        return d.prediction, d.exit_index, d.confidence, cache
    return serve_step


def make_batch_structs(cfg: ModelConfig, batch: int, seq: int,
                       dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins for a training batch."""
    extra = {k: jax.ShapeDtypeStruct(v, dtype)
             for k, v in extra_input_shapes(cfg, batch).items()}
    d = {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
         "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}
    if extra:
        d["extra"] = extra
    return d
