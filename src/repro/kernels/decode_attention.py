"""Single-query (decode) attention Pallas kernel with ring-buffer masking
and per-slot exit masking.

One new token attends over a KV cache of length W.  Grid: (B, KV_heads,
W/Tk) with the W axis innermost; the (qpk, hd) query-group tile stays in
VMEM and KV tiles stream through, carrying the online-softmax (acc, m, l)
in scratch.  The slot-position vector ``kpos`` (absolute position per cache
slot, −1 = empty) is streamed alongside each KV tile and implements causal
+ sliding-window + ring-wraparound masking in one comparison.

``live`` is the exit-aware part: a per-batch-slot mask (1 = still
generating).  Every ``(b, h, ik)`` grid cell belonging to a dead slot
early-outs under ``pl.when`` — no QK^T, no exp, no PV — and the output row
zero-fills (the serving engine discards dead slots' outputs anyway, and a
lane re-prefills from scratch before a slot is reused, so zero is as good
as the dense value at a fraction of the cost).  Live rows are bit-identical
to the unmasked kernel: decode attention is batch-separable, so masking one
row cannot perturb another.

Layout: q (B, KV, qpk, hd); k, v (B, KV, W, hd); kpos (W,) int32 — or
(B, W) for the paged cache layout's per-slot position rings (the lane-wide
(W,) vector is broadcast; the masking arithmetic per row is unchanged, so
dense calls are bit-identical to the 1-D operand); t scalar; live (B,)
int32.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.backend import resolve_interpret

NEG = -1e30


def _decode_kernel(t_ref, live_ref, q_ref, k_ref, v_ref, kpos_ref, o_ref,
                   acc_s, m_s, l_s, *, tk, n_ktiles, window, scale):
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        acc_s[...] = jnp.zeros_like(acc_s[...])
        m_s[...] = jnp.full_like(m_s[...], NEG)
        l_s[...] = jnp.zeros_like(l_s[...])

    # exit mask: dead slots skip the whole tile's compute (their scratch
    # stays zero, so the final write below emits an all-zero row)
    @pl.when(live_ref[0] != 0)
    def _tile():
        t = t_ref[0]
        q = q_ref[0, 0].astype(jnp.float32)                # (qpk, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (Tk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        kpos = kpos_ref[0]                                 # (Tk,)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = (kpos >= 0) & (kpos <= t)
        if window:
            mask &= kpos > t - window
        s = jnp.where(mask[None, :], s, NEG)
        m_old = m_s[...]
        m_new = jnp.maximum(m_old, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_old - m_new)
        l_s[...] = l_s[...] * corr + jnp.sum(p, axis=-1)
        acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_s[...] = m_new

    @pl.when(jk == n_ktiles - 1)
    def _out():
        # dead rows: acc == 0, l == 0 -> 0 / 1e-30 == exact zero-fill
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, t, kpos, live=None, *,
                     window: int = 0, tk: int = 512,
                     interpret: "bool | None" = None):
    """q: (B, KV, qpk, hd); caches (B, KV, W, hd); t scalar int32;
    kpos (W,) int32 — or (B, W) per-slot rings (paged layout); live (B,)
    bool/int32 or None (all live)
    -> (B, KV, qpk, hd) with dead slots' rows zero-filled.

    ``interpret`` resolves OUTSIDE the jit boundary (env var / backend
    auto-detection re-consulted every call, not baked into the trace)."""
    return _decode_attention(q, k_cache, v_cache, t, kpos, live,
                             window=window, tk=tk,
                             interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("window", "tk", "interpret"))
def _decode_attention(q, k_cache, v_cache, t, kpos, live, *, window, tk,
                      interpret):
    B, KV, qpk, hd = q.shape
    W = k_cache.shape[2]
    tk = min(tk, W)
    pad = (-W) % tk
    # per-row position rings: the lane-wide (W,) vector broadcasts to
    # (B, W) so every grid cell streams ITS slot's ring — same arithmetic,
    # so dense (broadcast) calls are bit-identical to the 1-D operand
    kpos = jnp.broadcast_to(kpos, (B, W))
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kpos = jnp.pad(kpos, ((0, 0), (0, pad)), constant_values=-1)
    Wp = W + pad
    n_ktiles = Wp // tk
    scale = 1.0 / math.sqrt(hd)
    live = (jnp.ones((B,), jnp.int32) if live is None
            else jnp.asarray(live).astype(jnp.int32))
    kernel = functools.partial(_decode_kernel, tk=tk, n_ktiles=n_ktiles,
                               window=window, scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=(B, KV, n_ktiles),
        in_specs=[
            pl.BlockSpec((1,), lambda b, h, ik: (0,)),
            pl.BlockSpec((1,), lambda b, h, ik: (b,)),
            pl.BlockSpec((1, 1, qpk, hd), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, tk, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, tk, hd), lambda b, h, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, tk), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, hd), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, qpk, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((qpk, hd), jnp.float32),
                        pltpu.VMEM((qpk,), jnp.float32),
                        pltpu.VMEM((qpk,), jnp.float32)],
        interpret=interpret,
    )(jnp.asarray(t, jnp.int32).reshape(1), live, q, k_cache, v_cache, kpos)
    return out
