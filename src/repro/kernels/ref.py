"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each ``ref_*`` is the mathematically-plain implementation the kernels are
tested against with assert_allclose over shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_confidence(logits):
    """Fused softmax-confidence oracle.  logits: (B, V) ->
    (argmax (B,) int32, delta (B,) f32) per Defs. 3.2-3.3."""
    x = logits.astype(jnp.float32)
    idx = jnp.argmax(x, axis=-1).astype(jnp.int32)
    m = jnp.max(x, axis=-1)
    lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
    return idx, jnp.exp(m - lse)


def ref_rmsnorm(x, w, eps: float = 1e-5):
    """x: (R, d); w: (d,)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(
        x.dtype)


def ref_flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q: (B, H, S, hd); k, v: (B, KV, S, hd).  GQA by head grouping."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    qpk = H // KV
    qh = q.reshape(B, KV, qpk, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qh, kf) / math.sqrt(hd)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, t, kpos, window: int = 0,
                         live=None):
    """q: (B, H, hd); caches: (B, W, KV, hd); t scalar; kpos (W,);
    live (B,) bool or None.  Dead slots' output rows are exact zeros (the
    exit-masked kernel's early-out contract); live rows are the plain
    ring-masked single-query attention."""
    B, H, hd = q.shape
    W, KV = k_cache.shape[1], k_cache.shape[2]
    qpk = H // KV
    qh = q.reshape(B, KV, qpk, hd).astype(jnp.float32)
    s = jnp.einsum("bkgh,bwkh->bkgw", qh, k_cache.astype(jnp.float32))
    s = s / math.sqrt(hd)
    m = (kpos >= 0) & (kpos <= t)
    if window:
        m = m & (kpos > t - window)
    s = jnp.where(m[None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgw,bwkh->bkgh", p, v_cache.astype(jnp.float32))
    o = o.reshape(B, H, hd)
    if live is not None:
        o = jnp.where(jnp.asarray(live, bool)[:, None, None], o, 0.0)
    return o.astype(q.dtype)


def ref_exit_head_update(h, norm_w, head, answered, pred, exit_idx, conf,
                         streak, ema, active, *, threshold, m, n_components,
                         patience_k=0, ema_decay=0.0, eps=1e-5, live=None):
    """Fused exit-head megakernel oracle: rmsnorm -> shared-unembed matmul
    -> :func:`ref_exit_update`, with dead (``live`` False) rows passing
    every carry through unchanged (the megakernel's grid early-out
    contract — a retired slot's outputs are never read)."""
    x = ref_rmsnorm(h, norm_w, eps)
    logits = (x @ head.astype(x.dtype)).astype(jnp.float32)
    outs = ref_exit_update(
        logits, answered, pred, exit_idx, conf, streak, ema, active,
        threshold=threshold, m=m, n_components=n_components,
        patience_k=patience_k, ema_decay=ema_decay)
    if live is None:
        return outs
    lv = jnp.asarray(live, bool)
    carry_in = (jnp.asarray(answered, bool),
                jnp.asarray(pred, jnp.int32),
                jnp.asarray(exit_idx, jnp.int32),
                jnp.asarray(conf, jnp.float32),
                jnp.asarray(streak, jnp.int32),
                jnp.asarray(ema, jnp.float32))
    return tuple(jnp.where(lv, o, i) for o, i in zip(outs, carry_in))


def ref_exit_update(logits, answered, pred, exit_idx, conf, streak, ema,
                    active, *, threshold, m, n_components, patience_k=0,
                    ema_decay=0.0):
    """Fused exit-update oracle: one component step of the decision scan
    (:meth:`repro.core.policy.ExitDecider.scan_component` semantics) plus
    the optional DecodeState confidence-EMA fold, in plain jnp."""
    idx, delta = ref_confidence(logits)
    last = m >= n_components - 1
    # final component: gate open BEFORE the patience rewrite (dense order)
    if last:
        gate = jnp.ones_like(delta, bool)
    else:
        gate = delta >= threshold
    streak_n = jnp.asarray(streak)
    if patience_k > 0:
        streak_n = jnp.where(gate, streak_n + 1, 0)
        gate = streak_n >= patience_k
        if last:
            gate = jnp.ones_like(gate)
    answered = jnp.asarray(answered, bool)
    fresh = gate & ~answered
    conf_n = jnp.where(fresh, delta, conf)
    ema_n = jnp.asarray(ema, jnp.float32)
    if ema_decay > 0.0:
        ema_n = jnp.where(jnp.asarray(active, bool),
                          ema_decay * ema_n + (1.0 - ema_decay) * conf_n,
                          ema_n)
    return (answered | gate,
            jnp.where(fresh, idx, pred).astype(jnp.int32),
            jnp.where(fresh, jnp.int32(m), exit_idx).astype(jnp.int32),
            conf_n, streak_n.astype(jnp.int32), ema_n)
