"""Cross-engine fleet scheduler: depth-aware placement, drain/migration,
health-gated stepping.

The layer above :class:`~repro.serving.engine.CascadeServingEngine` /
:class:`~repro.escalate.tier.ModelCascadeTier`: one
:class:`FleetScheduler` fronts N members and owns the fleet queue.
Placement generalizes DESIGN.md §5 one level up — where the engine's
DepthCompactor co-locates requests in *lanes* by predicted exit depth,
the fleet treats each MEMBER as a lane of a fleet-level compactor (same
banded depth-EMA init, same retire decay), and scores candidates by

    depth_weight · |member depth EMA − predicted depth| / (n_comp − 1)
  + load_weight  · (live + queued) / capacity
  + block_weight · used-block fraction        (paged members only)

lowest score wins (FIFO head-of-queue, like engine admission).  A member
whose observed traffic runs shallow keeps attracting shallow requests —
cond_batch skips fire fleet-wide, not just lane-wide — while the load and
block terms stop the depth signal from piling everything onto one engine.

**Drain** (rolling restarts): ``drain(idx)`` stops the member admitting
(the engine's ``admitting`` gate), pulls its still-queued requests back
into the fleet queue (requeue — nothing was decoded, nothing is lost),
and then either lets in-flight slots run to exit or budget on the
draining member (``"finish"``) or cancels them and **migrates** their
committed prefixes to siblings (``"migrate"``): the committed tokens ride
PR 7's :func:`repro.escalate.replay.build_replay` verbatim into the
target engine as replayed prompt positions, so a drain mid-decode loses
zero committed tokens.  The fleet queue re-sorts by original submission
order after every requeue — the same FIFO-restore rule the escalation
tier uses — so placement order stays deterministic.

**Health**: every ``fleet.heartbeat_every`` ticks each member's
``stats()`` is probed through :class:`~repro.fleet.health.EngineHealth`
(consecutive-failure counting, bounded exponential backoff); a member
whose probe or ``step()`` keeps raising is marked unhealthy, its queued
work is rescued into the fleet queue, its live work is migrated if the
member can still ``cancel`` (else resubmitted from the original prompt),
and placement/stepping skip it until a probe succeeds again.

The scheduler also exposes the controller surface (``lane_telemetry`` /
``current_thresholds`` / ``push_thresholds``), which is how a
:class:`~repro.fleet.aggregator.TelemetryAggregator` drives one merged
solve for the whole fleet — see that module.  Everything here is
pure-host scheduling: no device state ever moves between members.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.escalate.replay import build_replay, resolve_share_prefix
from repro.fleet.health import EngineHealth
from repro.obs.recorder import EventLog
from repro.serving.batching import DepthCompactor, LaneStats
from repro.serving.engine import Request
from repro.utils import get_logger

log = get_logger("fleet")


def _cancel_member(m, rid: int, reason: str):
    """Call a member's ``cancel`` with the terminal reason when it takes
    one (the engine stamps it on the flight's terminal span) and without
    it for members predating the kwarg."""
    try:
        return m.cancel(rid, reason=reason)
    except TypeError:
        return m.cancel(rid)


@dataclasses.dataclass
class _FleetRequest:
    """Fleet-side tracking of one submitted request across members."""

    request: Request
    order: int                       # submission order (FIFO restore key)
    engine: Optional[int] = None     # member currently holding it
    src_engine: Optional[int] = None  # member the committed prefix is from
    migrations: int = 0              # live-slot migrations (drain/unhealthy)
    requeues: int = 0                # queued-request requeues
    committed: List[int] = dataclasses.field(default_factory=list)
    committed_depths: List[int] = dataclasses.field(default_factory=list)
    committed_confs: List[float] = dataclasses.field(default_factory=list)
    spans: List[dict] = dataclasses.field(default_factory=list)
    discarded_tokens: int = 0        # committed tokens an incompatible
    #                                  migration target could not replay


class FleetScheduler:
    """Places requests across N serving engines / escalation tiers.

    ``members`` need the fleet surface the engine (and tier) provide:
    ``cfg``, ``submit`` / ``step`` / ``stats`` / ``finished``,
    ``admitting``, ``free_slot_count`` / ``queued_count`` / ``live_rids``
    / ``take_queue``; ``cancel`` enables live-slot migration (members
    without it drain in ``"finish"`` mode regardless of the requested
    mode), and the ``lane_telemetry`` / ``push_thresholds`` pair enables
    the aggregator.  ``fleet`` (a :class:`~repro.configs.base.
    FleetConfig`) defaults to ``members[0].cfg.fleet``.
    """

    def __init__(self, members: List, fleet=None, aggregator=None):
        if not members:
            raise ValueError("a fleet needs at least one member")
        self.members = list(members)
        self.fleet = fleet if fleet is not None else members[0].cfg.fleet
        n = len(self.members)
        n_comp = members[0].cfg.cascade.n_components
        # member i is "lane" i of a fleet-level compactor: same banded
        # depth-EMA init, same retire decay toward the population prior
        self.compactor = DepthCompactor(n, n_comp)
        self.health = EngineHealth(
            n, max_failures=self.fleet.max_failures,
            backoff_base=self.fleet.backoff_base,
            backoff_cap=self.fleet.backoff_cap)
        self.queue: List[_FleetRequest] = []
        self.finished: Dict[int, dict] = {}
        self._tracked: Dict[int, _FleetRequest] = {}
        self._order = 0
        self._tick = 0
        self._live_thresholds = None
        self._rescued: set = set()     # members whose work was rescued
        self.draining: set = set()     # drain() called, in-flight remains
        self.drained: set = set()      # drain complete (empty member)
        self.migrations = 0
        self.requeues = 0
        self.placements = 0
        # fleet-level event log (repro.obs): drains, migrations, rescues,
        # threshold pushes — always on (bounded host bookkeeping), shown
        # as the `fleet` track in the Perfetto export
        obs_cfg = getattr(members[0].cfg, "obs", None)
        self.events = EventLog(obs_cfg.max_events if obs_cfg is not None
                               else 1024)
        self.aggregator = aggregator
        if aggregator is not None:
            from repro.autotune.artifacts import config_key
            keys = set()
            for i, m in enumerate(self.members):
                if not m.cfg.autotune.enabled:
                    raise ValueError(
                        f"member {i} has autotune disabled — a fleet "
                        "aggregator needs telemetry in every member's "
                        "decode graphs (cfg.with_autotune(enabled=True))")
                if getattr(m, "controller", None) is not None:
                    raise ValueError(
                        f"member {i} carries its own controller — one "
                        "fleet aggregator and one per-engine controller "
                        "would push thresholds at each other; build the "
                        "member without autotune=/controller=")
                keys.add(config_key(m.cfg))
            if len(keys) > 1:
                raise ValueError(
                    "fleet members have different calibration identities "
                    "(config_key) — merged telemetry is only meaningful "
                    "across engines running the same cascade")
            aggregator.attach(self)

    # -- submission / placement ------------------------------------------
    def submit(self, req: Request) -> None:
        if req.rid in self._tracked or req.rid in self.finished:
            raise ValueError(f"duplicate rid {req.rid}")
        fr = _FleetRequest(request=req, order=self._order)
        self._order += 1
        self._tracked[req.rid] = fr
        self.queue.append(fr)

    def _predict_depth(self, req: Request) -> float:
        hint = (req.extra or {}).get("predicted_depth")
        return self.compactor.predict_depth(hint)

    def _candidates(self) -> List[int]:
        out = []
        for i, m in enumerate(self.members):
            if not self.health.healthy(i):
                continue
            if i in self.draining or i in self.drained:
                continue
            try:
                if not m.admitting or m.free_slot_count() <= 0:
                    continue
            except Exception as e:                    # noqa: BLE001
                self.health.note_failure(i, self._tick, e)
                self._rescue_if_unhealthy(i)
                continue
            out.append(i)
        return out

    def _score(self, idx: int, depth: float) -> float:
        """Placement score (lower = better); see module docstring."""
        m = self.members[idx]
        fl = self.fleet
        n_comp = m.cfg.cascade.n_components
        depth_term = (abs(self.compactor.lane_stats[idx].depth_ema - depth)
                      / max(1, n_comp - 1))
        free = m.free_slot_count()
        live = len(m.live_rids())
        capacity = max(1, free + live)
        load_term = (live + m.queued_count()) / capacity
        block_term = 0.0
        if fl.block_weight and getattr(m, "paged", False):
            pool = m.pcache.pool
            # block 0 is the reserved trash block, never allocatable
            block_term = 1.0 - pool.free_blocks / max(1, pool.num_blocks - 1)
        return (fl.depth_weight * depth_term + fl.load_weight * load_term
                + fl.block_weight * block_term)

    def _place(self) -> None:
        """Head-of-queue FIFO placement (same discipline as engine
        admission: if the head fits nowhere, the queue waits)."""
        while self.queue:
            cands = self._candidates()
            if not cands:
                return
            fr = self.queue[0]
            depth = self._predict_depth(fr.request)
            scores = [self._score(i, depth) for i in cands]
            best = cands[int(np.argmin(scores))]
            self.queue.pop(0)
            self._dispatch(fr, best)

    def _dispatch(self, fr: _FleetRequest, idx: int) -> None:
        """Submit ``fr`` to member ``idx``; a migrated request's committed
        prefix rides the escalation replay path when the source and target
        configs share a prefix (vocab + family), else the target restarts
        from the original prompt and the committed tokens are discarded
        (counted, like the tier's ``discarded_draft_tokens``)."""
        m = self.members[idx]
        req = fr.request
        if fr.committed:
            share = (idx == fr.src_engine or resolve_share_prefix(
                self.members[fr.src_engine].cfg, m.cfg))
            if share:
                prompt2, max_new2, replayed = build_replay(
                    req.prompt, fr.committed, req.max_new_tokens,
                    share_prefix=True)
                extra = dict(req.extra or {})
                # the engine's ordinary escalation replay accounting —
                # migrated prefixes are replayed prefill, not fresh traffic
                extra["escalation"] = {"rid": req.rid, "replayed": replayed,
                                       "migrated": True}
                m.submit(Request(rid=req.rid, prompt=prompt2,
                                 max_new_tokens=max_new2, extra=extra))
            else:
                fr.discarded_tokens += len(fr.committed)
                fr.committed = []
                fr.committed_depths = []
                fr.committed_confs = []
                fr.spans.append({"engine": fr.src_engine, "tokens": 0,
                                 "discarded": True})
                m.submit(req)
        else:
            m.submit(req)
        fr.engine = idx
        self.placements += 1

    # -- stepping ---------------------------------------------------------
    def step(self) -> None:
        """One fleet tick: place, step every live member, collect finished
        work, settle drains, run the aggregator's (rarely firing) merged
        solve, heartbeat."""
        self._tick += 1
        self._place()
        for idx, m in enumerate(self.members):
            if not self.health.healthy(idx) or idx in self.drained:
                continue
            try:
                m.step()
            except Exception as e:                    # noqa: BLE001
                self.health.note_failure(idx, self._tick, e)
                self._rescue_if_unhealthy(idx)
        self._collect()
        self._finish_drains()
        if self.aggregator is not None:
            self.aggregator.maybe_update(self)
        if self._tick % self.fleet.heartbeat_every == 0:
            self._heartbeat()

    def _heartbeat(self) -> None:
        for idx, m in enumerate(self.members):
            if idx in self.drained:
                continue
            self.health.beat(idx, self._tick, m.stats)
            if not self.health.healthy(idx):
                self._rescue_if_unhealthy(idx)
            elif idx in self._rescued and self.health.healthy(idx):
                # a recovered member serves fresh traffic again
                self._rescued.discard(idx)

    def _collect(self) -> None:
        for rid, fr in list(self._tracked.items()):
            if fr.engine is None:
                continue
            m = self.members[fr.engine]
            rec = m.finished.get(rid)
            if rec is None:
                continue
            m.finished.pop(rid, None)
            self._finalize(fr, rec, fr.engine)

    def _finalize(self, fr: _FleetRequest, rec: Optional[dict],
                  idx: Optional[int]) -> None:
        """Stitch the committed prefix (earlier members) and the finishing
        member's record into one fleet-level finished record."""
        rid = fr.request.rid
        tokens = list(fr.committed)
        depths = list(fr.committed_depths)
        confs = list(fr.committed_confs)
        spans = list(fr.spans)
        if rec is not None:
            tokens += list(rec["tokens"])
            depths += list(rec["exit_depths"])
            confs += list(rec["confs"])
            spans.append({"engine": idx, "tokens": len(rec["tokens"])})
        self.finished[rid] = {
            "tokens": tokens,
            "exit_depths": depths,
            "confs": confs,
            "engine": idx,
            "spans": spans,
            "migrations": fr.migrations,
            "requeues": fr.requeues,
            "discarded_tokens": fr.discarded_tokens,
            "escalated": bool(rec and rec.get("escalated", False)),
        }
        del self._tracked[rid]
        if idx is not None and rec is not None and rec["exit_depths"]:
            # feed the fleet-level depth prior exactly like an engine
            # feeds its lane compactor (skip accounting stays with the
            # engines — the fleet only learns depth placement)
            d = np.asarray(rec["exit_depths"])
            self.compactor.observe(idx, d, 0.0, steps=len(d))
            self.compactor.observe_retire(idx)
            if not fr.committed:
                self.compactor.observe_prefill_exit(float(d[0]))

    # -- drain / migration ------------------------------------------------
    def drain(self, idx: int, mode: Optional[str] = None) -> dict:
        """Drain member ``idx`` for a rolling restart.

        Stops admission immediately; queued requests requeue to the fleet
        (they were never decoded — nothing to preserve).  In-flight slots
        either run to exit or budget on the draining member
        (``"finish"``) or are cancelled and migrated (``"migrate"``):
        the cancel record's tokens become the fleet request's committed
        prefix, replayed into whichever sibling placement picks next.  A
        request whose committed tokens already meet its budget finalizes
        right here instead of requeueing (replay would have nothing left
        to decode).  Returns a summary; the member reports ``drained``
        once its last in-flight slot retires."""
        if mode is None:
            mode = self.fleet.drain_mode
        if mode not in ("finish", "migrate"):
            raise ValueError(f"drain mode {mode!r}")
        m = self.members[idx]
        m.admitting = False
        self.draining.add(idx)
        requeued, migrated, completed = [], [], []
        for req in m.take_queue():
            fr = self._tracked[req.rid]
            fr.engine = None
            fr.requeues += 1
            self.requeues += 1
            self.queue.append(fr)
            requeued.append(req.rid)
        if mode == "migrate" and hasattr(m, "cancel"):
            for rid in list(m.live_rids()):
                rec = _cancel_member(m, rid, "migrate")
                if rec is None:
                    continue
                # the cancel record is migration bookkeeping, not a
                # completion — keep it out of the member's finished set
                # so its stats count only requests it answered
                m.finished.pop(rid, None)
                fr = self._tracked[rid]
                fr.committed += list(rec["tokens"])
                fr.committed_depths += list(rec["exit_depths"])
                fr.committed_confs += list(rec["confs"])
                fr.spans.append({"engine": idx, "tokens": len(rec["tokens"])})
                fr.src_engine = idx
                fr.engine = None
                fr.migrations += 1
                self.migrations += 1
                if len(fr.committed) >= fr.request.max_new_tokens:
                    self._finalize(fr, None, idx)
                    completed.append(rid)
                else:
                    self.queue.append(fr)
                    migrated.append(rid)
        # FIFO restore: placement order is original submission order,
        # the same rule the escalation tier applies before resubmits
        self.queue.sort(key=lambda f: f.order)
        log.info("drain(%d, mode=%s): %d requeued, %d migrated, %d "
                 "completed-at-drain", idx, mode, len(requeued),
                 len(migrated), len(completed))
        summary = {"engine": idx, "mode": mode, "requeued": requeued,
                   "migrated": migrated, "completed": completed}
        self.events.add("drain", {"member": idx, "mode": mode,
                                  "requeued": len(requeued),
                                  "migrated": len(migrated),
                                  "completed": len(completed),
                                  "rids_migrated": migrated,
                                  "tick": self._tick})
        return summary

    def _finish_drains(self) -> None:
        for idx in list(self.draining):
            m = self.members[idx]
            try:
                empty = not m.live_rids() and not m.queued_count()
            except Exception:                         # noqa: BLE001
                empty = True
            if empty:
                self.draining.discard(idx)
                self.drained.add(idx)
                log.info("member %d drained", idx)

    def resume(self, idx: int) -> None:
        """Bring a drained (restarted) member back into rotation, pushing
        the fleet's live thresholds so it decodes with the current
        calibration from its first request (fleet warm-start)."""
        m = self.members[idx]
        self.draining.discard(idx)
        self.drained.discard(idx)
        m.admitting = True
        self.events.add("resume", {"member": idx, "tick": self._tick})
        if (self._live_thresholds is not None
                and hasattr(m, "push_thresholds")):
            m.push_thresholds(self._live_thresholds)

    def add_member(self, member) -> int:
        """Grow the fleet: the new member starts at the population depth
        prior (no banded guess — the fleet has real evidence) and
        inherits the current fleet thresholds immediately, which is the
        artifact store's warm-start promise made live."""
        self.members.append(member)
        self.compactor.lane_stats.append(
            LaneStats(depth_ema=self.compactor.population_prior))
        self.health.add_member()
        if (self._live_thresholds is not None
                and hasattr(member, "push_thresholds")):
            member.push_thresholds(self._live_thresholds)
        return len(self.members) - 1

    # -- failure rescue ---------------------------------------------------
    def _rescue_if_unhealthy(self, idx: int) -> None:
        """Once per unhealthy transition: pull the member's queued work
        back to the fleet and migrate-or-resubmit its live work."""
        if self.health.healthy(idx) or idx in self._rescued:
            return
        self._rescued.add(idx)
        m = self.members[idx]
        try:
            taken = m.take_queue()
        except Exception:                             # noqa: BLE001
            taken = []
        for req in taken:
            fr = self._tracked.get(req.rid)
            if fr is None:
                continue
            fr.engine = None
            fr.requeues += 1
            self.requeues += 1
            self.queue.append(fr)
        try:
            live = list(m.live_rids())
        except Exception:                             # noqa: BLE001
            live = [rid for rid, fr in self._tracked.items()
                    if fr.engine == idx]
        for rid in live:
            fr = self._tracked.get(rid)
            if fr is None or fr.engine != idx:
                continue
            rec = None
            if hasattr(m, "cancel"):
                try:
                    rec = _cancel_member(m, rid, "migrate")
                    m.finished.pop(rid, None)
                except Exception:                     # noqa: BLE001
                    rec = None
            if rec is not None:
                fr.committed += list(rec["tokens"])
                fr.committed_depths += list(rec["exit_depths"])
                fr.committed_confs += list(rec["confs"])
                fr.spans.append({"engine": idx,
                                 "tokens": len(rec["tokens"])})
                fr.src_engine = idx
                fr.migrations += 1
                self.migrations += 1
            # a dead member's un-cancellable slots lose their uncommitted
            # work; the request restarts from whatever we hold
            fr.engine = None
            if len(fr.committed) >= fr.request.max_new_tokens:
                self._finalize(fr, None, idx)
            else:
                self.queue.append(fr)
        self.queue.sort(key=lambda f: f.order)
        self.events.add("rescue", {"member": idx, "requeued": len(taken),
                                   "live_recovered": len(live),
                                   "tick": self._tick})
        log.warning("rescued member %d: %d queued requeued, %d live "
                    "recovered", idx, len(taken), len(live))

    # -- controller surface (what the TelemetryAggregator drives) --------
    def lane_telemetry(self) -> List:
        """Every healthy member's lane telemetry, concatenated — the
        merged-solve input.  ``merge_telemetry`` sums fixed-size counters,
        so lanes from different members merge exactly like lanes from one
        (homogeneous configs enforced at construction)."""
        out = []
        for idx, m in enumerate(self.members):
            if not self.health.healthy(idx):
                continue
            if not hasattr(m, "lane_telemetry"):
                continue
            try:
                out.extend(m.lane_telemetry())
            except Exception as e:                    # noqa: BLE001
                self.health.note_failure(idx, self._tick, e)
        return out

    def current_thresholds(self):
        return self._live_thresholds

    def push_thresholds(self, thresholds) -> None:
        """Fan one threshold vector to every healthy member — the fleet
        half of the zero-retrace push path (each member's own
        ``push_thresholds`` is the data swap)."""
        pushed = tuple(float(t) for t in thresholds)
        for idx, m in enumerate(self.members):
            if not self.health.healthy(idx):
                continue
            if not hasattr(m, "push_thresholds"):
                continue
            try:
                m.push_thresholds(pushed)
            except Exception as e:                    # noqa: BLE001
                self.health.note_failure(idx, self._tick, e)
        self._live_thresholds = pushed
        self.events.add("threshold_push", {"thresholds": list(pushed),
                                           "tick": self._tick})

    # -- driving / reporting ----------------------------------------------
    def run(self, max_ticks: int = 1000) -> Dict[int, dict]:
        for _ in range(max_ticks):
            if not self._tracked:
                break
            self.step()
        return self.finished

    # -- observability (repro.obs) ----------------------------------------
    @property
    def obs_events(self):
        """The fleet-level event log — also the hook a fleet-attached
        ThresholdController/TelemetryAggregator records resolves into."""
        return self.events

    def _recorders(self):
        """(name, FlightRecorder) per member that has one (obs enabled)."""
        out = []
        for i, m in enumerate(self.members):
            fl = getattr(m, "flight", None)
            if fl is not None:
                out.append((f"member{i}", fl))
        return out

    def dump_flight(self, rid: int) -> Optional[dict]:
        """Every member's flight for ``rid`` (a migrated request shows
        one per member it touched) stitched with the fleet-level record
        — None when nobody recorded it."""
        flights = []
        for i, m in enumerate(self.members):
            dump = getattr(m, "dump_flight", None)
            d = dump(rid) if dump is not None else None
            if isinstance(d, list):          # tier member: one per stage
                flights.extend({"member": i, **x} for x in d)
            elif d is not None:
                flights.append({"member": i, **d})
        if not flights and rid not in self.finished:
            return None
        return {"rid": rid, "members": flights,
                "record": self.finished.get(rid)}

    def scrape(self) -> str:
        """Prometheus text: per-member metrics (``member=`` label), the
        merged latency summaries (``member="merged"``) and fleet-level
        placement/drain/health metrics."""
        return self._registry().render_text()

    def scrape_json(self) -> dict:
        return self._registry().render_json()

    def _registry(self):
        from repro.obs.metrics import MetricsRegistry, engine_metrics_into
        reg = MetricsRegistry()
        merged = {}
        for i, m in enumerate(self.members):
            try:
                engine_metrics_into(reg, m, {"member": str(i)})
            except Exception as e:                    # noqa: BLE001
                self.health.note_failure(i, self._tick, e)
            fl = getattr(m, "flight", None)
            if fl is not None:
                for key, res in fl.reservoirs.items():
                    agg = merged.setdefault(key, ([], [0], [0.0]))
                    agg[0].extend(res.values())
                    agg[1][0] += res.count
                    agg[2][0] += res.total
        names = {"e2e_seconds": ("repro_request_latency_seconds",
                                 "Submit-to-finalize latency per request."),
                 "per_token_seconds": (
                     "repro_token_latency_seconds",
                     "Decode wall-clock attributed per generated token."),
                 "macs_per_request": (
                     "repro_macs_per_request",
                     "Analytic decode MACs spent per finished request.")}
        for key, (vals, cnt, tot) in merged.items():
            if key not in names:
                continue
            name, help_ = names[key]
            reg.summary(name, help_, vals, {"member": "merged"},
                        count=cnt[0], total=tot[0])
        for i in range(len(self.members)):
            h = self.health.summary(i)
            lm = {"member": str(i)}
            reg.gauge("repro_fleet_member_healthy",
                      "1 while the member passes health probes.",
                      1.0 if h["healthy"] else 0.0, lm)
            reg.gauge("repro_fleet_member_consecutive_failures",
                      "Consecutive probe/step failures (resets on a "
                      "successful probe).", h["consecutive_failures"], lm)
            reg.gauge("repro_fleet_member_backoff_ticks",
                      "Current exponential-backoff window before the "
                      "next probe.", h["backoff"], lm)
            reg.counter("repro_fleet_member_unhealthy_marks_total",
                        "Times the member crossed max_failures.",
                        h["unhealthy_marks"], lm)
        reg.gauge("repro_fleet_queue_depth",
                  "Requests waiting in the fleet queue.", len(self.queue))
        reg.counter("repro_fleet_placements_total",
                    "Requests placed onto members.", self.placements)
        reg.counter("repro_fleet_migrations_total",
                    "Live requests migrated off a member.", self.migrations)
        reg.counter("repro_fleet_requeues_total",
                    "Queued requests pulled back to the fleet queue.",
                    self.requeues)
        for name in ("drain", "rescue", "resume", "threshold_push"):
            reg.counter(f"repro_fleet_{name}_events_total",
                        f"Fleet-level {name} events.",
                        self.events.counts.get(name, 0))
        if self.aggregator is not None and hasattr(self.aggregator,
                                                   "metrics_into"):
            self.aggregator.metrics_into(reg, self)
        return reg

    def trace_events(self) -> List[dict]:
        """Chrome trace-event list: one process per member (lane tracks,
        chunk slices) plus the fleet event track (drains, migrations,
        pushes) — ready for Perfetto."""
        from repro.obs.traceviz import trace_events
        return trace_events(self._recorders(),
                            extra_events=self.events.snapshot())

    def export_trace(self, path: str) -> dict:
        from repro.obs.traceviz import export_trace
        return export_trace(path, self._recorders(),
                            extra_events=self.events.snapshot())

    def stats(self) -> dict:
        members = []
        for idx, m in enumerate(self.members):
            try:
                members.append({
                    "free_slots": m.free_slot_count(),
                    "queued": m.queued_count(),
                    "live": len(m.live_rids()),
                    "finished": len(m.finished),
                    "depth_ema": self.compactor.lane_stats[idx].depth_ema,
                    # the EngineHealth satellite: flapping is visible per
                    # member without digging into stats()["health"]
                    **self.health.summary(idx),
                })
            except Exception as e:                    # noqa: BLE001
                members.append({"error": repr(e),
                                **self.health.summary(idx)})
        return {
            "n_members": len(self.members),
            "requests_finished": len(self.finished),
            "requests_live": len(self._tracked),
            "queue_len": len(self.queue),
            "placements": self.placements,
            "migrations": self.migrations,
            "requeues": self.requeues,
            "discarded_tokens": sum(r["discarded_tokens"]
                                    for r in self.finished.values()),
            "draining": sorted(self.draining),
            "drained": sorted(self.drained),
            "thresholds": (list(self._live_thresholds)
                           if self._live_thresholds is not None else None),
            "aggregator": (self.aggregator.stats()
                           if self.aggregator is not None else None),
            "health": self.health.stats(),
            "events": dict(self.events.counts),
            "members": members,
        }
