"""Free-list block pool for the paged KV cache.

One :class:`BlockPool` owns the physical block ID space of a serving
engine's shared stores — blocks are fungible across lanes, slots and
cascade components (the SHARK-Engine ``BlockCache`` shape: a flat free
list, claim/release, no per-consumer partitions).  Block 0 is the
reserved *trash block*: dead slots' block-table entries point at it so
their (masked, never-read) decode writes land somewhere harmless instead
of corrupting a reallocated block.

The pool is host-side bookkeeping only — allocation never touches the
device.  What makes it cascade-aware is the accounting split on release:
blocks that backed components *deeper than the slot's observed exit
depth* count as ``reclaimed_by_exit`` (the cascade never computed those
components for this slot; their blocks only mirrored backfill state),
the rest as ``reclaimed_at_retire``.  Reclamation happens at the first
host sync after a slot finishes — the chunk boundary — NOT at the next
whole-lane re-prefill (see DESIGN.md "In-chunk reclamation").
"""
from __future__ import annotations

from typing import List, Optional

TRASH_BLOCK = 0


class BlockPool:
    """Flat free list over ``num_blocks`` fixed-size KV blocks.

    ``block_size`` is ring positions per block; ``block_bytes`` (set by the
    cache builder) prices one block across every component's k/v planes so
    ``peak_cache_bytes`` in :meth:`stats` is an honest HBM figure.
    """

    def __init__(self, num_blocks: int, block_size: int,
                 block_bytes: int = 0):
        if num_blocks < 2:
            raise ValueError(
                f"num_blocks must be >= 2 (block 0 is the reserved trash "
                f"block), got {num_blocks}")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.block_bytes = int(block_bytes)
        # LIFO free list, block 0 (trash) never enters it
        self._free: List[int] = list(range(self.num_blocks - 1, 0, -1))
        self.used = 0
        self.peak_used = 0
        self.reclaimed_by_exit = 0
        self.reclaimed_at_retire = 0
        # soft admission cap for cross-engine block donation: a tier can
        # lower one pool's cap and raise another's without moving physical
        # stores (they can't move — each engine's device buffers are its
        # own).  None = the physical limit.  Only ADMISSION honors the
        # cap; blocks already allocated above a newly lowered cap stay
        # valid and drain naturally at retire.
        self.soft_cap: Optional[int] = None
        # per-chunk reclamation window (engine calls begin_chunk per
        # dispatch; end_chunk returns blocks freed since)
        self._chunk_mark = 0
        self.chunk_reclaims: List[int] = []

    # ------------------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def _cap_free(self) -> int:
        """Blocks an allocation may still claim under the soft cap."""
        if self.soft_cap is None:
            return len(self._free)
        return min(len(self._free), max(0, self.soft_cap - self.used))

    def set_soft_cap(self, cap: Optional[int]):
        """Donate/reclaim capacity: cap usable blocks at ``cap`` (None
        lifts the cap).  The trash block is outside the budget; caps above
        the physical allocatable count are clamped, never an error —
        donation is advisory, the free list stays authoritative."""
        if cap is None:
            self.soft_cap = None
            return
        cap = int(cap)
        if cap < 0:
            raise ValueError(f"soft_cap must be >= 0, got {cap}")
        self.soft_cap = min(cap, self.num_blocks - 1)

    def can_alloc(self, n: int) -> bool:
        return n <= self._cap_free()

    def alloc(self, n: int) -> Optional[List[int]]:
        """Claim ``n`` blocks, or None (no partial grants — the caller
        backpressures admission instead of corrupting a half-covered
        slot)."""
        if n > self._cap_free():
            return None
        ids = [self._free.pop() for _ in range(n)]
        self.used += n
        self.peak_used = max(self.peak_used, self.used)
        return ids

    def free(self, ids: List[int], by_exit: bool = False):
        for b in ids:
            if b == TRASH_BLOCK:
                raise ValueError("attempt to free the trash block")
            self._free.append(b)
        self.used -= len(ids)
        if by_exit:
            self.reclaimed_by_exit += len(ids)
        else:
            self.reclaimed_at_retire += len(ids)

    # -- per-chunk reclamation telemetry --------------------------------
    def begin_chunk(self):
        self._chunk_mark = self.reclaimed_by_exit + self.reclaimed_at_retire

    def end_chunk(self) -> int:
        freed = (self.reclaimed_by_exit + self.reclaimed_at_retire
                 - self._chunk_mark)
        self.chunk_reclaims.append(freed)
        return freed

    def reset_window(self):
        """Clear the per-chunk reclaim window (engine ``reset_metrics``).
        ``peak_used`` and the lifetime reclaim counters survive: peak
        occupancy is high-water capacity accounting, the same split that
        keeps ``compile_seconds`` out of the decode window."""
        self.chunk_reclaims.clear()
        self._chunk_mark = self.reclaimed_by_exit + self.reclaimed_at_retire

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        return {
            "num_blocks": self.num_blocks,
            "block_size": self.block_size,
            "block_bytes": self.block_bytes,
            "blocks_free": self.free_blocks,
            "blocks_used": self.used,
            "peak_blocks_used": self.peak_used,
            "soft_cap": self.soft_cap,
            "reclaimed_by_exit": self.reclaimed_by_exit,
            "reclaimed_at_retire": self.reclaimed_at_retire,
            "blocks_reclaimed_per_chunk": list(self.chunk_reclaims[-32:]),
            "peak_cache_bytes": self.peak_used * self.block_bytes,
        }
