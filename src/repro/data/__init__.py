from repro.data.synth_images import SynthImageDataset, make_image_splits
from repro.data.lm_pipeline import SyntheticLMStream, shard_batch

__all__ = ["SynthImageDataset", "make_image_splits", "SyntheticLMStream",
           "shard_batch"]
