from repro.serving.engine import CascadeServingEngine, Request
from repro.serving.batching import DepthCompactor
from repro.serving.runtime import DecodeChunk, DeviceDecodeLoop

__all__ = ["CascadeServingEngine", "Request", "DepthCompactor",
           "DecodeChunk", "DeviceDecodeLoop"]
