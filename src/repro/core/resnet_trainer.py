"""End-to-end driver for the faithful reproduction: BT-train CI-RESNET(n)
(Algorithm 2), collect per-component confidences, calibrate thresholds (§5),
and evaluate the early-termination tradeoff (Algorithm 1 / Table 2 / Fig 3).

The paper's setup: SGD, cross-entropy + L2(1e-4), He init, [HZRS15a] LR
schedule, data augmentation for CIFAR.  All reproduced; the dataset is the
synthetic difficulty-structured distribution (see data/synth_images.py and
DESIGN.md §2 for the data gate).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import CascadeEvalResult, sweep_epsilons
from repro.core.macs import resnet_component_macs
from repro.core.policy import get_measure
from repro.core.training import (Phase, backtrack_training_plan, cross_entropy,
                                 l2_loss)
from repro.data.synth_images import SynthImageDataset
from repro.models.resnet import CIResNet
from repro.optim import sgd_momentum, resnet_paper_schedule
from repro.optim.optimizer import apply_updates
from repro.utils import get_logger

log = get_logger("resnet_trainer")


@dataclasses.dataclass
class TrainReport:
    component_acc: List[float]          # test accuracy of each component
    phase_losses: Dict[str, List[float]]
    params: Dict
    state: Dict


def _mask_for_phase(params, phase: Phase):
    """CI-ResNet layout: backbone = stem+modules; heads = head0..head2."""
    def mask(path, leaf):
        name = path[0].key if hasattr(path[0], "key") else str(path[0])
        if name.startswith("head"):
            idx = int(name[4:])
            if idx == 2:
                return jnp.asarray(phase.train_backbone)
            return jnp.asarray(idx in phase.train_heads)
        return jnp.asarray(phase.train_backbone)
    return jax.tree_util.tree_map_with_path(mask, params)


def train_backtrack(model: CIResNet, train: SynthImageDataset,
                    n_epochs: int, batch_size: int = 128,
                    base_lr: float = 0.1, l2_coef: float = 1e-4,
                    augment: bool = True, seed: int = 0,
                    test: Optional[SynthImageDataset] = None) -> TrainReport:
    """Algorithm 2 BT(M, T, n_e)."""
    key = jax.random.PRNGKey(seed)
    params, state = model.init(key)
    plan = backtrack_training_plan(3)
    steps_per_epoch = len(train) // batch_size
    rng = np.random.default_rng(seed)
    phase_losses: Dict[str, List[float]] = {}

    @functools.partial(jax.jit, static_argnames=("head", "train_flag"))
    def train_step(params, state, opt_state, x, y, mask, step, head,
                   train_flag=True):
        def loss_fn(p):
            logits, new_state = model.apply(p, state, x, train=train_flag)
            loss = cross_entropy(logits[head], y) + l2_loss(p, l2_coef)
            return loss, new_state
        (loss, new_state), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        updates, opt_state = opt.update(grads, opt_state, params, step,
                                        mask=mask)
        params = apply_updates(params, updates)
        return params, new_state, opt_state, loss

    for phase in plan:
        epochs = max(1, int(round(phase.epochs * n_epochs)))
        total_steps = epochs * steps_per_epoch
        lr = resnet_paper_schedule(base_lr if phase.train_backbone
                                   else base_lr * 0.1, total_steps)
        opt = sgd_momentum(lr, momentum=0.9)
        opt_state = opt.init(params)
        mask = _mask_for_phase(params, phase)
        head = phase.loss_head
        losses = []
        step = 0
        for x, y in train.batches(batch_size, rng, epochs=epochs,
                                  augment=augment):
            params, state, opt_state, loss = train_step(
                params, state, opt_state, jnp.asarray(x), jnp.asarray(y),
                mask, jnp.asarray(step), head)
            losses.append(float(loss))
            step += 1
        phase_losses[phase.name] = losses
        log.info("phase %s: %d steps, loss %.4f -> %.4f", phase.name, step,
                 losses[0], np.mean(losses[-20:]))

    report = TrainReport([], phase_losses, params, state)
    if test is not None:
        conf, preds, _ = collect_outputs(model, params, state, test)
        report.component_acc = [float(np.mean(p == test.labels))
                                for p in preds]
        log.info("component accuracies: %s", report.component_acc)
    return report


def collect_logits(model: CIResNet, params, state,
                   data: SynthImageDataset,
                   batch_size: int = 256) -> List[np.ndarray]:
    """One forward pass over the dataset: per-component logits (N, C).

    Logits are measure-independent — collect them once, then score any
    number of confidence measures on the cached tensors with
    :func:`score_logits` (what the measure-ablation bench does)."""
    @jax.jit
    def fwd(x):
        logits, _ = model.apply(params, state, x, train=False)
        return logits

    n_m = 3
    logits = [[] for _ in range(n_m)]
    for i in range(0, len(data), batch_size):
        x = jnp.asarray(data.images[i:i + batch_size])
        out = fwd(x)
        for m in range(n_m):
            logits[m].append(np.asarray(out[m]))
    return [np.concatenate(lg) for lg in logits]


def score_logits(logits: List[np.ndarray], labels: np.ndarray,
                 measure="softmax_max"):
    """(confidence, prediction, correct) per component from cached logits.

    ``measure`` is a confidence-measure registry spec (or instance)."""
    m_fn = get_measure(measure) if isinstance(measure, str) else measure
    score = jax.jit(lambda lg: m_fn(lg))
    confs, preds = [], []
    for lg in logits:
        out, delta = score(jnp.asarray(lg))
        preds.append(np.asarray(out))
        confs.append(np.asarray(delta))
    corrects = [(p == labels).astype(np.float64) for p in preds]
    return confs, preds, corrects


def collect_outputs(model: CIResNet, params, state,
                    data: SynthImageDataset, batch_size: int = 256,
                    measure="softmax_max"):
    """Per-component (confidence, prediction, correct) over a dataset —
    one forward pass (:func:`collect_logits`) + one measure scoring
    (:func:`score_logits`)."""
    logits = collect_logits(model, params, state, data, batch_size)
    return score_logits(logits, data.labels, measure)


def evaluate_wallclock(model: CIResNet, params, state,
                       data: SynthImageDataset, thresholds,
                       measure="softmax_max", batch_size: int = 256,
                       repeats: int = 3):
    """MEASURED wall-clock of staged cascade evaluation vs the dense cascade.

    Component m+1 runs only on samples still undecided after component m
    (host-side dynamic batching in fixed-shape padded chunks — the CPU/GPU
    analogue of the TPU engine's ``cond_batch`` skipping), so the compute
    the thresholds save is real elapsed time, not analytic MACs.  Both
    passes are jit-warmed before timing.

    Returns ``{"wallclock_speedup", "t_staged_s", "t_dense_s",
    "exit_fractions"}``.
    """
    m_fn = get_measure(measure) if isinstance(measure, str) else measure
    fns = model.component_fns(params, state)
    comp = [jax.jit(lambda x: fns[0](x, None)),
            jax.jit(lambda c: fns[1](None, c)),
            jax.jit(lambda c: fns[2](None, c))]
    score = jax.jit(lambda lg: m_fn(lg)[1])
    ths = tuple(float(t) for t in thresholds)
    images = np.asarray(data.images)

    def run_component(m, arr):
        """Apply component m chunkwise (padded to batch_size); returns
        (confidence (n,), features (n, ...))."""
        confs, feats = [], []
        for i in range(0, arr.shape[0], batch_size):
            chunk = arr[i:i + batch_size]
            real = chunk.shape[0]
            if real < batch_size:                 # pad to the fixed shape
                chunk = np.concatenate(
                    [chunk, np.repeat(chunk[:1], batch_size - real, 0)])
            lg, feat = comp[m](jnp.asarray(chunk))
            confs.append(np.asarray(score(lg))[:real])
            feats.append(np.asarray(feat)[:real])
        return np.concatenate(confs), np.concatenate(feats)

    def staged_pass():
        alive = images
        exited = []
        for m in range(3):
            if alive.shape[0] == 0:
                exited.append(0)
                continue
            conf, feat = run_component(m, alive)
            if m < 2:
                stay = conf < ths[m]
                exited.append(int(alive.shape[0] - stay.sum()))
                alive = feat[stay]
            else:
                exited.append(alive.shape[0])
        return exited

    def dense_pass():
        arr = images
        for m in range(3):
            _, arr = run_component(m, arr)

    staged_pass(), dense_pass()                  # jit warm-up
    t0 = time.perf_counter()
    for _ in range(repeats):
        exited = staged_pass()
    t_staged = (time.perf_counter() - t0) / repeats
    t0 = time.perf_counter()
    for _ in range(repeats):
        dense_pass()
    t_dense = (time.perf_counter() - t0) / repeats
    return {
        "wallclock_speedup": t_dense / t_staged if t_staged else 1.0,
        "t_staged_s": t_staged,
        "t_dense_s": t_dense,
        "exit_fractions": (np.asarray(exited, np.float64)
                           / max(1, len(data))).tolist(),
    }


def evaluate_tradeoff(model: CIResNet, params, state,
                      cal_data: SynthImageDataset,
                      test_data: SynthImageDataset,
                      epsilons, n_classes: int,
                      measure="softmax_max",
                      calibrator="self") -> List[Tuple[float, CascadeEvalResult]]:
    """ε-sweep: calibrate on cal_data, evaluate on test_data (paper §5/§6.2).

    ``measure`` / ``calibrator`` are registry specs, so any registered
    confidence measure or calibration rule runs through the same sweep."""
    mac_prefix = resnet_component_macs(model.n, n_classes,
                                       enhance_dim=model.enhance_dim)
    conf_c, _, corr_c = collect_outputs(model, params, state, cal_data,
                                        measure=measure)
    conf_t, pred_t, _ = collect_outputs(model, params, state, test_data,
                                        measure=measure)
    sweep = sweep_epsilons(conf_c, corr_c, conf_t, pred_t, test_data.labels,
                           mac_prefix, epsilons, calibrator=calibrator)
    return [(eps, res) for eps, _cal, res in sweep]
