"""Device-resident decode runtime: host/device bit-identity, cohort-split
skip counters, while_loop survival under jit + mesh sharding, and the
compile-time / retire-decay satellites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh

from repro.configs import get_config, reduced
from repro.core.exec import DecodeState, StagedExecutor, effective_cohorts
from repro.core.policy import ConfidenceMeasure, register_measure
from repro.models.model import build_model
from repro.serving import (CascadeServingEngine, DepthCompactor,
                           DeviceDecodeLoop, Request)


def _tiny(**cascade):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    return cfg.with_cascade(**cascade)


@pytest.fixture(scope="module")
def tiny_model():
    cfg = _tiny()
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# the acceptance contract: runtime="device" == runtime="host", bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("measure", ["softmax_max", "patience@2"])
def test_device_runtime_matches_host_engine(tiny_model, measure):
    """Same requests through both runtimes (cond_batch + 2 cohorts, mixed
    per-request budgets): identical tokens and exit indices for every
    request, for stateless AND stateful measures — the device while_loop is
    an execution strategy, not a semantics."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.6, 0.0), exit_mode="cond_batch", n_cohorts=2,
                confidence=measure)
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    budgets = [3, 5, 4, 6]

    def run(runtime):
        eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                   n_lanes=2, cache_len=32, runtime=runtime,
                                   chunk=4)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=p.copy(),
                               max_new_tokens=budgets[i]))
        eng.run(100)
        return eng

    h = run("host")
    d = run("device")
    assert h.finished.keys() == d.finished.keys()
    for rid in h.finished:
        assert h.finished[rid]["tokens"] == d.finished[rid]["tokens"]
        assert (h.finished[rid]["exit_depths"]
                == d.finished[rid]["exit_depths"])
        assert len(d.finished[rid]["tokens"]) == budgets[rid]
    # both runtimes did identical real execution (the state-carried
    # counters cover every step; the stats() window excludes each
    # runtime's own warm-up dispatch, so compare the carried state)
    h_run = np.sum([np.asarray(l["state"].segments_run)
                    for l in h.lanes], axis=0)
    d_run = np.sum([np.asarray(l["state"].segments_run)
                    for l in d.lanes], axis=0)
    np.testing.assert_array_equal(h_run, d_run)
    assert d.stats()["wallclock_us_per_token"] > 0


# ---------------------------------------------------------------------------
# cohort-split skipping converts more opportunity into realized skips
# ---------------------------------------------------------------------------

@register_measure("parity")
class ParityMeasure(ConfidenceMeasure):
    """Test measure: confident iff the argmax token is even — a
    deterministic mixed-difficulty batch (some rows always exit at
    component 0, others never) without training anything."""

    name = "parity"

    def __init__(self, arg: str = ""):
        del arg

    def __call__(self, logits):
        out = jnp.argmax(logits, axis=-1)
        return out, (out % 2 == 0).astype(jnp.float32)


def test_cohort_skip_counters_dominate_whole_lane(tiny_model):
    """On a mixed-difficulty batch the per-cohort predicate must realize at
    least as many skips as the whole-lane predicate — and strictly more
    here, where single hard rows hold the whole lane hostage."""
    model, params = tiny_model
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, 512, (4, 8)), jnp.int32)
    n_steps = 8

    def skip_fraction(n_cohorts):
        cfg = _tiny(thresholds=(0.5, 0.0), exit_mode="cond_batch",
                    confidence="parity", n_cohorts=n_cohorts)
        ex = StagedExecutor(model, cfg)
        cache = model.init_cache(4, 32)
        step = jax.jit(ex.decode_step, donate_argnums=(2, 3))
        d, cache, state = ex.prefill(params, toks, cache)
        for _ in range(n_steps):
            d, cache, state = step(params, d.prediction[:, None], cache,
                                   state)
        C = effective_cohorts(n_cohorts, 4)
        run_deep = int(np.asarray(state.segments_run)[1])
        return 1.0 - run_deep / (C * n_steps)

    whole = skip_fraction(1)
    cohort = skip_fraction(4)
    assert cohort >= whole
    assert cohort > whole        # deterministic under the fixed seed
    assert cohort > 0.0


def test_engine_places_requests_into_depth_cohorts(tiny_model):
    """Admission uses DepthCompactor depth predictions to pick the slot
    cohort: a shallow hint lands in cohort 0, a deep hint in the last."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(1.1, 0.0), n_cohorts=2)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=4, n_lanes=1,
                               cache_len=32)
    assert eng.cohorts == 2
    deep = Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                   max_new_tokens=2, extra={"predicted_depth": 1.0})
    shallow = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                      max_new_tokens=2, extra={"predicted_depth": 0.0})
    eng.submit(deep)
    eng.submit(shallow)
    eng._admit()
    lane = eng.lanes[0]
    rid_by_slot = [s.request.rid if not s.done else None
                   for s in lane["slots"]]
    # lane_batch=4, 2 cohorts -> slots [0,1] are cohort 0, [2,3] cohort 1
    assert rid_by_slot.index(1) < 2      # shallow -> cohort 0
    assert rid_by_slot.index(0) >= 2     # deep -> cohort 1
    # mesh sharding is a device-loop feature; the host runtime refuses it
    # instead of silently serving single-device
    with pytest.raises(ValueError, match="device"):
        CascadeServingEngine(cfg, model, params, runtime="host",
                             mesh=object())


# ---------------------------------------------------------------------------
# the while_loop carry survives jit + mesh sharding
# ---------------------------------------------------------------------------

def test_decode_loop_state_survives_jit_and_mesh_sharding(tiny_model):
    """A patience@2 config through the sharded device loop: streaks,
    cursor and cache ride the while_loop carry under jit with explicit
    mesh shardings; per-slot budgets end the loop early."""
    model, params = tiny_model
    cfg = _tiny(confidence="patience@2", thresholds=(0.0, 0.0),
                exit_mode="cond_batch")
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1),
                ("data", "model"))
    loop = DeviceDecodeLoop(model, cfg, chunk=8, cache_len=32, mesh=mesh)
    ex = StagedExecutor(model, cfg)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 6)), jnp.int32)
    d, cache, state = ex.prefill(params, toks, model.init_cache(2, 32))

    chunk, cache, state = loop.run_chunk(
        params, np.asarray(d.prediction)[:, None], cache, state,
        remaining=[3, 5])
    assert chunk.compiled and loop.compile_seconds > 0
    assert chunk.n_steps == 5                  # ended early: budgets spent
    assert chunk.live[:3, 0].all() and not chunk.live[3:, 0].any()
    assert chunk.live[:, 1].all()
    np.testing.assert_array_equal(chunk.remaining, [0, 0])
    # patience streak seeded at prefill survived INTO the loop: with
    # threshold 0 and k=2 every decode step exits at component 0, which is
    # only reachable if the carried streaks were not re-initialized
    assert (chunk.exits[chunk.live] == 0).all()
    assert isinstance(state, DecodeState)
    assert int(state.t) == toks.shape[1] + 5
    assert int(np.asarray(state.policy)[0].min()) >= 2
    assert not np.asarray(state.active).any()

    # a drained lane no-ops (0 iterations) without recompiling
    chunk2, cache, state = loop.run_chunk(
        params, chunk.tokens[-1:].T, cache, state, remaining=[0, 0])
    assert chunk2.n_steps == 0 and not chunk2.compiled
    assert chunk2.tokens.shape == (0, 2)


# ---------------------------------------------------------------------------
# satellites: compile-time separation, retire decay
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runtime", ["host", "device"])
def test_compile_time_reported_separately(tiny_model, runtime):
    """The first decode dispatch pays jit compilation; it must land in
    ``compile_seconds``, never in ``wallclock_us_per_token`` — with no
    reset_metrics() gymnastics by the caller."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.6, 0.0), exit_mode="cond_batch")
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=1,
                               cache_len=32, runtime=runtime, chunk=4)
    rng = np.random.default_rng(0)
    for i in range(2):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=6))
    eng.run(100)
    st = eng.stats()
    assert st["compile_seconds"] > 0
    assert st["wallclock_us_per_token"] > 0
    # compilation takes O(seconds); a warm decode step O(ms).  If warm-up
    # leaked into the wallclock average this ratio collapses.
    assert (st["wallclock_us_per_token"] / 1e6
            < st["compile_seconds"] / 2)
    # reset_metrics keeps the one-time compile cost (and stays warm)
    eng.reset_metrics()
    assert eng.stats()["compile_seconds"] == st["compile_seconds"]
    assert eng._decode_warm or runtime == "device"


def test_retire_decays_lane_depth_ema():
    """ROADMAP satellite: a retiring slot pulls the lane depth EMA back
    toward the population prior, so a lane that drained its deep requests
    stops repelling shallow traffic."""
    c = DepthCompactor(n_lanes=2, n_components=4, ema=0.8)
    c.lane_stats[0].depth_ema = 3.0        # lane served deep traffic
    prior = c.population_prior             # 1.5
    c.observe_retire(0)
    assert c.lane_stats[0].depth_ema == pytest.approx(
        0.8 * 3.0 + 0.2 * prior)
    for _ in range(50):
        c.observe_retire(0)
    assert c.lane_stats[0].depth_ema == pytest.approx(prior, abs=1e-3)
    # cohort placement helpers
    assert c.preferred_cohort(0.0, 2) == 0
    assert c.preferred_cohort(3.0, 2) == 1
    assert c.pick_slot(0.0, [1, 2, 3], lane_batch=4, n_cohorts=2) == 1
    assert c.pick_slot(3.0, [0, 1, 2], lane_batch=4, n_cohorts=2) == 2


def test_engine_end_to_end_with_retire_decay(tiny_model):
    """Serving traffic actually exercises the retire decay (depth EMAs end
    finite and sane) and finishes every request in device runtime."""
    model, params = tiny_model
    cfg = _tiny(thresholds=(0.0, 0.0), exit_mode="cond_batch", n_cohorts=2)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=2, n_lanes=2,
                               cache_len=32, runtime="device", chunk=4)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6).astype(np.int32), max_new_tokens=4))
    eng.run(200)
    st = eng.stats()
    assert st["requests_finished"] == 6
    assert st["cond_batch_skip_rate"] == 1.0   # threshold 0: all skip
    for ls in eng.compactor.lane_stats:
        assert 0.0 <= ls.depth_ema <= cfg.cascade.n_components
