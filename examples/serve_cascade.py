"""Serving scenario: batched requests through the cascade engine with
depth-compacted lanes, reporting the exit-depth histogram and the analytic
MAC speedup (the paper's metric) at several threshold settings.

    PYTHONPATH=src python examples/serve_cascade.py [--arch xlstm-350m]
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=8)
    args = ap.parse_args()

    base = reduced(get_config(args.arch)).replace(dtype="float32")
    model = build_model(base)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    print(f"{'threshold':>10} {'speedup':>8} {'mean_exit':>10} histogram")
    for th in (1.1, 0.9, 0.5, 0.1, 0.0):
        cfg = base.with_cascade(thresholds=(th, 0.0), exit_mode="select")
        eng = CascadeServingEngine(cfg, model, params, lane_batch=2,
                                   n_lanes=2, cache_len=48)
        for i in range(args.requests):
            eng.submit(Request(
                rid=i, prompt=rng.integers(0, cfg.vocab_size, 8).astype(
                    np.int32),
                max_new_tokens=args.max_new))
        eng.run(400)
        st = eng.stats()
        print(f"{th:>10.2f} {st['analytic_speedup']:>8.3f} "
              f"{st['mean_exit_depth']!s:>10} {st['exit_histogram']}")


if __name__ == "__main__":
    main()
