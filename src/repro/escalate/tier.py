"""Cross-model escalation tier: one ε-knob over a pool of engines.

:class:`ModelCascadeTier` fronts an ORDERED pool of
:class:`repro.serving.engine.CascadeServingEngine` instances — small
drafts first, large authorities last (Streeter's model-pool cascade, on
top of each model's own intra-model early-exit cascade).  A request
decodes on stage 0; every token its intra-model cascade answers at the
stage's FINAL component is additionally gated by the stage's escalation
threshold (:mod:`repro.escalate.router` — the IDK answer-or-defer rule).
A defer cancels the request at that token, keeps the committed prefix,
and re-submits the remainder to the next stage — replaying the prefix as
prefill when the stages can share it (:mod:`repro.escalate.replay`).

The tier's one knob is solved, not hand-set:
:class:`TierThresholdController` merges the stages' live exit telemetry
into ONE joint histogram (stage 0 accumulated under
``autotune.route_final`` so its final-component confidence is a routing
axis; :func:`repro.autotune.solver.compose_escalation` chains the stages
through the measured ``stage_agree``), prices every (stage, component)
exit with the heterogeneous per-stage analytic MACs
(:func:`repro.autotune.solver.compose_mac_prefix` over each engine's own
``mac_prefix``), runs the UNCHANGED ε / budget solver over the composed
histogram, and pushes the split result back: intra-model thresholds into
each engine (data, no retrace), the escalation threshold into the
router.

Parity corners (pinned by ``tests/test_escalate.py``): escalation
threshold 0.0 never defers — the tier is bit-identical to stage 0 alone;
threshold 1.1 with stage 0's intra thresholds at the 1.1 never-exit
sentinel defers every request at its first token with an empty committed
prefix — the next stage sees the exact original workload and the tier is
bit-identical to that stage alone.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.escalate.replay import build_replay, resolve_share_prefix
from repro.escalate.router import EscalationRouter
from repro.serving.engine import CascadeServingEngine, Request
from repro.utils import get_logger

log = get_logger("escalate")


@dataclasses.dataclass
class _TierRequest:
    """Tier-side tracking of one request across stages."""
    request: Request
    order: int                       # submission index (FIFO restore)
    stage: int = 0
    cursor: int = 0                  # tokens cleared at the current stage
    escalations: int = 0
    committed: List[int] = dataclasses.field(default_factory=list)
    committed_depths: List[int] = dataclasses.field(default_factory=list)
    committed_confs: List[float] = dataclasses.field(default_factory=list)
    spans: List[dict] = dataclasses.field(default_factory=list)
    # rejected token awaiting its next-stage regeneration (stage-agree
    # telemetry); only meaningful when the prefix was shared — an
    # unshared restart regenerates a different context
    pending_regen: Optional[int] = None


class ModelCascadeTier:
    """Escalation across an ordered pool of serving engines."""

    def __init__(self, engines: Sequence[CascadeServingEngine],
                 controller: Optional["TierThresholdController"] = None,
                 auto_rebalance: bool = False,
                 donate_quantum: int = 4):
        if not engines:
            raise ValueError("need at least one engine")
        self.engines = list(engines)
        if len(set(id(e) for e in self.engines)) != len(self.engines):
            raise ValueError(
                "each stage needs its own engine instance (finished-"
                "record keys and KV state are per-engine)")
        v0 = self.engines[0].cfg.vocab_size
        for s, e in enumerate(self.engines[1:], start=1):
            if e.cfg.vocab_size != v0:
                # the ORIGINAL prompt must be valid input to every stage
                # (family mismatch only disables prefix replay; vocab
                # mismatch makes the request itself unservable)
                raise ValueError(
                    f"stage {s} vocab_size {e.cfg.vocab_size} != stage 0 "
                    f"vocab_size {v0}: every stage must share the prompt "
                    "token space")
        self.router = EscalationRouter([e.cfg for e in self.engines])
        self.controller = controller
        self.auto_rebalance = bool(auto_rebalance)
        self.donate_quantum = int(donate_quantum)
        self._tracked: Dict[int, _TierRequest] = {}
        self.finished: Dict[int, dict] = {}
        self._order = 0
        self._tick = 0
        self._escalations_total = 0
        self._discarded_draft_tokens = 0
        self._blocks_donated = 0
        if controller is not None:
            controller.attach(self)

    # -- public API ------------------------------------------------------
    def submit(self, req: Request):
        if req.rid in self._tracked or req.rid in self.finished:
            raise ValueError(f"duplicate rid {req.rid}")
        self._tracked[req.rid] = _TierRequest(request=req,
                                              order=self._order)
        self._order += 1
        self.engines[0].submit(req)

    # -- fleet member surface --------------------------------------------
    # A tier can be a FleetScheduler member next to plain engines: the
    # fleet talks to a tier through its ENTRY stage (stage 0) — that is
    # where fresh traffic lands, queues, and is admission-gated.  Deeper
    # stages are internal to the tier (escalated requests carry committed
    # prefixes the fleet must not requeue), so live/queued accounting
    # deliberately counts anything past the stage-0 queue as live.
    # Tiers have no fleet `cancel`, so a drain degrades to "finish" mode.
    @property
    def cfg(self):
        """The ENTRY stage's config — what fleet placement and the
        aggregator's config_key check see."""
        return self.engines[0].cfg

    @property
    def admitting(self) -> bool:
        return self.engines[0].admitting

    @admitting.setter
    def admitting(self, value: bool) -> None:
        self.engines[0].admitting = bool(value)

    def free_slot_count(self) -> int:
        return self.engines[0].free_slot_count()

    def queued_count(self) -> int:
        return self.engines[0].queued_count()

    def live_rids(self) -> List[int]:
        """Tracked rids past the entry queue — decoding on some stage, or
        escalated (committed prefix held; never fleet-requeueable)."""
        queued = {r.rid for r in self.engines[0].queue}
        return [rid for rid in self._tracked if rid not in queued]

    def take_queue(self) -> List[Request]:
        """Fleet drain hook: remove and return the ENTRY queue's fresh
        requests (nothing decoded yet) and untrack them, so a scheduler
        can requeue them to a sibling member.  Escalated requests never
        sit in the stage-0 queue (escalation only moves forward), so
        everything returned is an original submission."""
        taken = self.engines[0].take_queue()
        for req in taken:
            self._tracked.pop(req.rid, None)
        return taken

    def lane_telemetry(self) -> List:
        """The ENTRY stage's lane telemetry.  Deliberately stage 0 only:
        deeper stages run different cascades (different mac_prefix /
        possibly route_final axes), so their telemetry does not merge
        into a homogeneous fleet histogram — cross-stage solving is the
        TierThresholdController's composed-histogram job, not the fleet
        aggregator's."""
        return self.engines[0].lane_telemetry()

    def current_thresholds(self):
        return self.engines[0].current_thresholds()

    def push_thresholds(self, thresholds) -> None:
        """Fleet-pushed thresholds land on the ENTRY stage (the cascade
        the fleet's merged histogram describes)."""
        self.engines[0].push_thresholds(thresholds)

    def set_escalation_threshold(self, stage: int, threshold: float):
        """Live escalation-threshold swap — plain data, like the engines'
        ``push_thresholds``; the next drain pass uses it."""
        self.router.set_threshold(stage, threshold)

    def step(self):
        """One tier tick: each stage steps, then its deferrals drain into
        the next stage (in original submission order, so escalated
        workloads replay FIFO — the bit-identity the parity corners
        pin)."""
        self._tick += 1
        for s in range(len(self.engines)):
            self.engines[s].step()
            self._drain(s)
        if self.controller is not None:
            self.controller.maybe_update(self)
        if self.auto_rebalance:
            self._rebalance()

    def run(self, max_ticks: int = 1000) -> Dict[int, dict]:
        for _ in range(max_ticks):
            if not self._tracked:
                break
            self.step()
        return self.finished

    # -- drain: defer / finalize ----------------------------------------
    def _streams(self, eng: CascadeServingEngine, rid: int):
        """A tracked request's live streams in ``eng``: (tokens, depths,
        confs, live) — or None while it still queues."""
        rec = eng.finished.get(rid)
        if rec is not None:
            return rec["tokens"], rec["exit_depths"], rec["confs"], False
        for lane in eng.lanes:
            for s in lane["slots"]:
                if not s.done and s.request is not None \
                        and s.request.rid == rid:
                    return s.generated, s.exit_depths, s.confs, True
        return None

    def _drain(self, stage: int):
        eng = self.engines[stage]
        deferrals: List[_TierRequest] = []
        for tr in list(self._tracked.values()):
            if tr.stage != stage:
                continue
            got = self._streams(eng, tr.request.rid)
            if got is None:
                continue                       # still queued
            tokens, depths, confs, live = got
            if tr.pending_regen is not None and len(tokens) > tr.cursor:
                # first regenerated token at the SAME context the draft
                # was rejected at — the stage-agree observation
                self.router.observe_regeneration(tr.pending_regen,
                                                 tokens[tr.cursor])
                tr.pending_regen = None
            d = self.router.first_defer(stage, depths, confs,
                                        start=tr.cursor)
            if d is None:
                tr.cursor = len(tokens)
                if not live:
                    self._finalize(tr, tokens, depths, confs, stage)
                continue
            if live:
                eng.cancel(tr.request.rid, keep=d)
            self._escalate(tr, tokens, depths, confs, d, stage)
            deferrals.append(tr)
        # restore FIFO before the next stage sees the deferred workload
        deferrals.sort(key=lambda tr: tr.order)
        for tr in deferrals:
            self.engines[tr.stage].submit(tr.request)

    def _escalate(self, tr: _TierRequest, tokens, depths, confs,
                  d: int, stage: int):
        """Commit ``tokens[:d]``, rebuild the request for stage+1."""
        if stage + 1 >= len(self.engines):
            raise AssertionError("last stage cannot defer")
        orig = tr.request if tr.escalations == 0 else None
        share = resolve_share_prefix(self.engines[stage].cfg,
                                     self.engines[stage + 1].cfg)
        rejected = int(tokens[d])
        if share:
            tr.committed.extend(int(t) for t in tokens[:d])
            tr.committed_depths.extend(int(x) for x in depths[:d])
            tr.committed_confs.extend(float(c) for c in confs[:d])
            tr.spans.append({"stage": stage, "n_tokens": d,
                             "kept": True})
        else:
            # the next stage restarts from the original prompt: every
            # earlier committed token (this stage's AND prior stages')
            # is draft output the tier discards from the final record
            self._discarded_draft_tokens += len(tr.committed) + d
            tr.committed.clear()
            tr.committed_depths.clear()
            tr.committed_confs.clear()
            tr.spans.append({"stage": stage, "n_tokens": d,
                             "kept": False})
        base = self._base_request(tr)
        prompt, max_new, replayed = build_replay(
            base.prompt, tr.committed, base.max_new_tokens, share)
        extra = dict(base.extra or {})
        extra["escalation"] = {"stage": stage + 1, "rid": base.rid,
                               "replayed": replayed}
        tr.request = Request(rid=base.rid, prompt=prompt,
                             max_new_tokens=max_new, extra=extra)
        tr.stage = stage + 1
        tr.cursor = 0
        tr.escalations += 1
        tr.pending_regen = rejected if share else None
        self._escalations_total += 1
        # flight recorder (repro.obs): the source engine's flight already
        # carries the terminal ("escalate" via cancel, or "exit" when the
        # defer fired after a natural finish); stamp the routing context
        # only the tier knows, and log the hop on the source event track
        flight = getattr(self.engines[stage], "flight", None)
        if flight is not None:
            flight.annotate(base.rid, {
                "escalated_to_stage": stage + 1, "deferred_at": d,
                "replayed": replayed, "committed": len(tr.committed)})
            flight.on_event("escalate", {
                "rid": base.rid, "from_stage": stage,
                "to_stage": stage + 1, "deferred_at": d,
                "replayed": replayed, "kept": share})
        del orig

    def _base_request(self, tr: _TierRequest) -> Request:
        """The ORIGINAL submission (prompt/budget before any replay)."""
        if tr.escalations == 0:
            return tr.request
        req = tr.request
        esc = (req.extra or {}).get("escalation", {})
        replayed = int(esc.get("replayed", 0))
        prompt = req.prompt[:len(req.prompt) - replayed] \
            if replayed else req.prompt
        extra = {k: v for k, v in (req.extra or {}).items()
                 if k != "escalation"}
        return Request(rid=req.rid, prompt=prompt,
                       max_new_tokens=req.max_new_tokens + replayed,
                       extra=extra or None)

    def _finalize(self, tr: _TierRequest, tokens, depths, confs,
                  stage: int):
        rid = tr.request.rid
        self.finished[rid] = {
            # committed prefixes + the answering stage's tokens; exit
            # depths and confidences stay STAGE-LOCAL (no global
            # component offsets — the parity corners compare these
            # streams bit-for-bit against a single engine's)
            "tokens": tr.committed + [int(t) for t in tokens],
            "exit_depths": tr.committed_depths + [int(x) for x in depths],
            "confs": tr.committed_confs + [float(c) for c in confs],
            "final_stage": stage,
            "escalations": tr.escalations,
            "spans": tr.spans + [{"stage": stage,
                                  "n_tokens": len(tokens),
                                  "kept": True}],
        }
        del self._tracked[rid]

    # -- cross-engine block donation -------------------------------------
    def _paged_pool(self, stage: int):
        eng = self.engines[stage]
        return eng.pcache.pool if getattr(eng, "paged", False) else None

    def _donation_compatible(self, a: int, b: int) -> bool:
        pa, pb = self._paged_pool(a), self._paged_pool(b)
        return (pa is not None and pb is not None
                and pa.block_bytes > 0 and pb.block_bytes > 0)

    def donate_blocks(self, src: int, dst: int, n: int) -> int:
        """Move ``n`` of stage ``src``'s soft-cap block units to stage
        ``dst``.  Physical stores never move (each engine owns its device
        buffers); what moves is ADMISSION headroom under a tier-level HBM
        budget — the donor stops admitting into the donated capacity, the
        recipient may use that much more of its own free list.  The trade
        is priced in BYTES: a draft-stage block and an authority-stage
        block cover different cache planes, so the recipient gains
        ``floor(n * src.block_bytes / dst.block_bytes)`` of ITS blocks
        (any remainder bytes stay unspent — the budget never inflates).
        Requires both pools paged with byte-priced blocks and soft caps
        already set; returns the recipient blocks actually granted,
        clamped so the donor's cap never drops below its current
        usage."""
        if src == dst:
            raise ValueError("src == dst")
        if not self._donation_compatible(src, dst):
            raise ValueError(
                f"stages {src} and {dst} cannot trade blocks: both must "
                "be paged with byte-priced blocks (block_bytes > 0)")
        ps, pd = self._paged_pool(src), self._paged_pool(dst)
        if ps.soft_cap is None or pd.soft_cap is None:
            raise ValueError(
                "block donation needs soft caps on both pools "
                "(set_soft_cap — a tier-level block budget); without "
                "caps each pool already admits to its physical limit")
        n = max(0, min(int(n), ps.soft_cap - ps.used))
        gained = (n * ps.block_bytes) // pd.block_bytes
        if n == 0 or gained == 0:
            return 0
        before = pd.soft_cap
        pd.set_soft_cap(pd.soft_cap + gained)
        granted = pd.soft_cap - before     # clamped at dst's physical
        # only charge the donor for what the recipient could bank
        charged = -(-(granted * pd.block_bytes) // ps.block_bytes)
        ps.set_soft_cap(ps.soft_cap - min(n, charged))
        self._blocks_donated += granted
        return granted

    def _rebalance(self):
        """One conservative auto-donation step: a stage that has queued
        work its capped pool cannot admit borrows ``donate_quantum``
        units from the compatible stage with the most idle cap slack."""
        for s, eng in enumerate(self.engines):
            pool = self._paged_pool(s)
            if (pool is None or pool.soft_cap is None
                    or not eng.queue or pool._cap_free() > 0):
                continue
            donors = [(self._paged_pool(d).soft_cap
                       - self._paged_pool(d).used, d)
                      for d in range(len(self.engines))
                      if d != s and self._donation_compatible(d, s)
                      and self._paged_pool(d).soft_cap is not None
                      and not self.engines[d].queue]
            donors = [x for x in donors if x[0] > 0]
            if not donors:
                continue
            slack, d = max(donors)
            self.donate_blocks(d, s, min(self.donate_quantum, slack))

    # -- observability (repro.obs) ----------------------------------------
    def dump_flight(self, rid: int):
        """Every stage's flight for ``rid`` (an escalated request shows
        one per stage it touched), or None when no stage knows it."""
        out = []
        for k, eng in enumerate(self.engines):
            dump = getattr(eng, "dump_flight", None)
            d = dump(rid) if dump is not None else None
            if d is not None:
                out.append({"stage": k, **d})
        return out or None

    # -- metrics ---------------------------------------------------------
    def stats(self) -> dict:
        final_stage = np.bincount(
            [r["final_stage"] for r in self.finished.values()],
            minlength=len(self.engines)).tolist() if self.finished else \
            [0] * len(self.engines)
        return {
            "requests_finished": len(self.finished),
            "requests_live": len(self._tracked),
            "escalations_total": self._escalations_total,
            "final_stage_histogram": final_stage,
            "discarded_draft_tokens": self._discarded_draft_tokens,
            "blocks_donated": self._blocks_donated,
            "router": self.router.stats(),
            "controller": (self.controller.stats()
                           if self.controller is not None else None),
            "stages": [e.stats() for e in self.engines],
        }


class TierThresholdController:
    """Heterogeneous-cost threshold autotuning for a 2-stage tier.

    Periodically merges both engines' live telemetry, composes the joint
    tier histogram (:func:`repro.autotune.solver.compose_escalation`),
    runs the unchanged ε / budget solver over it with the composed
    per-(stage, component) MAC prefix, and pushes the split thresholds
    back as data — intra-model vectors via each engine's
    ``push_thresholds``, the escalation threshold via the tier router.

    Stage 0's engine must be built with ``autotune.route_final=True``
    (its final-component confidence is the escalation routing axis);
    stage 1 with ordinary autotune telemetry.  ``stage_agree`` is read
    from the router's online regeneration scoring once
    ``min_escalations`` rejections have been scored, ``stage_agree_prior``
    before that.
    """

    def __init__(self, epsilon: Optional[float] = None,
                 mac_budget: Optional[float] = None,
                 interval: int = 64, min_shadow: float = 64.0,
                 min_escalations: int = 8,
                 stage_agree_prior: float = 1.0,
                 replay_overhead: float = 0.0):
        if (epsilon is None) == (mac_budget is None):
            raise ValueError("pass exactly one of epsilon= / mac_budget=")
        if interval < 1:
            raise ValueError(f"interval must be >= 1, got {interval}")
        self.epsilon = epsilon
        self.mac_budget = mac_budget
        self.interval = int(interval)
        self.min_shadow = float(min_shadow)
        self.min_escalations = int(min_escalations)
        self.stage_agree_prior = float(stage_agree_prior)
        self.replay_overhead = float(replay_overhead)
        self.solves = 0
        self.skipped_starved = 0
        self.last_result = None
        self.last_thresholds = None
        self.last_stage_agree = None

    def attach(self, tier: ModelCascadeTier):
        if len(tier.engines) != 2:
            raise ValueError(
                f"TierThresholdController solves 2-stage tiers, got "
                f"{len(tier.engines)} stages (chain pairs for deeper "
                "pools)")
        for s, eng in enumerate(tier.engines):
            if not eng.cfg.autotune.enabled:
                raise ValueError(
                    f"stage {s} engine lacks autotune telemetry "
                    "(cfg.with_autotune(enabled=True))")
        if not tier.engines[0].cfg.autotune.route_final:
            raise ValueError(
                "stage 0 must be built with autotune.route_final=True — "
                "the escalation threshold is solved over its final-"
                "component confidence axis")

    def maybe_update(self, tier: ModelCascadeTier):
        if tier._tick % self.interval:
            return
        self.update(tier)

    def update(self, tier: ModelCascadeTier) -> bool:
        """One solve attempt; False when telemetry is still starved."""
        from repro.autotune.solver import (ExitHistogram,
                                           compose_escalation,
                                           compose_mac_prefix,
                                           solve_budget, solve_epsilon,
                                           split_tier_thresholds)
        from repro.autotune.telemetry import merge_telemetry
        eng0, eng1 = tier.engines
        tels0, tels1 = eng0.lane_telemetry(), eng1.lane_telemetry()
        if not tels0 or not tels1:
            self.skipped_starved += 1
            return False
        tel0, tel1 = merge_telemetry(tels0), merge_telemetry(tels1)
        if (float(tel0["shadow_steps"]) < self.min_shadow
                or float(tel1["shadow_steps"]) < self.min_shadow):
            self.skipped_starved += 1
            return False
        # the route-final extra entry prices deferring PAST stage 0's
        # final component at stage-0 cost; the composed prefix then
        # re-prices every cell with the true heterogeneous tier costs
        p0 = [float(x) for x in eng0.mac_prefix]
        p1 = [float(x) for x in eng1.mac_prefix]
        h0 = ExitHistogram.from_telemetry(tel0,
                                          mac_prefix=p0 + [p0[-1]])
        h1 = ExitHistogram.from_telemetry(tel1, mac_prefix=p1)
        agree = tier.router.stage_agree(prior=self.stage_agree_prior,
                                        min_observations=self.min_escalations)
        joint = compose_escalation(
            h0, h1, stage_agree=agree,
            mac_prefix=compose_mac_prefix(
                [p0, p1], [self.replay_overhead]))
        if self.epsilon is not None:
            res = solve_epsilon(joint, self.epsilon)
        else:
            res = solve_budget(joint, self.mac_budget)
        n0 = eng0.cfg.cascade.n_components
        ths0, esc, ths1 = split_tier_thresholds(res.thresholds, n0)
        eng0.push_thresholds(ths0)
        eng1.push_thresholds(ths1)
        tier.set_escalation_threshold(0, esc)
        self.solves += 1
        self.last_result = res
        self.last_thresholds = (ths0, esc, ths1)
        self.last_stage_agree = agree
        log.info("tier solve #%d: esc=%.3f stage0=%s stage1=%s "
                 "(stage_agree=%.3f)", self.solves, esc, ths0, ths1, agree)
        return True

    def stats(self) -> dict:
        return {
            "solves": self.solves,
            "skipped_starved": self.skipped_starved,
            "interval": self.interval,
            "epsilon": self.epsilon,
            "mac_budget": self.mac_budget,
            "stage_agree": self.last_stage_agree,
            "thresholds": (
                {"stage0": list(self.last_thresholds[0]),
                 "escalation": float(self.last_thresholds[1]),
                 "stage1": list(self.last_thresholds[2])}
                if self.last_thresholds is not None else None),
            "predicted": (
                {"avg_macs": self.last_result.avg_macs,
                 "agreement": self.last_result.agreement}
                if self.last_result is not None else None),
        }
