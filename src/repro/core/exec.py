"""Staged cascade execution: the :class:`DecodeState` pytree and the
segment-skipping executor that makes early exit mean early *termination*.

The paper's claim is that inference stops as soon as the softmax confidence
clears the calibrated threshold — yet a batched TPU decode graph has a fixed
shape, so the seed implementation computed every segment and merely *selected*
the exit, leaving the measured speedup analytic (MACs), not wall-clock.  This
module closes that gap the way IDK Cascades (Wang et al., 2017) and Learning
to Cascade (Enomoto & Eda, 2021) frame it: the exit decision is part of the
execution program, not a post-hoc filter.

Two pieces:

* :class:`DecodeState` — the explicit, jit/shard-friendly pytree carried
  across decode steps: the cache-write cursor ``t``, the per-sequence
  ``active`` mask, the stateful-measure carry (patience streaks), an EMA of
  the answering confidence (per-slot difficulty telemetry, surfaced through
  the serving engine's stats), and per-segment execution counters.

* :class:`StagedExecutor` — runs the cascade one segment at a time, feeding
  each segment's logits to the shared :class:`~repro.core.policy.ExitDecider`
  component scan.  Under ``cascade.exit_mode == "cond_batch"`` every segment
  after the first sits under ``lax.cond``: once all live sequences have
  exited, deeper segments take only the cheap ``backfill`` path (cache
  coherence writes), skipping their matmuls entirely.  Under ``"select"``
  the graph stays fixed (the dry-run / roofline shape) but applies the SAME
  masked state updates, so the two modes produce bit-identical tokens, exit
  indices, and carried state — ``exit_mode`` chooses an execution strategy,
  never a semantics.

This replaces the old fixed ``(params, token, t, cache, extra)`` serve-step
signature: launch steps and the serving engine now thread
``(params, token, cache, state, extra)`` with ``state: DecodeState`` (see
``launch/steps.py`` for the migration shim-free builders and
``launch/shard_rules.decode_state_spec`` for its sharding).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.policy import ExitDecider, ExitDecision

# EMA decay for the per-slot answering-confidence telemetry carried in
# DecodeState (same decay as DepthCompactor's host-side depth prior).
CONF_EMA_DECAY = 0.8


def effective_cohorts(n_cohorts: int, batch: int) -> int:
    """Largest divisor of ``batch`` that is <= ``n_cohorts`` (>= 1).

    Cohort slices must be equal-size static ranges, so an indivisible batch
    degrades gracefully instead of erroring — the same policy the sharding
    rules apply to indivisible axes.
    """
    c = max(1, min(int(n_cohorts), int(batch)))
    while batch % c:
        c -= 1
    return c


def _slice_ctx(ctx, lo, hi):
    """Batch-slice a decode context: only ``cross`` (B, T, d) carries a
    batch dim; everything else (kpos ring, scalars, shared params) is
    batch-free and passes through."""
    cross = ctx.get("cross")
    if cross is None:
        return ctx
    return {**ctx, "cross": cross[lo:hi]}


@dataclasses.dataclass
class DecodeState:
    """Per-lane decode carry (a registered pytree).

    t             () int32   — decode position == cache-write cursor.
    active        (B,) bool  — sequences still generating; finished slots
                               neither block segment skipping nor update EMAs.
    policy        stateful-measure carry (e.g. patience streaks,
                               (n_components, B) int32) or None.
    ema_conf      (B,) f32   — EMA of the answering confidence per lane
                               slot (difficulty telemetry; the engine
                               reports it per lane in ``stats()``).
    segments_run  (n_components,) int32 — how many decode steps actually
                               computed each segment (physical compute: in
                               ``select`` mode every segment counts every
                               step; in ``cond_batch`` skipped segments
                               don't).  The real-skip evidence.
    """

    t: jnp.ndarray
    active: jnp.ndarray
    policy: Optional[jnp.ndarray]
    ema_conf: jnp.ndarray
    segments_run: jnp.ndarray

    def replace(self, **kw) -> "DecodeState":
        return dataclasses.replace(self, **kw)


jax.tree_util.register_dataclass(
    DecodeState,
    data_fields=("t", "active", "policy", "ema_conf", "segments_run"),
    meta_fields=())


def init_decode_state(decider: ExitDecider, batch: int, n_components: int,
                      t: int = 0, active=None) -> DecodeState:
    """Fresh decode carry for a lane of ``batch`` sequences."""
    return DecodeState(
        t=jnp.asarray(t, jnp.int32),
        active=(jnp.ones((batch,), bool) if active is None
                else jnp.asarray(active, bool)),
        policy=decider.measure.init_state(n_components, batch),
        ema_conf=jnp.zeros((batch,), jnp.float32),
        segments_run=jnp.zeros((n_components,), jnp.int32))


class StagedExecutor:
    """Segment-at-a-time cascade decode under one :class:`ExitDecider`.

    ``decode_step`` is THE decode program; ``cfg.cascade.exit_mode`` only
    picks how it is realized:

    * ``"select"`` — fixed graph: every segment computes, the skip
      predicate selects between the full result and the backfill result.
      Lowered by the dry-run (roofline shape).
    * ``"cond_batch"`` — ``lax.cond`` per segment: when every live sequence
      has exited, the deep segment's matmuls do not execute; only the cheap
      cache backfill runs.  Wall-clock savings, identical outputs.

    Works for every registered measure/policy whose decision reduces to
    per-component gates over static thresholds — including stateful
    patience@k (streaks ride in ``DecodeState.policy``) and a *fitted*
    BudgetPolicy (its thresholds resolve to static floats at trace time).
    """

    def __init__(self, model, cfg=None, decider: Optional[ExitDecider] = None):
        self.model = model
        self.cfg = cfg or model.cfg
        self.decider = decider or ExitDecider.from_config(self.cfg)
        self.mode = self.cfg.cascade.exit_mode
        self.n_components = self.cfg.cascade.n_components

    # ------------------------------------------------------------------
    def init_state(self, batch: int, t: int = 0, active=None) -> DecodeState:
        return init_decode_state(self.decider, batch, self.n_components,
                                 t=t, active=active)

    def _carry_forward(self, state: DecodeState,
                       decision: ExitDecision) -> DecodeState:
        conf = decision.confidence.astype(jnp.float32)
        ema = jnp.where(state.active,
                        CONF_EMA_DECAY * state.ema_conf
                        + (1.0 - CONF_EMA_DECAY) * conf,
                        state.ema_conf)
        return state.replace(policy=decision.state, ema_conf=ema)

    # ------------------------------------------------------------------
    def prefill(self, params, tokens, cache, extra=None,
                state: Optional[DecodeState] = None):
        """Full-sequence prefill; returns (decision, cache, state) with the
        prefill decision seeding the stateful-measure carry (it counts as
        the streak's first step) and ``t`` set past the prompt."""
        if state is None:
            state = self.init_state(tokens.shape[0])
        logits, cache = self.model.prefill(params, tokens, cache, extra)
        decision = self.decider.decide(logits, state=state.policy,
                                       active=state.active)
        state = self._carry_forward(state, decision).replace(
            t=jnp.asarray(tokens.shape[1], jnp.int32))
        return decision, cache, state

    # ------------------------------------------------------------------
    def decode_step(self, params, token, cache, state: DecodeState,
                    extra=None):
        """One staged decode step.  token: (B, 1) int32.

        Returns (decision, new_cache, new_state).  Segment 0 always runs;
        each deeper segment runs only while some live sequence has not
        exited (cond_batch) or computes-but-masks (select).

        ``cfg.cascade.n_cohorts > 1`` splits the batch into C contiguous
        equal-size cohorts, each with its OWN skip predicate: a deep
        segment's compute is skipped for a cohort as soon as every live
        sequence in THAT cohort has exited, even while another cohort still
        needs it (nested ``lax.cond`` per cohort).  The serving engine
        places similar-depth requests into the same cohort so this converts
        more of the measured skip opportunity into realized skips.
        ``segments_run`` counts in cohort units: segment ``si`` advances by
        the number of cohorts that actually computed it (C per step when
        nothing skips; C == 1 reproduces the whole-batch predicate exactly).
        """
        model, decider, n_m = self.model, self.decider, self.n_components
        ths = decider.resolved_thresholds(n_m)
        t = state.t
        B = token.shape[0]
        C = effective_cohorts(self.cfg.cascade.n_cohorts, B)
        Bc = B // C
        h, ctx = model.begin_decode(params, token, t, cache, extra)
        segs = cache["segments"]
        new_segs = []
        ran = [jnp.asarray(C, jnp.int32)]

        h, nc, _ = model.run_segment(0, params, h, ctx, segs[0])
        new_segs.append(nc)
        out, conf = decider.measure_one(
            model.exit_logits(params, 0, h)[:, 0, :])
        sc = decider.scan_component(0, n_m, out, conf, ths,
                                    state=state.policy)

        for si in range(1, n_m):
            h_parts, nc_parts, sc_parts = [], [], []
            ran_si = jnp.zeros((), jnp.int32)
            for c in range(C):
                lo, hi = c * Bc, (c + 1) * Bc
                if C == 1:
                    h_c, seg_c, sc_c, ctx_c = h, segs[si], sc, ctx
                    active_c = state.active
                else:
                    h_c = h[lo:hi]
                    seg_c = jax.tree_util.tree_map(
                        lambda x: x[:, lo:hi], segs[si])
                    sc_c = decider.slice_carry(sc, lo, hi)
                    ctx_c = _slice_ctx(ctx, lo, hi)
                    active_c = state.active[lo:hi]
                skip = decider.should_skip(sc_c, active_c)

                def run_path(h, seg_cache, sc, _si=si, _ctx=ctx_c):
                    h2, nc2, _ = model.run_segment(_si, params, h, _ctx,
                                                   seg_cache)
                    o, c = decider.measure_one(
                        model.exit_logits(params, _si, h2)[:, 0, :])
                    return h2, nc2, decider.scan_component(_si, n_m, o, c,
                                                           ths, sc)

                def skip_path(h, seg_cache, sc, _si=si, _ctx=ctx_c):
                    if self.cfg.cascade.state_backfill:
                        seg_cache = model.backfill_segment(_si, params, h,
                                                           _ctx, seg_cache)
                    return h, seg_cache, sc

                if self.mode == "cond_batch":
                    h_c, nc_c, sc_c = lax.cond(skip, skip_path, run_path,
                                               h_c, seg_c, sc_c)
                    ran_si = ran_si + jnp.logical_not(skip).astype(jnp.int32)
                else:  # select: both paths compute; skip only masks results
                    full = run_path(h_c, seg_c, sc_c)
                    lite = skip_path(h_c, seg_c, sc_c)
                    h_c, nc_c, sc_c = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(skip, a, b), lite, full)
                    ran_si = ran_si + 1
                h_parts.append(h_c)
                nc_parts.append(nc_c)
                sc_parts.append(sc_c)
            if C == 1:
                h, nc, sc = h_parts[0], nc_parts[0], sc_parts[0]
            else:
                h = jnp.concatenate(h_parts, axis=0)
                nc = jax.tree_util.tree_map(
                    lambda *xs: jnp.concatenate(xs, axis=1), *nc_parts)
                sc = decider.concat_carry(sc_parts)
            ran.append(ran_si)
            new_segs.append(nc)

        decision = decider.finish_scan(sc)
        cache = model.commit_decode(cache, new_segs, t)
        state = self._carry_forward(state, decision).replace(
            t=t + 1, segments_run=state.segments_run + jnp.stack(ran))
        return decision, cache, state
