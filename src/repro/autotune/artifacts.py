"""Config-hash-keyed calibration artifacts: persist a resolved threshold
vector (plus the evidence behind it) so a serving fleet warm-starts from
the last calibration instead of re-learning thresholds from cold
telemetry.

An artifact is one JSON file named by the config key — a stable hash over
exactly the fields that make a calibration transferable (architecture
identity, cascade structure, confidence measure, histogram resolution).
Two configs with the same key may exchange thresholds; anything else
(different exit boundaries, different measure, different bin grid) may
not, and :func:`load_artifact` refuses rather than silently mis-warming.

Writes are atomic (write-to-temp + rename), mirroring
``repro.ckpt.checkpoint``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Optional, Sequence, Tuple

ARTIFACT_VERSION = 1


def config_key(cfg) -> str:
    """Stable identity of a calibration: sha256 over the fields a threshold
    vector depends on.  Deliberately excludes serving-shape knobs (lane
    batch, chunk, runtime) — thresholds transfer across those."""
    ident = {
        "version": ARTIFACT_VERSION,
        "name": cfg.name,
        "n_layers": cfg.n_layers,
        "vocab_size": cfg.vocab_size,
        "segments": [list(s) for s in cfg.segments],
        "n_components": cfg.cascade.n_components,
        "confidence": cfg.cascade.confidence,
        "bins": cfg.autotune.bins,
    }
    blob = json.dumps(ident, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()


@dataclasses.dataclass
class CalibrationArtifact:
    """One persisted calibration: the resolved thresholds plus enough
    provenance to audit (and re-seed) them."""

    config_key: str
    thresholds: Tuple[float, ...]
    direction: str                    # "epsilon" | "macs"
    target: float                     # the ε or the MAC budget
    bins: int
    mac_prefix: Tuple[float, ...]
    agreement: float                  # solver's expected agreement
    avg_macs: float                   # solver's expected avg MACs/sample
    shadow_steps: float               # evidence size behind the solve
    edges: Tuple[int, ...] = ()
    # provenance: "engine" = one engine's controller solved this;
    # "fleet" = a TelemetryAggregator solved it on merged fleet telemetry
    # (larger evidence window per wall-clock second — preferred seed for
    # fresh engines).  Absent in pre-fleet artifact files → "engine".
    source: str = "engine"

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["version"] = ARTIFACT_VERSION
        return d

    @classmethod
    def from_json(cls, d: dict) -> "CalibrationArtifact":
        d = dict(d)
        ver = d.pop("version", ARTIFACT_VERSION)
        if ver != ARTIFACT_VERSION:
            raise ValueError(f"artifact version {ver} != {ARTIFACT_VERSION}")
        d["thresholds"] = tuple(float(t) for t in d["thresholds"])
        d["mac_prefix"] = tuple(float(m) for m in d["mac_prefix"])
        d["edges"] = tuple(int(e) for e in d.get("edges", ()))
        return cls(**d)


def artifact_path(artifact_dir: str, key: str) -> str:
    return os.path.join(artifact_dir, f"autotune_{key[:16]}.json")


def save_artifact(artifact_dir: str, artifact: CalibrationArtifact) -> str:
    """Atomically persist; returns the written path."""
    os.makedirs(artifact_dir, exist_ok=True)
    path = artifact_path(artifact_dir, artifact.config_key)
    fd, tmp = tempfile.mkstemp(dir=artifact_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(artifact.to_json(), f, indent=2)
            f.write("\n")
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def load_artifact(artifact_dir: str, cfg) -> Optional[CalibrationArtifact]:
    """The artifact matching this config's key, or None.  A key mismatch
    inside the file (hand-copied artifact) raises rather than mis-warms."""
    key = config_key(cfg)
    path = artifact_path(artifact_dir, key)
    if not os.path.exists(path):
        return None
    with open(path) as f:
        art = CalibrationArtifact.from_json(json.load(f))
    if art.config_key != key:
        raise ValueError(
            f"artifact {path} was calibrated for config key "
            f"{art.config_key[:16]}..., not this config's {key[:16]}...")
    if len(art.thresholds) != cfg.cascade.n_components:
        raise ValueError(
            f"artifact {path} has {len(art.thresholds)} thresholds for "
            f"{cfg.cascade.n_components} components")
    return art
