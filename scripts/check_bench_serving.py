"""CI gate for the serving hot-path ablation in ``BENCH_serving.json``.

Validates EVERY row of the threshold sweep (written by
``benchmarks/run.py`` whenever the llm_cascade bench runs):

* the sweep covers at least 3 thresholds and every row carries all four
  wall-clock measurements (host / device-major / device-copy / kernels-off);
* ``streams_identical`` on every row — the cohort-major layout must decode
  bit-identical token streams to the copy layout;
* the cohort-major layout is no slower than the slice+concat copy path at
  every threshold (small noise tolerance) and STRICTLY faster at
  threshold 0.0, where cohort skipping makes the copy path's per-segment
  cache concat pure overhead;
* the device while_loop runtime is strictly faster than the host per-token
  runtime at threshold 0.0 (the dispatch-amortization criterion);
* every row carries kernel execution-backend provenance
  (``kernel_backend`` interpret|compiled + ``kernel_platform``), and the
  ``kernel_speedup`` column is gated by it: rows measured through the
  Pallas interpreter are ADVISORY (printed and labeled — interpreter
  timings say nothing about Mosaic-compiled performance), rows measured
  compiled must show kernel_speedup STRICTLY > 1.0;
* the paged KV layout on every row: ``paged_streams_identical`` (the
  layout is an addressing scheme, not a semantics), peak cache bytes
  STRICTLY below the dense slab at every threshold, and the equal-memory
  admission wait (deterministic ticks submit->admit) STRICTLY better than
  dense at threshold 0.02 (the mixed-exit operating point) and no worse
  elsewhere with the same 0.90 noise headroom the layout gate uses —
  though both admission numbers are tick counts, so in practice they
  either win or tie exactly.

When the summary carries a ``kernels`` section (written whenever
``benchmarks/bench_kernels.py`` runs), it is validated too:

* every sweep row shows ``tuned_speedup >= 1.0`` — the default tiles are
  themselves a candidate and both timings come from the same sweep, so a
  tuned config losing to the default means the sweep or the tile registry
  is broken, not that the machine was noisy;
* every row carries backend/platform provenance, and the covered kernel
  set includes the serving hot path (decode attention, exit update, the
  per-segment megakernel).

When the summary carries an ``autotune`` section (written whenever
``benchmarks/bench_autotune.py`` runs), it is validated too:

* >= 3 swept budgets, each with the coordinate-descent solver STRICTLY
  more accurate than the shared-quantile fit at <= its average MACs
  (small quantization slack) — the per-component-dominates-shared gate;
* telemetry overhead within 3% tokens/s of the telemetry-off engine,
  with ZERO additional host syncs per decode chunk (counted, not
  assumed) and bit-identical token streams.

When the summary carries an ``escalation`` section (written whenever the
llm_cascade bench runs), the cross-model tier is validated too:

* both parity corners bit-identical — the tier at escalation=0.0 streams
  exactly the draft engine's tokens, and at the 1.1 always-defer sentinel
  exactly the target engine's (deterministic, no noise tolerance);
* the matched-accuracy solve (ε=0 on the labeled population priced with
  the real composed MAC prefixes) is feasible, spends STRICTLY fewer
  average MACs than always running the target, and gives up no accuracy
  doing it.

When the summary carries an ``obs`` section (written whenever
``benchmarks/bench_obs.py`` runs), the flight recorder is validated too:

* recorder overhead within 3% tokens/s of the recorder-off engine, zero
  added host syncs per decode chunk, bit-identical token streams;
* the fleet trace export passes the Chrome trace-event schema check with
  the drain instant present, and a migrated request's flight spans both
  members.

The summary also carries ``schema_version`` + run ``meta`` (jax version,
backend); an unknown version prints a warning and gates only the
sections this checker recognizes — never a KeyError.

Exit code 1 on violation so CI can retry once — the strict margins are
real but finite (~5–10%), and a shared runner's scheduler noise can eat
them in a single unlucky run.  (The escalation gates are deterministic
tick/count/histogram quantities; if they fail, the retry will fail too —
that is a real regression, not noise.)

    python scripts/check_bench_serving.py [path]
"""
import json
import sys

# Threshold 0.0 is gated strictly: every step takes the all-skip fast path
# there, which is where the cohort-major layout structurally beats the
# per-segment slice+concat (measured 1.05-1.30x).  At mixed-exit operating
# points the dispatch falls back to per-cohort conds and the two layouts
# are STRUCTURAL PARITY (repeated interleaved A/B: 0.98-1.01x), so those
# rows gate "no slower" with headroom for the ±6-8% wave-level timing
# noise a shared runner shows even with interleaved measurement.
LAYOUT_NOISE_TOL = 0.90
MIN_THRESHOLDS = 3
MIN_BUDGETS = 3
# the acceptance bar: telemetry accumulation may cost at most 3% tokens/s
TELEMETRY_RATIO_MIN = 0.97
# same bar for the flight recorder (repro.obs): recording at the existing
# host-sync boundaries may cost at most 3% tokens/s, with streams
# bit-identical and zero added host syncs per chunk
OBS_RATIO_MIN = 0.97
# summary schema versions this checker knows how to gate; an UNKNOWN (or
# newer) version warns instead of failing — sections it still recognizes
# are gated, sections it does not are someone else's job
KNOWN_SCHEMA_VERSIONS = (1, 2)
# fleet gates: a 4-engine fleet must reach its first merged-solve push on
# <= 1/3 the per-member shadow evidence a lone engine needs
MIN_FLEET_ENGINES = 4
WARMUP_RATIO_MAX = 1.0 / 3.0
# realized-MAC slack for the equal-budget comparison: the solver fits on
# a BINS-bin histogram and is evaluated on raw samples, so its realized
# spend can quantize a hair past the shared fit's
MAC_SLACK = 1.02
# kernels the microbench sweep must cover — the serving hot path
KERNEL_MUST_COVER = {"decode_attention", "exit_update", "megakernel"}


def check_kernels(kern) -> bool:
    """Per-kernel sweep gates (written by ``benchmarks/bench_kernels.py``):
    tuned tiles must never lose to the defaults (>= 1.0x by construction —
    a violation is a sweep/registry bug, not noise), every row must say
    which backend measured it, and the sweep must cover the serving hot
    path kernels."""
    ok = True
    rows = kern.get("rows") or []
    if not rows:
        print("kernels: summary present but carries no sweep rows",
              file=sys.stderr)
        return False
    covered = set()
    for r in rows:
        tag = f"kernels {r.get('kernel')}/{r.get('shape')}"
        covered.add(r.get("kernel"))
        if not r.get("backend") or not r.get("platform"):
            print(f"{tag}: missing backend/platform provenance",
                  file=sys.stderr)
            ok = False
        speedup = float(r.get("tuned_speedup") or 0.0)
        if speedup < 1.0:
            print(f"{tag}: tuned tiles LOST to the defaults "
                  f"({speedup:.4f}x) — the default is a candidate in the "
                  f"same sweep, so this is a tuner bug", file=sys.stderr)
            ok = False
    missing = KERNEL_MUST_COVER - covered
    if missing:
        print(f"kernels: sweep missing hot-path kernel(s) "
              f"{sorted(missing)}", file=sys.stderr)
        ok = False
    print(f"kernels sweep [{kern.get('backend')}/{kern.get('platform')}] "
          "tuned_speedup:",
          [(f"{r.get('kernel')}", round(float(r.get('tuned_speedup') or 0),
                                        3)) for r in rows])
    return ok


def check_autotune(auto) -> bool:
    ok = True
    budgets = auto.get("budgets") or []
    if len(budgets) < MIN_BUDGETS:
        print(f"autotune: only {len(budgets)} budgets; sweep must cover "
              f">= {MIN_BUDGETS}", file=sys.stderr)
        ok = False
    for b in budgets:
        tag = f"autotune budget={b.get('budget')}"
        # missing keys fail the gate with a printable value, not a
        # TypeError mid-report
        solver_acc = float(b.get("solver_acc") or 0.0)
        shared_acc = float(b.get("shared_acc") or 1.0)
        solver_macs = float(b.get("solver_macs") or 1e30)
        shared_macs = float(b.get("shared_macs") or 0.0)
        if not solver_acc > shared_acc:
            print(f"{tag}: solver not strictly more accurate than the "
                  f"shared quantile: {solver_acc:.4f} vs "
                  f"{shared_acc:.4f}", file=sys.stderr)
            ok = False
        if solver_macs > shared_macs * MAC_SLACK:
            print(f"{tag}: solver spends more MACs than the shared fit: "
                  f"{solver_macs:.4f} vs {shared_macs:.4f}",
                  file=sys.stderr)
            ok = False
    tel = auto.get("telemetry") or {}
    ratio = tel.get("tokens_per_s_ratio", 0.0)
    if ratio < TELEMETRY_RATIO_MIN:
        print(f"autotune: telemetry overhead beyond 3%: tokens/s ratio "
              f"{ratio:.3f} < {TELEMETRY_RATIO_MIN}", file=sys.stderr)
        ok = False
    if tel.get("extra_host_syncs_per_chunk_on", 1) != 0:
        print(f"autotune: telemetry added host syncs per chunk: "
              f"{tel.get('extra_host_syncs_per_chunk_on')}",
              file=sys.stderr)
        ok = False
    if not tel.get("streams_identical"):
        print("autotune: telemetry-on token streams diverged from "
              "telemetry-off", file=sys.stderr)
        ok = False
    if not tel.get("mixed_exits"):
        print("autotune: overhead bench ran at a non-mixed exit point — "
              "the streams_identical gate is vacuous there (exit_counts "
              f"{tel.get('exit_counts')})", file=sys.stderr)
        ok = False
    print("autotune solver_acc - shared_acc:",
          [round(b.get("solver_acc", 0) - b.get("shared_acc", 0), 4)
           for b in budgets])
    print(f"autotune telemetry ratio: {ratio:.3f} "
          f"(extra syncs {tel.get('extra_host_syncs_per_chunk_on')})")
    return ok


def check_escalation(esc) -> bool:
    ok = True
    if not esc.get("never_streams_identical"):
        print("escalation: tier at threshold 0.0 diverged from the draft "
              "engine's streams", file=sys.stderr)
        ok = False
    if not esc.get("always_streams_identical"):
        print("escalation: tier at the 1.1 sentinel diverged from the "
              "target engine's streams", file=sys.stderr)
        ok = False
    if not esc.get("feasible"):
        print("escalation: ε=0 solve infeasible — never-exit is always a "
              "feasible corner, so the histogram is malformed",
              file=sys.stderr)
        ok = False
    tier_macs = float(esc.get("tier_avg_macs") or 1e30)
    tier_acc = float(esc.get("tier_accuracy") or 0.0)
    big_macs = float(esc.get("big_avg_macs") or 0.0)
    big_acc = float(esc.get("big_accuracy") or 1.0)
    if not tier_macs < big_macs:
        print(f"escalation: tier not strictly cheaper than target-only: "
              f"{tier_macs:.4f} vs {big_macs:.4f} avg MACs",
              file=sys.stderr)
        ok = False
    if tier_acc < big_acc - 1e-9:
        print(f"escalation: tier gave up accuracy at ε=0: "
              f"{tier_acc:.4f} vs {big_acc:.4f}", file=sys.stderr)
        ok = False
    print(f"escalation parity: never="
          f"{bool(esc.get('never_streams_identical'))} always="
          f"{bool(esc.get('always_streams_identical'))}")
    print(f"escalation tier: {tier_macs:.3f} MACs @ {tier_acc:.4f} acc "
          f"(target-only {big_macs:.3f} @ {big_acc:.4f}, draft-only "
          f"{float(esc.get('small_avg_macs') or 0):.3f} @ "
          f"{float(esc.get('small_accuracy') or 0):.4f}; "
          f"esc threshold {esc.get('escalation_threshold')})")
    return ok


def check_fleet(fl) -> bool:
    """Fleet-tier gates (written by ``benchmarks/bench_fleet.py``):
    the merged-telemetry solve is EXACTLY the pooled solve, the fleet
    warm-up beats a lone engine by >= 3x in per-member shadow evidence,
    threshold fan-out preserves streams bit-for-bit, and a mid-decode
    drain drops zero requests and loses zero committed tokens."""
    ok = True
    if int(fl.get("n_engines") or 0) < MIN_FLEET_ENGINES:
        print(f"fleet: bench ran {fl.get('n_engines')} engines; the "
              f"acceptance row needs >= {MIN_FLEET_ENGINES}",
              file=sys.stderr)
        ok = False
    if not fl.get("merged_solve_matches_pooled"):
        print("fleet: merged-histogram solve diverged from the pooled-"
              "sample solve — fixed-bin merge must be exact",
              file=sys.stderr)
        ok = False
    warm = fl.get("warmup") or {}
    ratio = float(warm.get("warmup_ratio") or 1e30)
    if ratio > WARMUP_RATIO_MAX + 1e-9:
        print(f"fleet: warm-up ratio {ratio:.3f} > {WARMUP_RATIO_MAX:.3f}"
              f" — the busiest member's shadow at first push must be <= "
              f"1/3 of a lone engine's", file=sys.stderr)
        ok = False
    if int(warm.get("fleet_pushes") or 0) < 1:
        print("fleet: aggregator never pushed thresholds",
              file=sys.stderr)
        ok = False
    if not fl.get("streams_identical_after_push"):
        print("fleet: fan-out-pushed engine diverged from a directly-"
              "pushed engine once thresholds matched", file=sys.stderr)
        ok = False
    drain = fl.get("drain") or {}
    if int(drain.get("dropped", 1)) != 0 or (
            int(drain.get("finished") or 0)
            != int(drain.get("submitted") or -1)):
        print(f"fleet: drain dropped requests: submitted="
              f"{drain.get('submitted')} finished={drain.get('finished')}",
              file=sys.stderr)
        ok = False
    if not drain.get("prefix_preserved"):
        print("fleet: a migrated request's committed prefix was not "
              "preserved verbatim", file=sys.stderr)
        ok = False
    if int(drain.get("migrated", 0)) < 1:
        print("fleet: drain migrated no in-flight requests — the bench "
              "must exercise the replay path", file=sys.stderr)
        ok = False
    if int(drain.get("discarded_tokens", 1)) != 0:
        print(f"fleet: {drain.get('discarded_tokens')} committed tokens "
              "discarded — same-config migration must replay, never "
              "discard", file=sys.stderr)
        ok = False
    if not drain.get("drained"):
        print("fleet: the drained member never reported empty",
              file=sys.stderr)
        ok = False
    print(f"fleet warmup: member shadow {warm.get('fleet_max_member_shadow_at_first_push')} "
          f"vs lone {warm.get('single_shadow_at_first_push')} "
          f"(ratio {ratio:.3f})")
    print(f"fleet drain: {drain.get('finished')}/{drain.get('submitted')} "
          f"finished, {drain.get('migrated')} migrated, "
          f"{drain.get('requeued')} requeued, "
          f"{drain.get('discarded_tokens')} tokens discarded")
    return ok


def check_obs(obs) -> bool:
    """Observability gates (written by ``benchmarks/bench_obs.py``): the
    flight recorder must be effectively free — within 3% tokens/s of the
    recorder-off engine on interleaved traffic, ZERO added host syncs
    per decode chunk (counted, not assumed), token streams bit-identical
    — and the fleet trace export must validate against the Chrome
    trace-event schema with the drain visible and a migrated request's
    flight spanning both members."""
    ok = True
    ov = obs.get("overhead") or {}
    ratio = float(ov.get("tokens_per_s_ratio") or 0.0)
    if ratio < OBS_RATIO_MIN:
        print(f"obs: recorder overhead beyond 3%: tokens/s ratio "
              f"{ratio:.3f} < {OBS_RATIO_MIN}", file=sys.stderr)
        ok = False
    if ov.get("extra_host_syncs_per_chunk_on", 1) != 0:
        print(f"obs: recorder added host syncs per chunk: "
              f"{ov.get('extra_host_syncs_per_chunk_on')}", file=sys.stderr)
        ok = False
    if not ov.get("streams_identical"):
        print("obs: recorder-on token streams diverged from recorder-off",
              file=sys.stderr)
        ok = False
    if not ov.get("mixed_exits"):
        print("obs: overhead bench ran at a non-mixed exit point — the "
              "streams_identical gate is vacuous there (exit_histogram "
              f"{ov.get('exit_histogram')})", file=sys.stderr)
        ok = False
    if int(ov.get("flights_recorded") or 0) < 1:
        print("obs: recorder-on engine recorded no flights", file=sys.stderr)
        ok = False
    tr = obs.get("trace") or {}
    if not tr.get("trace_valid"):
        print("obs: fleet trace export failed schema validation",
              file=sys.stderr)
        ok = False
    if int(tr.get("migrated") or 0) < 1:
        print("obs: fleet trace run migrated no requests — the bench must "
              "show a drain/migration on the timeline", file=sys.stderr)
        ok = False
    if not tr.get("migrated_shows_both_members"):
        print("obs: migrated request's flight does not span both members "
              "(want terminal migrate on the source, exit on the target)",
              file=sys.stderr)
        ok = False
    if int(tr.get("finished") or 0) != int(tr.get("submitted") or -1):
        print(f"obs: trace run dropped requests: "
              f"{tr.get('finished')}/{tr.get('submitted')} finished",
              file=sys.stderr)
        ok = False
    print(f"obs recorder ratio: {ratio:.3f} (extra syncs "
          f"{ov.get('extra_host_syncs_per_chunk_on')}, "
          f"{ov.get('flights_recorded')} flights, "
          f"{ov.get('flights_evicted')} evicted)")
    print(f"obs fleet trace: {tr.get('trace_events')} events, "
          f"{tr.get('migrated')} migrated, both_members="
          f"{bool(tr.get('migrated_shows_both_members'))}")
    return ok


def check_paged_row(r, th) -> bool:
    """Paged-vs-dense gates for one threshold row (see module docstring)."""
    ok = True
    needed = ("paged_streams_identical", "dense_peak_cache_bytes",
              "paged_peak_cache_bytes", "dense_admission_wait_mean",
              "paged_admission_wait_mean")
    missing = [k for k in needed if r.get(k) is None]
    if missing:
        print(f"th={th}: missing paged column(s) {missing}",
              file=sys.stderr)
        return False
    if not r["paged_streams_identical"]:
        print(f"th={th}: paged token streams diverged from the dense "
              f"layout", file=sys.stderr)
        ok = False
    dense_b = float(r["dense_peak_cache_bytes"])
    paged_b = float(r["paged_peak_cache_bytes"])
    if not paged_b < dense_b:
        print(f"th={th}: paged peak cache bytes not below the dense slab: "
              f"{paged_b:.0f} vs {dense_b:.0f}", file=sys.stderr)
        ok = False
    dense_w = float(r["dense_admission_wait_mean"])
    paged_w = float(r["paged_admission_wait_mean"])
    if th == 0.02:
        if not paged_w < dense_w:
            print(f"th={th}: paged admission wait not strictly better "
                  f"than dense: {paged_w:.2f} vs {dense_w:.2f} ticks",
                  file=sys.stderr)
            ok = False
    elif paged_w > dense_w / LAYOUT_NOISE_TOL:
        print(f"th={th}: paged admission wait worse than dense beyond "
              f"headroom: {paged_w:.2f} vs {dense_w:.2f} ticks",
              file=sys.stderr)
        ok = False
    return ok


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_serving.json"
    with open(path) as f:
        s = json.load(f)
    ver = s.get("schema_version")
    if ver is not None and ver not in KNOWN_SCHEMA_VERSIONS:
        # a newer writer may carry sections this checker has never heard
        # of — gate what is recognized, warn about the rest, never KeyError
        print(f"WARNING: {path} has schema_version {ver!r}; this checker "
              f"knows {list(KNOWN_SCHEMA_VERSIONS)} — gating only the "
              f"sections it recognizes", file=sys.stderr)
    meta = s.get("meta") or {}
    if meta:
        print(f"bench meta: jax {meta.get('jax')} "
              f"({meta.get('backend')}), python {meta.get('python')}")
    rows = s.get("rows") or []
    ok = True
    if len(rows) < MIN_THRESHOLDS:
        print(f"{path}: only {len(rows)} serving rows; the threshold sweep "
              f"must cover >= {MIN_THRESHOLDS}", file=sys.stderr)
        ok = False
    for r in rows:
        th = r.get("threshold")
        wallclocks = ("host_us_per_token", "device_us_per_token",
                      "copy_us_per_token", "kernels_off_us_per_token")
        missing = [k for k in wallclocks if not r.get(k)]
        if missing:
            print(f"th={th}: missing wallclock(s) {missing}",
                  file=sys.stderr)
            ok = False
            continue
        if not r.get("streams_identical"):
            print(f"th={th}: cohort-major stream diverged from the copy "
                  f"layout", file=sys.stderr)
            ok = False
        backend = r.get("kernel_backend")
        if backend not in ("interpret", "compiled") or \
                not r.get("kernel_platform"):
            print(f"th={th}: missing kernel backend provenance "
                  f"(kernel_backend={backend!r}, kernel_platform="
                  f"{r.get('kernel_platform')!r})", file=sys.stderr)
            ok = False
        elif backend == "compiled" and \
                float(r.get("kernel_speedup") or 0.0) <= 1.0:
            # interpreter rows are advisory (labeled in the printout
            # below); compiled rows are the real performance claim
            print(f"th={th}: compiled kernel path not faster than "
                  f"kernels-off: {float(r.get('kernel_speedup') or 0):.3f}x",
                  file=sys.stderr)
            ok = False
        layout = r.get("layout_speedup", 0.0)
        if th == 0.0:
            if layout <= 1.0:
                print(f"th={th}: cohort-major not strictly faster than "
                      f"copy: {layout:.3f}x", file=sys.stderr)
                ok = False
            if r.get("device_speedup", 0.0) <= 1.0:
                print(f"th={th}: device loop not faster than host: "
                      f"{r.get('device_speedup', 0.0):.3f}x",
                      file=sys.stderr)
                ok = False
        elif layout < LAYOUT_NOISE_TOL:
            print(f"th={th}: cohort-major slower than copy beyond noise "
                  f"tolerance: {layout:.3f}x < {LAYOUT_NOISE_TOL}",
                  file=sys.stderr)
            ok = False
        ok = check_paged_row(r, th) and ok
    print("device_speedup:",
          [round(r.get("device_speedup", 0.0), 3) for r in rows])
    print("layout_speedup:",
          [round(r.get("layout_speedup", 0.0), 3) for r in rows])
    backends = {r.get("kernel_backend") for r in rows}
    advisory = backends == {"interpret"}
    print(f"kernel_speedup"
          f"{' (ADVISORY: interpret backend)' if advisory else ''}:",
          [round(r.get("kernel_speedup", 0.0), 3) for r in rows])
    print("paged admission wait (paged vs dense, ticks):",
          [(round(r.get("paged_admission_wait_mean") or 0.0, 2),
            round(r.get("dense_admission_wait_mean") or 0.0, 2))
           for r in rows])
    print("paged peak bytes / dense slab:",
          [round(float(r.get("paged_peak_cache_bytes") or 0)
                 / max(1.0, float(r.get("dense_peak_cache_bytes") or 1)), 3)
           for r in rows])
    if s.get("kernels") is not None:
        ok = check_kernels(s["kernels"]) and ok
    if s.get("autotune") is not None:
        ok = check_autotune(s["autotune"]) and ok
    if s.get("escalation") is not None:
        ok = check_escalation(s["escalation"]) and ok
    if s.get("fleet") is not None:
        ok = check_fleet(s["fleet"]) and ok
    if s.get("obs") is not None:
        ok = check_obs(s["obs"]) and ok
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
