"""End-to-end behaviour tests: the paper's pipeline on a tiny scale, the
serving engine, backtrack training, checkpointing, data pipeline.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import load_checkpoint, save_checkpoint
from repro.configs import get_config, reduced
from repro.core.resnet_trainer import (collect_outputs, evaluate_tradeoff,
                                       train_backtrack)
from repro.core.training import backtrack_training_plan
from repro.data.synth_images import make_image_splits
from repro.data.lm_pipeline import SyntheticLMStream
from repro.models.model import build_model
from repro.models.resnet import CIResNet
from repro.serving import CascadeServingEngine, Request


@pytest.fixture(scope="module")
def tiny_trained():
    train, val, test = make_image_splits(n_classes=4, n_train=512, n_val=256,
                                         n_test=256, seed=5)
    model = CIResNet(n_blocks=1, n_classes=4, enhance_dim=32)
    report = train_backtrack(model, train, n_epochs=2, batch_size=64,
                             augment=False, test=test)
    return model, report, (train, val, test)


def test_backtrack_training_learns(tiny_trained):
    model, report, (train, val, test) = tiny_trained
    # final component must beat chance (0.25) clearly
    assert report.component_acc[2] > 0.5
    # phase-1 loss decreased
    pl = report.phase_losses["backbone+last"]
    assert np.mean(pl[-5:]) < np.mean(pl[:5])


def test_backtrack_phases_freeze_backbone(tiny_trained):
    """Head phases must not change the backbone (Algorithm 2)."""
    plan = backtrack_training_plan(3)
    assert plan[0].train_backbone and plan[0].epochs == 1.25
    assert all(not p.train_backbone for p in plan[1:])
    assert [p.loss_head for p in plan] == [2, 0, 1]


def test_tradeoff_sweep_monotone(tiny_trained):
    model, report, (train, val, test) = tiny_trained
    sweep = evaluate_tradeoff(model, report.params, report.state, val, test,
                              [0.0, 0.05, 0.2], 4)
    speedups = [r.speedup for _, r in sweep]
    assert speedups == sorted(speedups)          # larger eps -> faster
    assert all(r.speedup >= 1.0 for _, r in sweep)
    fracs = sweep[-1][1].exit_fractions
    assert abs(fracs.sum() - 1.0) < 1e-9


def test_confidence_accuracy_correlation(tiny_trained):
    """Fig-4 claim: higher-confidence samples are more accurate."""
    model, report, (_, _, test) = tiny_trained
    confs, preds, corrects = collect_outputs(model, report.params,
                                             report.state, test)
    m = 2
    order = np.argsort(confs[m])
    lo = corrects[m][order[:len(order) // 3]].mean()
    hi = corrects[m][order[-len(order) // 3:]].mean()
    assert hi >= lo


# ---------------------------------------------------------------------------

def test_serving_engine_thresholds_trade_speed(tmp_path):
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def run(th):
        c = cfg.with_cascade(thresholds=(th, 0.0))
        eng = CascadeServingEngine(c, model, params, lane_batch=2,
                                   n_lanes=1, cache_len=32)
        for i in range(4):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, c.vocab_size, 6).astype(np.int32), max_new_tokens=4))
        eng.run(100)
        return eng

    easy = run(0.0)     # everything exits at component 0
    hard = run(1.1)     # nothing exits early
    assert easy.stats()["requests_finished"] == 4
    assert hard.stats()["requests_finished"] == 4
    assert easy.speedup() > hard.speedup()
    assert hard.speedup() == pytest.approx(1.0)
    assert easy.stats()["mean_exit_depth"] == 0.0


def test_checkpoint_roundtrip(tmp_path):
    cfg = reduced(get_config("yi-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = save_checkpoint(str(tmp_path), 7, params)
    assert os.path.exists(path)
    restored = load_checkpoint(str(tmp_path), params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    cfg = reduced(get_config("yi-9b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    save_checkpoint(str(tmp_path), 1, params)
    other = build_model(reduced(get_config("yi-9b"), d_model=128)).init(
        jax.random.PRNGKey(0))
    with pytest.raises((ValueError, KeyError)):
        load_checkpoint(str(tmp_path), other)


def test_lm_stream_is_learnable_markov():
    s = SyntheticLMStream(vocab_size=64, seq_len=32, batch_size=4,
                          easy_frac=1.0, seed=0)
    x, y = next(s)
    assert x.shape == (4, 32) and y.shape == (4, 32)
    # with easy_frac=1 every next token is one of the 4 Markov successors
    nxt = s.next_tok[x.reshape(-1)]
    assert (y.reshape(-1)[:, None] == nxt).any(axis=1).all()


def test_synth_images_difficulty_controls_noise():
    train, _, _ = make_image_splits(n_classes=4, n_train=256, n_val=8,
                                    n_test=8, seed=1)
    assert train.images.shape == (256, 32, 32, 3)
    # standardized per-sample
    assert np.allclose(train.images.mean(axis=(1, 2, 3)), 0, atol=1e-4)


def test_trainability_mask_llm_layout():
    """Algorithm-2 phase masks over the CascadeModel pytree: head phases
    freeze the backbone and other heads."""
    from repro.core.training import backtrack_training_plan, trainability_mask
    cfg = reduced(get_config("qwen2.5-3b"))
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    plan = backtrack_training_plan(cfg.cascade.n_components)
    m0 = trainability_mask(params, plan[0])       # backbone+last
    assert bool(m0["embed"]) and bool(m0["lm_head"])
    assert not bool(m0["exits"][0]["norm"]["w"])
    m1 = trainability_mask(params, plan[1])       # head 0 only
    assert bool(m1["exits"][0]["norm"]["w"])
    assert not bool(m1["embed"]) and not bool(m1["lm_head"])
