"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures, per threshold / measure, BOTH of:
  (i)  the paper's analytic MAC speedup (§6.2), and
  (ii) measured decode wall-clock per token under ``select`` (fixed graph)
       vs ``cond_batch`` (lax.cond skips exited segments' compute) — the
       ``wallclock_speedup`` column is real elapsed time; jit compilation
       is timed apart by the engine (``compile_seconds``) and a warm-up
       wave + ``reset_metrics()`` keeps the measured wave steady-state.

The serving sweep is the skip-aware hot-path ablation (persisted to
``BENCH_serving.json`` by ``benchmarks/run.py``): at every threshold, with
``n_cohorts=2`` and ``use_kernels=True``,

* ``runtime=host`` vs ``runtime=device`` — the ``DeviceDecodeLoop``
  while_loop amortizes per-token dispatch (``device_speedup``);
* ``cohort_layout=copy`` vs ``cohort_layout=major`` — the per-segment
  slice+concat cohort path vs the cohort-major layout that splits once and
  scatters cache results back in place (``layout_speedup``), with the two
  layouts' token streams asserted bit-identical (``streams_identical``);
* kernels on vs off — the exit-masked decode-attention + fused exit-update
  Pallas fast path vs the plain jnp path (``kernel_speedup``; on CPU CI the
  kernels run interpreted, so this column is only meaningful on real
  hardware — it is recorded, not gated);
* ``cache_layout=dense`` vs ``cache_layout=paged`` — bit-identity at
  capacity (``paged_streams_identical``), then an EQUAL-MEMORY admission
  burst: the paged engine runs twice the slots inside the dense slab's
  byte budget (slots claim blocks only for their actual span), so its
  admission wait (ticks from submit to admit — deterministic, not
  wall-clock) and peak cache bytes must beat the dense layout
  (``check_bench_serving.py`` gates both, plus the exit-reclamation
  counters recorded per row).

All exit decisions route through the one ExitDecider resolved from the
config's registry strings; per-lane decode state (patience streaks
included) rides in the carried DecodeState.
"""
import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request

LANE_BATCH = 2
CHUNK = 8
# the serving ablation runs cohort-split skipping (the device loop's
# intended configuration); summary rows record it
N_COHORTS = 2
# serving-ablation lane shape: larger than the mode rows above so the
# layout delta (cache copies per segment per step) clears timer noise
SERVE_LANE_BATCH = 4
SERVE_CACHE_LEN = 256
# paged-cache ablation shape: 16-position blocks over the 256-position ring
PAGED_BLOCK = 16
# the full threshold sweep persisted to BENCH_serving.json — at least 3
# operating points so the perf trajectory tracks the cascade, not one row:
# 0.0 exits everyone at component 0 (max skipping), 0.02 sits inside the
# random-init confidence band (~0.02–0.03 over a 512 vocab) for genuinely
# mixed per-slot exits, 1.1 never exits early (the dense ceiling)
SERVE_THRESHOLDS = (0.0, 0.02, 1.1)

# set by run(): machine-readable serving-ablation summary
LAST_SERVING_SUMMARY = None


def _drive(cfg, model, params, n_req=6, max_new=8, runtime="host",
           chunk=CHUNK, lane_batch=LANE_BATCH, n_lanes=2, cache_len=48,
           waves=1):
    """Run a warm-up wave, reset metrics, run ``waves`` measured waves.

    Returns the engine (callers read ``stats()`` and the finished token
    streams).  Prompts are seeded per request id, so two runs with the
    same shape execute identical traffic; every wave is submitted exactly
    at capacity so nothing queues (queueing admits at chunk boundaries in
    the device runtime and would legitimately diverge the streams).
    """
    rng = np.random.default_rng(0)
    eng = CascadeServingEngine(cfg, model, params, lane_batch=lane_batch,
                               n_lanes=n_lanes, cache_len=cache_len,
                               runtime=runtime, chunk=chunk)
    prompts = [rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
               for _ in range((waves + 1) * n_req)]
    for i in range(n_req):                       # wave 1: jit warm-up
        eng.submit(Request(rid=i, prompt=prompts[i], max_new_tokens=max_new))
    eng.run(300)
    eng.reset_metrics()
    for w in range(1, waves + 1):                # measured waves
        for i in range(w * n_req, (w + 1) * n_req):
            eng.submit(Request(rid=i, prompt=prompts[i],
                               max_new_tokens=max_new))
        eng.run(300)
    return eng


def _streams(eng):
    return {rid: tuple(r["tokens"]) for rid, r in eng.finished.items()}


def run(quick: bool = False):
    global LAST_SERVING_SUMMARY
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rows = []
    n_req = 2 if quick else 6
    ths_grid = (0.0, 0.5) if quick else (0.0, 0.5, 1.1)
    for th in ths_grid:
        per_mode = {}
        for mode in ("select", "cond_batch"):
            c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode=mode)
            st = _drive(c, model, params, n_req=n_req).stats()
            per_mode[mode] = st
            rows.append((f"llm_cascade/th={th:g}/{mode}",
                         st["wallclock_us_per_token"] or 0.0,
                         f"analytic={st['analytic_speedup']:.3f};"
                         f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                         f"opportunity={st['skip_opportunity_rate']:.3f}"))
        sel, cb = (per_mode["select"]["wallclock_us_per_token"],
                   per_mode["cond_batch"]["wallclock_us_per_token"])
        wc = (sel / cb) if (sel and cb) else 1.0
        rows.append((f"llm_cascade/th={th:g}/wallclock_speedup", 0.0,
                     f"{wc:.3f}"))
    # alternative measures through the same registry-resolved engine path —
    # patience@2 carries its streaks in the lane DecodeState and still skips
    measures = ("patience@2",) if quick else ("entropy", "patience@2")
    for measure in measures:
        c = cfg.with_cascade(thresholds=(0.5, 0.0), exit_mode="cond_batch",
                             confidence=measure)
        st = _drive(c, model, params, n_req=n_req).stats()
        rows.append((f"llm_cascade/measure={measure}",
                     st["wallclock_us_per_token"] or 0.0,
                     f"analytic={st['analytic_speedup']:.3f};"
                     f"skip_rate={st['cond_batch_skip_rate']:.3f}"))

    # ------------------------------------------------------------------
    # the skip-aware hot-path ablation (persisted to BENCH_serving.json):
    # host-vs-device x cohort-layout x kernels, full threshold sweep.
    # A 3-component cascade on a 3-layer reduced config: two deep segments,
    # so the copy layout pays its per-segment slice+concat twice per step —
    # the copy overhead the cohort-major layout deletes.  Exactly at
    # capacity (2 lanes x SERVE_LANE_BATCH slots): with no queued requests
    # every compared run admits at the same points, so identical-semantics
    # runs (copy vs major at equal n_cohorts) execute bit-identical token
    # streams (asserted below, recorded per row as streams_identical).
    scfg = reduced(get_config("qwen2.5-3b"), n_layers=3).replace(
        dtype="float32").with_cascade(
            n_components=3, exit_boundaries=(1, 2), exit_mode="cond_batch",
            n_cohorts=N_COHORTS)
    smodel = build_model(scfg)
    sparams = smodel.init(jax.random.PRNGKey(1))
    serving_rows = []
    rt_req = 2 * SERVE_LANE_BATCH
    # many short waves beat few long ones: the engines interleave at wave
    # granularity, so shorter waves = finer interleave = better cancellation
    # of machine-load drift between the compared variants
    max_new = 12 if quick else 16
    waves = 6 if quick else 8
    # the four compared engines per threshold; measured waves run
    # INTERLEAVED across them (host load drifts on multi-second scales —
    # back-to-back runs would hand whole waves of drift to one variant)
    variants = (("host", "host", "major", True),
                ("major", "device", "major", True),
                ("copy", "device", "copy", True),
                ("nokernel", "device", "major", False))

    def serve_ablation(th):
        engines = {}
        for name, runtime, layout, kernels in variants:
            c = scfg.replace(use_kernels=kernels).with_cascade(
                thresholds=(th, th, 0.0), cohort_layout=layout)
            eng = _drive(c, smodel, sparams, n_req=rt_req, max_new=max_new,
                         runtime=runtime, lane_batch=SERVE_LANE_BATCH,
                         cache_len=SERVE_CACHE_LEN, waves=0)
            engines[name] = eng
        rng = np.random.default_rng(0)
        prompts = [rng.integers(0, scfg.vocab_size, 8).astype(np.int32)
                   for _ in range((waves + 1) * rt_req)]
        for w in range(1, waves + 1):            # interleaved measured waves
            for name, eng in engines.items():
                for i in range(w * rt_req, (w + 1) * rt_req):
                    eng.submit(Request(rid=i, prompt=prompts[i],
                                       max_new_tokens=max_new))
                eng.run(300)
        stats = {}
        for name, runtime, layout, kernels in variants:
            st = engines[name].stats()
            stats[name] = st
            rows.append((
                f"llm_cascade/th={th:g}/runtime={runtime}/layout={layout}/"
                f"kernels={'on' if kernels else 'off'}",
                st["wallclock_us_per_token"] or 0.0,
                f"analytic={st['analytic_speedup']:.3f};"
                f"skip_rate={st['cond_batch_skip_rate']:.3f};"
                f"opportunity={st['skip_opportunity_rate']:.3f};"
                f"compile_s={st['compile_seconds']:.2f}"))
        return engines, stats

    def paged_ablation(th, dense_host_eng):
        """Dense vs paged KV layout at one threshold.

        Two measurements: (i) bit-identity at capacity — a paged engine
        with the SAME lane shape sees the same traffic as the ablation's
        host engine and must produce identical token streams; (ii) an
        equal-memory admission burst — the paged engine runs twice the
        slots inside the dense slab's byte budget (its pool is capped at
        the dense-equivalent block count), so queued requests admit
        sooner (fewer ticks submit->admit) and the block pool's peak
        occupancy stays below the always-resident dense slab.  Both burst
        metrics are deterministic tick/byte counts, not wall-clock."""
        base = scfg.replace(use_kernels=True).with_cascade(
            thresholds=(th, th, 0.0), cohort_layout="major")
        paged = base.with_paged_cache(layout="paged",
                                      block_size=PAGED_BLOCK)
        e_par = _drive(paged, smodel, sparams, n_req=rt_req,
                       max_new=max_new, runtime="host",
                       lane_batch=SERVE_LANE_BATCH,
                       cache_len=SERVE_CACHE_LEN, waves=waves)
        identical = _streams(dense_host_eng) == _streams(e_par)
        # the paged parity engine auto-sized its pool to the dense
        # equivalent of THIS lane shape (+ trash block) — reuse that as
        # the equal-memory cap for the double-slot burst engine
        pool_cap = e_par.pcache.pool.num_blocks
        big = base.with_paged_cache(layout="paged", block_size=PAGED_BLOCK,
                                    num_blocks=pool_cap)
        burst = 3 * rt_req

        def admission(cfg_, lane_batch):
            eng = CascadeServingEngine(cfg_, smodel, sparams,
                                       lane_batch=lane_batch, n_lanes=2,
                                       cache_len=SERVE_CACHE_LEN,
                                       runtime="host")
            arng = np.random.default_rng(0)
            for i in range(burst):
                eng.submit(Request(
                    rid=i,
                    prompt=arng.integers(0, scfg.vocab_size,
                                         8).astype(np.int32),
                    max_new_tokens=max_new))
            eng.run(600)
            assert len(eng.finished) == burst
            return eng.stats()

        ad = admission(base, SERVE_LANE_BATCH)
        ap = admission(big, 2 * SERVE_LANE_BATCH)
        st_par = e_par.stats()
        out = {
            "paged_streams_identical": identical,
            "paged_us_per_token": st_par["wallclock_us_per_token"],
            "dense_peak_cache_bytes": ad["memory"]["peak_cache_bytes"],
            "paged_peak_cache_bytes": ap["memory"]["peak_cache_bytes"],
            "paged_pool_blocks": ap["memory"]["num_blocks"],
            "paged_peak_blocks": ap["memory"]["peak_blocks_used"],
            "paged_reclaimed_by_exit": ap["memory"]["reclaimed_by_exit"],
            "paged_reclaimed_at_retire":
                ap["memory"]["reclaimed_at_retire"],
            "dense_admission_wait_mean": ad["admission_wait_mean"],
            "paged_admission_wait_mean": ap["admission_wait_mean"],
        }
        rows.append((
            f"llm_cascade/th={th:g}/cache_layout=paged",
            st_par["wallclock_us_per_token"] or 0.0,
            f"streams_identical={identical};"
            f"admission_wait={out['paged_admission_wait_mean']:.2f}"
            f"_vs_dense={out['dense_admission_wait_mean']:.2f};"
            f"peak_bytes={out['paged_peak_cache_bytes']}"
            f"_vs_dense={out['dense_peak_cache_bytes']};"
            f"reclaimed_by_exit={out['paged_reclaimed_by_exit']}"))
        return out

    for th in SERVE_THRESHOLDS:
        engines, stats = serve_ablation(th)
        paged_row = paged_ablation(th, engines["host"])
        host_st, major_st = stats["host"], stats["major"]
        copy_st, off_st = stats["copy"], stats["nokernel"]
        identical = _streams(engines["major"]) == _streams(engines["copy"])
        hu = host_st["wallclock_us_per_token"]
        du = major_st["wallclock_us_per_token"]
        cu = copy_st["wallclock_us_per_token"]
        ou = off_st["wallclock_us_per_token"]
        device_speedup = (hu / du) if (hu and du) else 1.0
        layout_speedup = (cu / du) if (cu and du) else 1.0
        kernel_speedup = (ou / du) if (ou and du) else 1.0
        rows.append((f"llm_cascade/th={th:g}/device_speedup", 0.0,
                     f"{device_speedup:.3f}"))
        rows.append((f"llm_cascade/th={th:g}/layout_speedup", 0.0,
                     f"{layout_speedup:.3f};streams_identical={identical}"))
        rows.append((f"llm_cascade/th={th:g}/kernel_speedup", 0.0,
                     f"{kernel_speedup:.3f}"))
        serving_rows.append({
            "threshold": th,
            "host_us_per_token": hu,
            "device_us_per_token": du,
            "device_speedup": device_speedup,
            "copy_us_per_token": cu,
            "major_us_per_token": du,
            "layout_speedup": layout_speedup,
            "kernels_off_us_per_token": ou,
            "kernel_speedup": kernel_speedup,
            "streams_identical": identical,
            "realized_skip_rate": major_st["cond_batch_skip_rate"],
            "opportunity_rate": major_st["skip_opportunity_rate"],
            "mac_speedup": major_st["analytic_speedup"],
            "compile_seconds_host": host_st["compile_seconds"],
            "compile_seconds_device": major_st["compile_seconds"],
            **paged_row,
        })
    LAST_SERVING_SUMMARY = {
        "bench": "llm_cascade",
        "arch": scfg.name,
        "lane_batch": SERVE_LANE_BATCH,
        "cache_len": SERVE_CACHE_LEN,
        "chunk": CHUNK,
        "n_cohorts": N_COHORTS,
        "n_components": scfg.cascade.n_components,
        "use_kernels": True,
        "paged_block_size": PAGED_BLOCK,
        "quick": bool(quick),
        "rows": serving_rows,
    }
    return rows
