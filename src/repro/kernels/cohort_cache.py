"""In-place cohort scatter for the mixed-exit cache re-join (Pallas).

The cohort-major decode path (``core/exec.py`` ``_mixed``) runs each cohort's
segment step over a zero-copy view of the cache slab, then re-joins the C
per-cohort outputs into the full slab.  The seeded re-join is
``jnp.concatenate(parts, axis=1)`` — and PR 4's layout study documented that
XLA does NOT elide the equivalent ``.at[:, lo:hi].set`` scatter inside the
surrounding ``while_loop`` + ``cond``: every mixed step paid a full-slab
materialization even though each cohort only produced ``B/C`` fresh rows.

:func:`cohort_scatter` replaces that re-join with an aliased partial-write
``pallas_call``: the destination slab is input 0 AND the output buffer
(``input_output_aliases={0: 0}``), the grid covers only the target cohort's
blocks, and the kernel copies the cohort's rows into place.  Blocks the grid
never visits keep the aliased input's bytes — the other cohorts' rows are
untouched, no full-slab copy is issued by the kernel itself.  Chaining the
call once per cohort (``dst = cohort_scatter(dst, part, c, C)``) rebuilds the
slab with C cohort-sized writes instead of one B-sized concat.

``c`` and ``C`` are Python ints (the cohort loop in ``_mixed`` is unrolled),
so the block index maps are static — no dynamic-slice lowering.

Semantics are bit-identical to the concat (pinned by tests); only the memory
traffic changes.  Non-array-friendly leaves (cohort axis missing, or a
trailing extent the TPU layout can't partial-write) fall back to
``dst.at[...].set(src)`` — same bytes, XLA's choice of copy.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


def _scatter_kernel(dst_ref, src_ref, out_ref):
    out_ref[...] = src_ref[...]


@partial(jax.jit, static_argnames=("c", "C", "interpret"))
def _scatter(dst, src, c: int, C: int, interpret: bool):
    L, B = dst.shape[0], dst.shape[1]
    Bc = B // C
    rest = dst.shape[2:]
    R = 1
    for r in rest:
        R *= r
    d3 = dst.reshape(L, B, R)
    s3 = src.reshape(L, Bc, R)
    out = pl.pallas_call(
        _scatter_kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, Bc, R), lambda l, _c=c: (l, _c, 0)),
            pl.BlockSpec((1, Bc, R), lambda l: (l, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, Bc, R), lambda l, _c=c: (l, _c, 0)),
        out_shape=jax.ShapeDtypeStruct(d3.shape, d3.dtype),
        input_output_aliases={0: 0},
        interpret=interpret,
    )(d3, s3)
    return out.reshape(dst.shape)


def cohort_scatter(dst, src, c: int, C: int, *, interpret=None):
    """Write cohort ``c``'s rows ``src`` into ``dst`` along axis 1.

    ``dst``: (L, B, ...); ``src``: (L, B // C, ...) — the cohort's segment
    output.  Returns the updated slab; the destination buffer is aliased so
    the compiled program updates in place (untouched cohorts keep their
    bytes).  Bit-identical to ``dst.at[:, c*Bc:(c+1)*Bc].set(src)``.
    """
    interpret = resolve_interpret(interpret)
    if dst.ndim < 2 or dst.shape[1] % C != 0:
        lo = c * (dst.shape[1] // C) if dst.ndim >= 2 else 0
        return dst.at[:, lo:lo + src.shape[1]].set(src)
    Bc = dst.shape[1] // C
    R = 1
    for r in dst.shape[2:]:
        R *= r
    # compiled TPU lowering needs a lane-aligned trailing extent for a
    # partial write; oddball leaves take the plain XLA scatter instead
    if not interpret and (R % 128 != 0 or dst.dtype == jnp.bool_):
        return dst.at[:, c * Bc:(c + 1) * Bc].set(src)
    if dst.dtype == jnp.bool_:
        out = _scatter(dst.astype(jnp.int8), src.astype(jnp.int8), c, C,
                       interpret)
        return out.astype(jnp.bool_)
    return _scatter(dst, src, c, C, interpret)


def cohort_scatter_tree(dst_tree, src_tree, c: int, C: int, *, interpret=None):
    """Tree-mapped :func:`cohort_scatter` over matching cache pytrees."""
    return jax.tree_util.tree_map(
        lambda d, s: cohort_scatter(d, s, c, C, interpret=interpret),
        dst_tree, src_tree)
