"""Beyond-paper benchmark: cascade early exit on an LLM decode stream.

Measures (i) the serving engine's analytic MAC speedup at several thresholds,
(ii) softmax-confidence vs entropy-confidence (the BranchyNet [TMK16]
baseline the paper argues against) at matched exit rates, and (iii) the
cond_batch skip rate with depth-compacted lanes.
"""
import time

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models.model import build_model
from repro.serving import CascadeServingEngine, Request


def run():
    cfg = reduced(get_config("qwen2.5-3b")).replace(dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    for th in (0.0, 0.5, 1.1):
        c = cfg.with_cascade(thresholds=(th, 0.0), exit_mode="select")
        eng = CascadeServingEngine(c, model, params, lane_batch=2,
                                   n_lanes=2, cache_len=48)
        for i in range(6):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, c.vocab_size, 8).astype(np.int32), max_new_tokens=8))
        t0 = time.time()
        eng.run(300)
        dt = (time.time() - t0) * 1e6
        st = eng.stats()
        rows.append((f"llm_cascade/th={th:g}/speedup",
                     dt / max(1, st["requests_finished"]),
                     f"{st['analytic_speedup']:.3f}"))
        rows.append((f"llm_cascade/th={th:g}/skip_rate", 0.0,
                     f"{st['cond_batch_skip_rate']:.3f}"))
    return rows
