"""Parameter-initialization helpers (flax is unavailable; pure pytrees)."""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp


def dense_init(key, shape, dtype=jnp.float32, scale: float | None = None):
    """He/Lecun style fan-in init: N(0, sqrt(scale / fan_in)).

    The paper initializes from N(0, sqrt(2/k)) with k = fan-in [HZRS15b];
    ``scale`` defaults to 1.0 (lecun) for transformer weights and callers pass
    2.0 for ReLU conv stacks.
    """
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = math.sqrt((scale if scale is not None else 1.0) / max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def zeros_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.zeros(shape, dtype)


def ones_init(key, shape, dtype=jnp.float32):
    del key
    return jnp.ones(shape, dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


def stack_init(init_fn, key, n: int):
    """Initialize ``n`` identical blocks and stack each leaf on axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)
