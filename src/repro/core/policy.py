"""Unified exit-policy layer: pluggable confidence measures, exit policies
and calibrators behind one registry, plus the single exit-decision engine
(:class:`ExitDecider`) shared by Algorithm-1 inference, the vectorized
evaluation harness, the serving engine and the launch steps.

The paper's mechanism — softmax confidence δ_m gates early exit at calibrated
thresholds δ̂_m(ε) — previously lived in three hand-rolled copies (sequential
inference, serving ``select_exit``, numpy eval sweep).  Related work swaps
each piece independently: *Learning to Cascade* replaces max-softmax with a
calibrated confidence, *IDK Cascades* gates on entropy or margin, PABEE-style
decoding requires k consecutive confident steps.  Each such variant is now a
small registered class:

* :class:`ConfidenceMeasure` — logits → (prediction, scalar confidence).
  Shipped: ``softmax_max`` (Def. 3.3, with a fused Pallas path),
  ``entropy`` (BranchyNet baseline, mapped onto (0, 1]), ``margin``
  (top-2 probability gap) and ``patience`` (k consecutive confident decode
  steps, wrapping any base measure).
* :class:`ExitPolicy` — per-component confidences → boolean exit gates.
  Shipped: :class:`ThresholdPolicy` (Algorithm 1 verbatim) and
  :class:`BudgetPolicy` (fits thresholds to hit a target average-MAC
  budget on calibration confidences).
* :class:`Calibrator` — §5 threshold calibration.  Shipped: ``self`` (the
  paper's per-component rule) and ``final`` (cascade-level ε budget).

Strings in :class:`repro.configs.base.CascadeConfig` (``confidence``,
``policy``, ``calibrator``) resolve through the registries, so configs stay
frozen/hashable and a new strategy is one ``@register_*`` class away.
Parameterized specs use ``name@arg`` (e.g. ``patience@3``,
``patience@3:entropy``, ``budget@2.5e6``).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.calibration import (CalibrationResult, threshold_for_epsilon)
from repro.core.confidence import entropy_confidence, softmax_outputs


# ---------------------------------------------------------------------------
# registries
# ---------------------------------------------------------------------------

_MEASURES: Dict[str, Callable[[str], "ConfidenceMeasure"]] = {}
_POLICIES: Dict[str, Callable[[str], "ExitPolicy"]] = {}
_CALIBRATORS: Dict[str, Callable[[str], "Calibrator"]] = {}


def _register(table, name):
    def deco(factory):
        table[name] = factory
        return factory
    return deco


def register_measure(name: str):
    """Class decorator: register a ConfidenceMeasure under ``name``.

    The class is constructed as ``cls(argspec)`` where ``argspec`` is the
    (possibly empty) text after ``@`` in the config string.
    """
    return _register(_MEASURES, name)


def register_policy(name: str):
    return _register(_POLICIES, name)


def register_calibrator(name: str):
    return _register(_CALIBRATORS, name)


def _resolve(table, spec: str, kind: str):
    name, _, arg = spec.partition("@")
    if name not in table:
        raise KeyError(f"unknown {kind} {name!r}; registered: "
                       f"{sorted(table)}")
    return table[name](arg)


def get_measure(spec: str) -> "ConfidenceMeasure":
    """``softmax_max`` | ``entropy`` | ``margin`` | ``patience@k[:base]`` …"""
    return _resolve(_MEASURES, spec, "confidence measure")


def get_policy(spec: str) -> "ExitPolicy":
    """``threshold`` | ``budget@<avg-mac-target>`` …"""
    return _resolve(_POLICIES, spec, "exit policy")


def get_calibrator(spec: str) -> "Calibrator":
    """``self`` | ``final`` …"""
    return _resolve(_CALIBRATORS, spec, "calibrator")


def available_measures() -> List[str]:
    return sorted(_MEASURES)


def available_policies() -> List[str]:
    return sorted(_POLICIES)


def available_calibrators() -> List[str]:
    return sorted(_CALIBRATORS)


# ---------------------------------------------------------------------------
# confidence measures
# ---------------------------------------------------------------------------

class ConfidenceMeasure:
    """logits (..., C) → (prediction (...,), confidence (...,) in (0, 1]).

    ``stateful`` measures additionally thread per-sequence decode state
    through :meth:`ExitDecider.decide` (see :class:`PatienceMeasure`).
    """

    name = "base"
    stateful = False
    patience_k = 1

    def __call__(self, logits: jnp.ndarray):
        raise NotImplementedError

    def fused_kernel(self, logits: jnp.ndarray, interpret=None):
        """Optional fused-kernel path for 2D (B, V) logits; None = no kernel.

        Only consulted when the caller opted in (``cfg.use_kernels``); the
        semantics must match ``__call__`` bit-for-bit up to float tolerance.
        ``interpret`` is the config's Pallas-backend override (None = auto).
        """
        return None

    def init_state(self, n_exits: int, batch: int):
        """Per-sequence decode-time carry for stateful measures, or None.

        LAYOUT CONTRACT: a non-None state must be shaped
        ``(n_exits, batch, ...)`` — the component scan indexes row ``m``
        per component, ``decode_state_spec`` shards axis 1 as the batch,
        and cohort-split execution slices axis 1
        (:meth:`ExitDecider.slice_carry`).
        """
        return None


@register_measure("softmax_max")
class SoftmaxMaxMeasure(ConfidenceMeasure):
    """δ = max softmax (Defs. 3.2–3.3) — the paper's measure."""

    name = "softmax_max"

    def __init__(self, arg: str = ""):
        del arg

    def __call__(self, logits):
        return softmax_outputs(logits)

    def fused_kernel(self, logits, interpret=None):
        if logits.ndim != 2:
            return None
        from repro.kernels.ops import softmax_confidence_fused
        return softmax_confidence_fused(logits, interpret=interpret)


@register_measure("entropy")
class EntropyMeasure(ConfidenceMeasure):
    """BranchyNet [TMK16] baseline: −entropy, mapped onto (0, 1] via
    1/(1 + H) so §5 calibration grids behave like δ's."""

    name = "entropy"

    def __init__(self, arg: str = ""):
        del arg

    def __call__(self, logits):
        out = jnp.argmax(logits, axis=-1)
        neg_ent = entropy_confidence(logits)          # (−inf, 0]
        return out, 1.0 / (1.0 - neg_ent)


@register_measure("margin")
class MarginMeasure(ConfidenceMeasure):
    """Top-2 softmax probability gap (IDK-cascade style), in [0, 1)."""

    name = "margin"

    def __init__(self, arg: str = ""):
        del arg

    def __call__(self, logits):
        x = logits.astype(jnp.float32)
        out = jnp.argmax(x, axis=-1)
        top2 = jax.lax.top_k(x, 2)[0]                  # (..., 2) descending
        m = top2[..., 0]
        lse = m + jnp.log(jnp.sum(jnp.exp(x - m[..., None]), axis=-1))
        p = jnp.exp(top2 - lse[..., None])
        return out, p[..., 0] - p[..., 1]


@register_measure("patience")
class PatienceMeasure(ConfidenceMeasure):
    """PABEE-style patience: a sequence may exit at component m only after
    its base confidence has cleared the gate on k *consecutive* decode steps
    (the current one included).  Spec: ``patience@k`` or ``patience@k:base``
    (default base ``softmax_max``, k=2).

    The per-(exit, sequence) streak counters live in decider state; the gate
    rewrite happens inside :meth:`ExitDecider.decide` so the scan stays the
    single implementation.
    """

    name = "patience"
    stateful = True

    def __init__(self, arg: str = ""):
        k, _, base = arg.partition(":")
        self.patience_k = int(k) if k else 2
        if self.patience_k < 1:
            raise ValueError("patience k must be >= 1")
        self.base = get_measure(base or "softmax_max")

    def __call__(self, logits):
        return self.base(logits)

    def fused_kernel(self, logits, interpret=None):
        return self.base.fused_kernel(logits, interpret=interpret)

    def init_state(self, n_exits: int, batch: int):
        return jnp.zeros((n_exits, batch), jnp.int32)


# ---------------------------------------------------------------------------
# exit policies
# ---------------------------------------------------------------------------

class ExitPolicy:
    """Per-component confidences (n_m, B) → boolean exit gates (n_m, B).

    The final component's gate must be all-True (it always answers); the
    decision scan itself (first open gate wins) lives in ExitDecider.

    ``component_gate`` is the staged-execution entry point: the gate for ONE
    component, called segment by segment as the executor computes (or skips)
    them.  It must equal row ``m`` of :meth:`gates` — that identity is what
    makes ``cond_batch`` segment skipping bit-identical to the fixed-graph
    ``select`` mode.
    """

    name = "base"

    def resolve_thresholds(self, thresholds, explicit: bool = False):
        """Thresholds the decider should use.

        ``explicit`` marks thresholds passed per-call to
        :meth:`ExitDecider.decide` (as opposed to the decider's configured
        vector); policies that own a fitted vector (BudgetPolicy) honor the
        per-call override and warn about the ambiguity.
        """
        del explicit
        return thresholds

    def gates(self, confs: jnp.ndarray, thresholds) -> jnp.ndarray:
        raise NotImplementedError

    def component_gate(self, conf: jnp.ndarray, thresholds, m: int,
                       n_components: int) -> jnp.ndarray:
        raise NotImplementedError(
            f"policy {self.name!r} defines no per-component gate; staged "
            "(cond_batch) execution needs component_gate == gates()[m]")


@register_policy("threshold")
class ThresholdPolicy(ExitPolicy):
    """Algorithm 1 verbatim: exit at the first component with δ_m ≥ δ̂_m;
    the final component always answers."""

    name = "threshold"

    def __init__(self, arg: str = ""):
        del arg

    def gates(self, confs, thresholds):
        ths = jnp.asarray(thresholds, confs.dtype).reshape(
            (-1,) + (1,) * (confs.ndim - 1))
        if ths.shape[0] != confs.shape[0]:
            raise ValueError(
                f"{ths.shape[0]} thresholds for {confs.shape[0]} cascade "
                f"components")
        open_ = confs >= ths
        return open_.at[-1].set(True)

    def component_gate(self, conf, thresholds, m, n_components):
        if m >= n_components - 1:
            return jnp.ones(conf.shape, bool)
        return conf >= jnp.asarray(thresholds[m], conf.dtype)


# one-time deprecation notice for the shared-quantile fit
_SHARED_QUANTILE_WARNED = False


@register_policy("budget")
class BudgetPolicy(ThresholdPolicy):
    """Pick thresholds hitting a target *average* MAC budget per sample.

    Spec: ``budget@<avg_macs>`` (default) fits PER-COMPONENT thresholds via
    the ``repro.autotune`` coordinate-descent solver: :meth:`fit` builds the
    joint confidence histogram from the calibration dump (confidences +
    correctness) and maximizes accuracy subject to mean MACs <= budget —
    the search that dominates a shared quantile at equal budget (the
    shared-quantile solution is one of its starting points).

    ``budget@<avg_macs>:shared`` is the DEPRECATED legacy alias (one
    shared exit quantile, bisected onto the budget — it cannot shift exit
    mass toward the components that earn it).  It no longer selects a
    different fit: when :meth:`fit` has ``corrects``, the alias warns once
    and routes through the same solver as the default spelling (seeded
    from the shared-quantile solution, so it provably fits no worse) —
    identical thresholds to ``budget@<avg_macs>``.  Only a :meth:`fit`
    call WITHOUT ``corrects`` still runs the legacy bisection itself
    (with the same warning), since the per-component search needs
    correctness to rank allocations.

    Unlike ThresholdPolicy this policy needs a calibration step: resolve it
    (``get_policy("budget@...")`` or via ``ExitDecider.from_config``), call
    :meth:`fit` with held-out confidences (+ correctness) + the MAC prefix,
    and only then decide/serve with it.
    """

    name = "budget"

    def __init__(self, arg: str = ""):
        spec, _, mode = arg.partition(":")
        self.mac_budget = float(spec) if spec else None
        if mode not in ("", "shared", "solver"):
            raise ValueError(
                f"budget policy mode must be 'shared' or 'solver', "
                f"got {mode!r}")
        self.mode = mode or "solver"
        self.thresholds: Optional[Tuple[float, ...]] = None

    def resolve_thresholds(self, thresholds, explicit: bool = False):
        if explicit and thresholds is not None:
            if self.thresholds is not None:
                warnings.warn(
                    "BudgetPolicy has fitted thresholds AND explicit "
                    "thresholds were passed per call; honoring the per-call "
                    "override (drop one of the two to silence this)",
                    stacklevel=3)
            return thresholds
        if self.thresholds is None:
            raise RuntimeError(
                "BudgetPolicy has no fitted thresholds: call "
                "decider.policy.fit(calibration_confidences, mac_prefix) "
                "after construction (a budget@ config string alone cannot "
                "fit — fitting needs held-out confidences)")
        return self.thresholds

    @staticmethod
    def _warn_shared():
        global _SHARED_QUANTILE_WARNED
        if _SHARED_QUANTILE_WARNED:
            return
        _SHARED_QUANTILE_WARNED = True
        warnings.warn(
            "BudgetPolicy's shared-quantile fit is deprecated: the "
            "per-component solver (repro.autotune.solver.solve_budget) "
            "dominates it at equal budget.  Pass corrects= to fit() to "
            "use it, or spell budget@<macs>:shared to keep the legacy "
            "ablation behavior explicitly.",
            DeprecationWarning, stacklevel=4)

    def _fit_shared(self, conf, macs, budget, iters):
        """Legacy shared-quantile bisection (the ``:shared`` ablation)."""

        def avg_macs(q):
            ths = np.quantile(conf, q, axis=1)
            ths[-1] = 0.0
            idx = np.asarray(_first_open_gate(
                jnp.asarray(conf), ThresholdPolicy().gates(
                    jnp.asarray(conf), ths)))
            return float(macs[idx].mean()), tuple(float(t) for t in ths)

        lo, hi = 0.0, 1.0                      # q=0: all exit first; macs min
        best = avg_macs(0.0)
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            got, ths = avg_macs(mid)
            if abs(got - budget) < abs(best[0] - budget):
                best = (got, ths)
            if got > budget:                   # too much compute: exit more
                hi = mid
            else:
                lo = mid
        return best[1], best[0]

    def fit(self, confidences: Sequence[np.ndarray],
            mac_prefix: Sequence[float],
            mac_budget: Optional[float] = None,
            corrects: Optional[Sequence[np.ndarray]] = None,
            iters: int = 40, bins: int = 64) -> Tuple[float, ...]:
        """Calibrate thresholds so mean MACs <= mac_budget on
        ``confidences``.  With ``corrects`` (per-component correctness
        arrays) the per-component solver allocates the budget — including
        under the deprecated ``:shared`` alias, which only adds its
        one-time warning; without ``corrects`` the legacy shared quantile
        runs (deprecated)."""
        budget = self.mac_budget if mac_budget is None else mac_budget
        if budget is None:
            raise ValueError("no MAC budget given (budget@<float> or fit())")
        conf = np.stack([np.asarray(c, np.float64) for c in confidences])
        macs = np.asarray(mac_prefix, np.float64)
        budget = float(np.clip(budget, macs[0], macs[-1]))

        if corrects is None:
            # the per-component search needs correctness to rank
            # allocations — the legacy bisection is the only fallback
            self._warn_shared()
            self.thresholds, self.fitted_avg_macs = self._fit_shared(
                conf, macs, budget, iters)
            return self.thresholds
        if self.mode == "shared":
            # deprecated alias, NOT a separate fit anymore: it warns once
            # and routes through the solver like the default spelling —
            # seeded from the shared-quantile solution, so the result
            # provably spends <= its MACs at >= its agreement
            self._warn_shared()

        from repro.autotune.solver import (ExitHistogram,
                                           edges_from_thresholds,
                                           solve_budget)
        corr = np.stack([np.asarray(c, np.float64) for c in corrects])
        hist = ExitHistogram.from_samples(conf, corr, macs, bins)
        # seed with the (quantized) shared-quantile solution: coordinate
        # moves only improve, so the solver provably fits no worse
        shared_ths, _ = self._fit_shared(conf, macs, budget, iters)
        res = solve_budget(hist, budget,
                           init_edges=edges_from_thresholds(shared_ths,
                                                            bins))
        self.thresholds = res.thresholds
        self.fitted_avg_macs = res.avg_macs
        return self.thresholds


# ---------------------------------------------------------------------------
# calibrators (§5)
# ---------------------------------------------------------------------------

class Calibrator:
    """Per-component confidences + correctness → δ̂(ε) thresholds.

    ``val_confidences`` / ``val_corrects`` (optional, per-component arrays
    like the calibration set) are the paper's validation-set remark: when
    given, α*_m (and the target) still come from the calibration arrays,
    but each threshold is *selected* on the validation accuracy curve.
    """

    name = "base"

    def calibrate(self, confidences: Sequence[np.ndarray],
                  corrects: Sequence[np.ndarray],
                  epsilon: float,
                  val_confidences: Optional[Sequence[np.ndarray]] = None,
                  val_corrects: Optional[Sequence[np.ndarray]] = None
                  ) -> CalibrationResult:
        raise NotImplementedError

    def _run(self, confidences, corrects, epsilon, target,
             val_confidences=None, val_corrects=None):
        n_m = len(confidences)
        ths, stars = [], []
        for m in range(n_m):
            t, a = threshold_for_epsilon(
                confidences[m], corrects[m], epsilon, target=target,
                val_conf=(None if val_confidences is None
                          else val_confidences[m]),
                val_correct=(None if val_corrects is None
                             else val_corrects[m]))
            ths.append(0.0 if m == n_m - 1 else t)
            stars.append(a)
        return CalibrationResult(tuple(ths), tuple(stars), epsilon)


@register_calibrator("self")
class SelfCalibrator(Calibrator):
    """The paper's §5 rule: δ_m(ε) targets the component's OWN α*_m − ε.

    Conservative when an early component already matches the cascade: its own
    α* can sit far above the cascade's accuracy, blocking exits that would
    cost nothing (the paper's CIFAR-100 ε-gap).
    """

    name = "self"

    def __init__(self, arg: str = ""):
        del arg

    def calibrate(self, confidences, corrects, epsilon,
                  val_confidences=None, val_corrects=None):
        return self._run(confidences, corrects, epsilon, target=None,
                         val_confidences=val_confidences,
                         val_corrects=val_corrects)


@register_calibrator("final")
class FinalCalibrator(Calibrator):
    """Beyond-paper cascade-level rule: every component targets the FINAL
    component's realized accuracy − ε (the final component at threshold 0,
    NOT its α* — the max over δ would re-introduce the conservatism this
    rule removes).  Dominates ``self`` in speedup at equal ε on calibration
    data."""

    name = "final"

    def __init__(self, arg: str = ""):
        del arg

    def calibrate(self, confidences, corrects, epsilon,
                  val_confidences=None, val_corrects=None):
        alpha_final = float(np.mean(corrects[-1]))
        return self._run(confidences, corrects, epsilon, target=alpha_final,
                         val_confidences=val_confidences,
                         val_corrects=val_corrects)


@register_calibrator("holdout")
class HoldoutCalibrator(Calibrator):
    """§5 with the validation split the module docstring promises: α*_m is
    estimated on a statistics split, the threshold is then the smallest δ
    whose accuracy on a DISJOINT validation split clears α*_m − ε — so the
    same samples never both set the bar and certify a threshold against it.

    Spec: ``holdout`` (validation fraction 0.5), ``holdout@0.3`` (fraction),
    ``holdout@0.3:final`` (cascade-level target like FinalCalibrator).
    When the caller already has a separate validation set, pass it via
    ``val_confidences`` / ``val_corrects`` and no internal split happens.
    The internal split is deterministic and interleaved (every k-th sample
    goes to validation), so ordered calibration dumps split evenly.
    """

    name = "holdout"

    def __init__(self, arg: str = ""):
        frac, _, target = arg.partition(":")
        self.val_frac = float(frac) if frac else 0.5
        if not 0.0 < self.val_frac < 1.0:
            raise ValueError(
                f"holdout fraction must be in (0, 1), got {self.val_frac}")
        if target not in ("", "self", "final"):
            raise ValueError(f"holdout target must be 'self' or 'final', "
                             f"got {target!r}")
        self.target_mode = target or "self"

    def _split(self, arrays):
        stats, vals = [], []
        for a in arrays:
            a = np.asarray(a)
            n = len(a)
            n_val = max(1, min(n - 1, int(round(n * self.val_frac))))
            idx = np.zeros(n, bool)
            idx[np.round(np.linspace(0, n - 1, n_val)).astype(int)] = True
            stats.append(a[~idx])
            vals.append(a[idx])
        return stats, vals

    def calibrate(self, confidences, corrects, epsilon,
                  val_confidences=None, val_corrects=None):
        if val_confidences is None:
            confidences, val_confidences = self._split(confidences)
            corrects, val_corrects = self._split(corrects)
        target = (float(np.mean(corrects[-1]))
                  if self.target_mode == "final" else None)
        return self._run(confidences, corrects, epsilon, target=target,
                         val_confidences=val_confidences,
                         val_corrects=val_corrects)


# ---------------------------------------------------------------------------
# the one decision engine
# ---------------------------------------------------------------------------

def _first_open_gate(confs: jnp.ndarray, gates: jnp.ndarray) -> jnp.ndarray:
    """THE exit-selection scan: index of the first open gate per sample.

    gates (n_m, ...) bool with gates[-1] all-True; argmax over the component
    axis returns the first True.  Every exit decision in the repo funnels
    through this one line.
    """
    del confs  # shape companion; kept for symmetry/debuggability
    return jnp.argmax(gates, axis=0).astype(jnp.int32)


@dataclasses.dataclass
class ExitDecision:
    prediction: jnp.ndarray    # (...,) argmax of the answering component
    exit_index: jnp.ndarray    # (...,) int32 component that answered
    confidence: jnp.ndarray    # (...,) its confidence
    state: Optional[jnp.ndarray] = None   # stateful-measure carry


# a pytree, so decisions flow through jit/cond boundaries (staged executor)
jax.tree_util.register_dataclass(
    ExitDecision,
    data_fields=("prediction", "exit_index", "confidence", "state"),
    meta_fields=())


class ExitDecider:
    """The single, jit-compatible exit-decision implementation.

    Composes a :class:`ConfidenceMeasure` with an :class:`ExitPolicy`.
    Three entry points, one semantics:

    * :meth:`decide` — per-exit logits (serving / Algorithm 1), all at once.
    * the **component scan** (:meth:`scan_component` / :meth:`should_skip` /
      :meth:`finish_scan`) — the same decision fed one component at a time,
      which is what lets :class:`repro.core.exec.StagedExecutor` run each
      cascade segment under ``lax.cond`` and *skip the compute* of segments
      nobody needs.
    * :meth:`exit_indices` — precomputed confidences (the vectorized
      evaluation sweep).

    ``decide`` is implemented ON the component scan, including its
    skip-masked state updates (a skipped segment's patience streak does not
    advance), so fixed-graph ``select`` execution and segment-skipping
    ``cond_batch`` execution produce bit-identical decisions and carried
    state.
    """

    def __init__(self, measure, policy="threshold",
                 thresholds: Optional[Sequence[float]] = None,
                 use_kernels: bool = False,
                 kernel_interpret: Optional[bool] = None,
                 telemetry_bins: int = 0):
        self.measure = (get_measure(measure) if isinstance(measure, str)
                        else measure)
        self.policy = (get_policy(policy) if isinstance(policy, str)
                       else policy)
        self.thresholds = tuple(thresholds) if thresholds is not None else None
        self.use_kernels = use_kernels
        self.kernel_interpret = kernel_interpret
        # > 0 enables the autotune telemetry rider: every scan additionally
        # records each component's raw confidence bin / raw prediction /
        # reached-mask in the carry (repro.autotune.telemetry consumes it).
        # 0 keeps the carry — and thus every decode graph — byte-identical
        # to the pre-autotune program.
        self.telemetry_bins = int(telemetry_bins)

    @classmethod
    def from_config(cls, cfg) -> "ExitDecider":
        """Resolve a ModelConfig's cascade strings through the registries."""
        cas = cfg.cascade
        return cls(measure=cas.confidence, policy=cas.policy,
                   thresholds=cas.thresholds, use_kernels=cfg.use_kernels,
                   kernel_interpret=cfg.kernel_interpret,
                   telemetry_bins=(cfg.autotune.bins
                                   if cfg.autotune.enabled else 0))

    @property
    def fused_scan(self) -> bool:
        """Whether :meth:`scan_logits` may take the fused exit-update
        kernel: the caller opted into kernels, the measure bottoms out in
        softmax-max (Defs. 3.2/3.3 — ``softmax_max`` itself or
        ``patience@k`` over it), and the policy gates are the plain
        per-component threshold comparisons the kernel hard-codes
        (:class:`ThresholdPolicy` and subclasses; a fitted
        :class:`BudgetPolicy` qualifies because its thresholds resolve to
        static floats before the scan)."""
        if not self.use_kernels:
            return False
        base = getattr(self.measure, "base", self.measure)
        if getattr(base, "name", "") != "softmax_max":
            return False
        if self.measure.stateful and self.measure.name != "patience":
            return False
        return isinstance(self.policy, ThresholdPolicy)

    def init_state(self, batch: int, n_exits: Optional[int] = None):
        if n_exits is None:
            if self.thresholds is None:
                raise ValueError("n_exits needed when no thresholds are set")
            n_exits = len(self.thresholds)
        return self.measure.init_state(n_exits, batch)

    def resolved_thresholds(self, n_components: int,
                            thresholds: Optional[Sequence[float]] = None
                            ) -> Tuple[float, ...]:
        """The threshold vector the decision scan gates on: per-call
        ``thresholds`` (explicit override) > policy-owned fitted vector
        (BudgetPolicy) > the decider's configured vector.

        Normally a tuple of static floats (folded into the trace).  A jax
        array — the autotune live-threshold path, where thresholds are
        DATA carried in the DecodeState so a controller push never
        retraces — passes through as-is after a length check.
        """
        explicit = thresholds is not None
        if explicit and not isinstance(thresholds, jax.Array):
            thresholds = tuple(thresholds)
        ths = self.policy.resolve_thresholds(
            self.thresholds if thresholds is None else thresholds,
            explicit=explicit)
        if ths is None:
            raise ValueError(
                "no thresholds: configure them on the decider/config or "
                "pass them per call")
        if isinstance(ths, jax.Array):
            if ths.shape[0] != n_components:
                raise ValueError(f"{ths.shape[0]} thresholds for "
                                 f"{n_components} cascade components")
            return ths
        ths = tuple(float(t) for t in ths)
        if len(ths) != n_components:
            raise ValueError(f"{len(ths)} thresholds for {n_components} "
                             f"cascade components")
        return ths

    # -- logits path (serving, Algorithm 1) -----------------------------
    def measure_one(self, logits: jnp.ndarray):
        """(prediction, confidence) of ONE component (fused path if asked)."""
        if self.use_kernels:
            pair = self.measure.fused_kernel(logits,
                                             interpret=self.kernel_interpret)
            if pair is not None:
                return pair
        return self.measure(logits)

    def measure_all(self, logits_list: Sequence[jnp.ndarray]):
        """(outs, confs) stacked (n_m, ...) via the measure (fused if asked)."""
        pairs = [self.measure_one(lg) for lg in logits_list]
        return (jnp.stack([p[0] for p in pairs]),
                jnp.stack([p[1] for p in pairs]))

    # -- the component scan (staged execution's decision core) -----------
    def _init_carry(self, m: int, n_components: int, prediction, confidence,
                    state):
        """THE decision-scan carry layout, shared by the dense
        (:meth:`scan_component`) and fused (:meth:`scan_logits`) paths —
        one definition, so a new carry field cannot drift between them.

        ``prediction`` / ``confidence`` are shape/dtype templates for the
        per-sample leaves.  "ema"/"act" are the optional DecodeState rider
        ((B,) confidence EMA + active mask) the staged executor may seed so
        the final component's EMA fold can happen inside the scan (fused
        into the exit-update kernel on the fast path); None when the
        caller doesn't carry an EMA (eval sweep, decide()).
        """
        if m != 0:
            raise ValueError("a decision scan must start at component 0")
        streak = None
        if self.measure.stateful:
            streak = (state if state is not None else jnp.zeros(
                (n_components,) + confidence.shape, jnp.int32))
        carry = {
            "answered": jnp.zeros(confidence.shape, bool),
            "pred": jnp.zeros_like(prediction),
            "exit": jnp.zeros(confidence.shape, jnp.int32),
            "conf": jnp.zeros_like(confidence),
            "streak": streak,
            "ema": None,
            "act": None,
        }
        if self.telemetry_bins:
            # autotune telemetry rider: one packed
            # prediction/confidence-bin code row per component
            # (repro.autotune.telemetry.pack_rider).  Rows of skipped
            # segments stay zeroed (the accumulator masks them out via
            # the decision's exit index).  Riders never influence the
            # decision — only repro.autotune.telemetry reads them.
            carry["tcode"] = jnp.zeros(
                (n_components,) + confidence.shape, jnp.int32)
        return carry

    def scan_component(self, m: int, n_components: int,
                       prediction: jnp.ndarray, confidence: jnp.ndarray,
                       thresholds: Tuple[float, ...], carry=None,
                       state=None, batch_uniform: bool = False):
        """Feed component ``m``'s measured (prediction, confidence) into the
        running decision scan; returns the updated carry (a pytree of
        arrays, safe to thread through ``lax.cond``).

        ``carry=None`` starts the scan (m must be 0); ``state`` then seeds
        the stateful-measure carry (patience streaks).  The first open gate
        answers each sample, exactly as :func:`_first_open_gate` does on the
        stacked path.
        """
        gate = self.policy.component_gate(confidence, thresholds, m,
                                          n_components)
        if carry is None:
            carry = self._init_carry(m, n_components, prediction, confidence,
                                     state)
        streak = carry["streak"]
        if self.measure.stateful:
            row = jnp.where(gate, streak[m] + 1, 0)
            streak = streak.at[m].set(row)
            gate = row >= self.measure.patience_k
            if m == n_components - 1:
                gate = jnp.ones_like(gate)
        if batch_uniform:
            gate = jnp.broadcast_to(jnp.all(gate), gate.shape)
            if m == n_components - 1:
                gate = jnp.ones_like(gate)
        fresh = jnp.logical_and(gate, jnp.logical_not(carry["answered"]))
        out = {
            "answered": jnp.logical_or(carry["answered"], gate),
            "pred": jnp.where(fresh, prediction, carry["pred"]),
            "exit": jnp.where(fresh, jnp.int32(m), carry["exit"]),
            "conf": jnp.where(fresh, confidence, carry["conf"]),
            "streak": streak,
            "ema": carry.get("ema"),
            "act": carry.get("act"),
        }
        if carry.get("tcode") is not None:
            from repro.autotune.telemetry import pack_rider
            out["tcode"] = carry["tcode"].at[m].set(
                pack_rider(prediction, confidence, self.telemetry_bins))
        return out

    def fold_ema(self, carry, decay: float):
        """Fold the final decision confidence into the carry's "ema" rider
        (the :class:`~repro.core.exec.DecodeState` confidence EMA) — no-op
        when the caller didn't seed one.  Formula and operand order match
        the fused kernel's exactly, so the dense and fused paths produce
        bit-identical EMAs given identical confidences."""
        if carry.get("ema") is None:
            return carry
        new = dict(carry)
        ema = decay * carry["ema"] + (1.0 - decay) * carry["conf"]
        new["ema"] = (jnp.where(carry["act"], ema, carry["ema"])
                      if carry.get("act") is not None else ema)
        return new

    def scan_logits(self, m: int, n_components: int, logits: jnp.ndarray,
                    thresholds: Tuple[float, ...], carry=None, state=None,
                    batch_uniform: bool = False, ema_decay: float = 0.0):
        """Measure component ``m``'s logits AND fold them into the decision
        scan in one call.

        When :attr:`fused_scan` allows (2D logits, softmax-max-family
        measure, threshold-family policy), this takes the fused exit-update
        Pallas kernel: ONE streaming pass over the (B, V) logits computes
        the confidence (softmax never materialized), the threshold gate,
        the patience-streak rewrite and the carry merge — plus, when
        ``ema_decay > 0`` (callers pass it on the final component only),
        the DecodeState confidence-EMA fold.  Otherwise it is exactly
        :meth:`measure_one` + :meth:`scan_component` (+ :meth:`fold_ema`),
        so callers never branch on kernel availability.
        """
        fused = (self.fused_scan and not batch_uniform
                 and logits.ndim == 2)
        if not fused:
            out, conf = self.measure_one(logits)
            carry = self.scan_component(m, n_components, out, conf,
                                        thresholds, carry, state=state,
                                        batch_uniform=batch_uniform)
            return self.fold_ema(carry, ema_decay) if ema_decay else carry
        from repro.kernels.ops import exit_update_fused
        B = logits.shape[0]
        if carry is None:
            carry = self._init_carry(m, n_components,
                                     jnp.zeros((B,), jnp.int32),
                                     jnp.zeros((B,), jnp.float32), state)
        streak = carry["streak"]
        srow = streak[m] if streak is not None else jnp.zeros((B,), jnp.int32)
        has_ema = carry.get("ema") is not None
        ema = carry["ema"] if has_ema else jnp.zeros((B,), jnp.float32)
        act = (carry["act"] if carry.get("act") is not None
               else jnp.ones((B,), bool))
        # thresholds[m] is a static float (folded into the kernel body) or,
        # on the autotune live-threshold path, a traced scalar the kernel
        # reads as an operand — the wrapper picks the variant
        th_m = (thresholds[m] if isinstance(thresholds, jax.Array)
                else float(thresholds[m]))
        outs = exit_update_fused(
            logits, carry["answered"], carry["pred"], carry["exit"],
            carry["conf"], srow, ema, act,
            threshold=th_m, m=m, n_components=n_components,
            patience_k=(self.measure.patience_k if self.measure.stateful
                        else 0),
            ema_decay=(float(ema_decay) if has_ema else 0.0),
            tel_bins=self.telemetry_bins,
            interpret=self.kernel_interpret)
        ans, pred, exi, conf, srow_n, ema_n = outs[:6]
        new = {"answered": ans, "pred": pred, "exit": exi, "conf": conf,
               "streak": (streak.at[m].set(srow_n) if streak is not None
                          else None),
               "ema": ema_n if has_ema else None,
               "act": carry.get("act")}
        if carry.get("tcode") is not None:
            new["tcode"] = carry["tcode"].at[m].set(outs[6])
        return new

    def scan_hidden(self, m: int, n_components: int, h: jnp.ndarray,
                    norm_w: jnp.ndarray, head: jnp.ndarray,
                    thresholds, carry=None, state=None,
                    ema_decay: float = 0.0, live=None, eps: float = 1e-5):
        """:meth:`scan_logits` from the segment HIDDEN state: the
        per-segment megakernel route (rmsnorm + unembed matmul + streaming
        confidence + exit-update merge in one pallas_call — the (B, V)
        logits tensor never materializes in HBM).

        ``h`` (B, d); ``norm_w`` / ``head`` from
        :meth:`~repro.models.model.CascadeModel.exit_head_params` (callers
        fall back to ``exit_logits`` + :meth:`scan_logits` when that
        returns None — enhancement-MLP / layernorm-bias heads don't fit
        the fusion).  ``live`` additionally lifts the per-slot exit mask
        into the megakernel grid: fully-dead batch blocks skip the matmul,
        dead rows pass every carry through unchanged.  Requires
        :attr:`fused_scan`; tile sizes come from the autotune registry.
        """
        if not self.fused_scan:
            raise ValueError("scan_hidden requires a fused-scan decider "
                             "(use exit_logits + scan_logits instead)")
        from repro.kernels.ops import exit_head_fused
        B = h.shape[0]
        if carry is None:
            carry = self._init_carry(m, n_components,
                                     jnp.zeros((B,), jnp.int32),
                                     jnp.zeros((B,), jnp.float32), state)
        streak = carry["streak"]
        srow = streak[m] if streak is not None else jnp.zeros((B,), jnp.int32)
        has_ema = carry.get("ema") is not None
        ema = carry["ema"] if has_ema else jnp.zeros((B,), jnp.float32)
        act = (carry["act"] if carry.get("act") is not None
               else jnp.ones((B,), bool))
        th_m = (thresholds[m] if isinstance(thresholds, jax.Array)
                else float(thresholds[m]))
        outs = exit_head_fused(
            h, norm_w, head, carry["answered"], carry["pred"], carry["exit"],
            carry["conf"], srow, ema, act,
            threshold=th_m, m=m, n_components=n_components,
            patience_k=(self.measure.patience_k if self.measure.stateful
                        else 0),
            ema_decay=(float(ema_decay) if has_ema else 0.0),
            tel_bins=self.telemetry_bins, live=live, eps=eps,
            interpret=self.kernel_interpret)
        ans, pred, exi, conf, srow_n, ema_n = outs[:6]
        new = {"answered": ans, "pred": pred, "exit": exi, "conf": conf,
               "streak": (streak.at[m].set(srow_n) if streak is not None
                          else None),
               "ema": ema_n if has_ema else None,
               "act": carry.get("act")}
        if carry.get("tcode") is not None:
            new["tcode"] = carry["tcode"].at[m].set(outs[6])
        return new

    # carry keys laid out (n_components, batch, ...): slice/concat axis 1
    _COMPONENT_MAJOR_KEYS = frozenset(("streak", "tcode"))

    def slice_carry(self, carry, lo: int, hi: int):
        """Batch-slice a decision-scan carry (cohort-split execution).

        Lives here, next to the carry layout :meth:`scan_component`
        defines: per-sample leaves are batch-leading; the stateful-measure
        ``streak`` and the telemetry rider rows follow the
        :meth:`ConfidenceMeasure.init_state` contract
        ``(n_exits, batch, ...)`` and slice axis 1.
        """
        return {k: (v if v is None
                    else (v[:, lo:hi] if k in self._COMPONENT_MAJOR_KEYS
                          else v[lo:hi]))
                for k, v in carry.items()}

    def concat_carry(self, parts):
        """Inverse of :meth:`slice_carry`: rejoin per-cohort carries."""
        return {k: (None if parts[0][k] is None
                    else jnp.concatenate(
                        [p[k] for p in parts],
                        axis=1 if k in self._COMPONENT_MAJOR_KEYS else 0))
                for k in parts[0]}

    def should_skip(self, carry, active=None) -> jnp.ndarray:
        """Scalar bool: every live sample has already exited — the staged
        executor's segment-skip predicate, and decide()'s masked-update
        predicate (the identity that keeps both execution styles exact)."""
        answered = carry["answered"]
        if active is not None:
            answered = jnp.logical_or(answered, jnp.logical_not(active))
        return jnp.all(answered)

    def finish_scan(self, carry) -> ExitDecision:
        return ExitDecision(carry["pred"], carry["exit"], carry["conf"],
                            carry["streak"])

    def decide_with_carry(self, logits_list: Sequence[jnp.ndarray],
                          thresholds: Optional[Sequence[float]] = None,
                          state=None, batch_uniform: bool = False,
                          active=None):
        """:meth:`decide`, additionally returning the finished scan carry
        (the telemetry rider's home — ``StagedExecutor.prefill`` reads the
        raw per-component rows out of it)."""
        n_m = len(logits_list)
        ths = self.resolved_thresholds(n_m, thresholds)
        carry = None
        for m, lg in enumerate(logits_list):
            new = self.scan_logits(m, n_m, lg, ths, carry, state=state,
                                   batch_uniform=batch_uniform)
            if carry is None:
                carry = new
            else:
                skip = self.should_skip(carry, active)
                # decision/state leaves take the skip-masked update (the
                # identity with staged cond_batch execution); telemetry
                # rider rows always land — the logits were computed here
                # regardless, and riders never feed back into decisions
                carry = {
                    k: (v if v is None or k in self._COMPONENT_TEL_KEYS
                        else jnp.where(skip, carry[k], v))
                    for k, v in new.items()}
        return self.finish_scan(carry), carry

    _COMPONENT_TEL_KEYS = frozenset(("tcode",))

    def decide(self, logits_list: Sequence[jnp.ndarray],
               thresholds: Optional[Sequence[float]] = None,
               state=None, batch_uniform: bool = False,
               active=None) -> ExitDecision:
        """Pick the answering component for each sample.

        ``batch_uniform`` gives Algorithm 1's TPU whole-batch semantics: a
        component answers only when *every* sample in the batch is confident
        (the ``cond_batch`` skip condition).  ``state`` carries stateful
        measures (patience streaks) across decode steps; ``active`` masks
        finished lanes out of the skip predicate.

        Components a staged run would have skipped (everyone already exited)
        contribute no state updates here either — their streak rows stay
        put — so this fixed-graph path matches ``cond_batch`` exactly.
        """
        return self.decide_with_carry(logits_list, thresholds, state=state,
                                      batch_uniform=batch_uniform,
                                      active=active)[0]

    # -- precomputed-confidence path (evaluation sweep) ------------------
    def exit_indices(self, confidences: Sequence[np.ndarray],
                     thresholds: Optional[Sequence[float]] = None
                     ) -> np.ndarray:
        """Exit component per sample from precomputed confidences (n_m, N).

        Stateful measures (patience) depend on decode order and have no
        precomputed-confidence equivalent — use :meth:`decide` step by step.
        """
        if self.measure.stateful:
            raise NotImplementedError(
                f"measure {self.measure.name!r} is stateful; exit_indices "
                "cannot reproduce its decode-time gating — drive decide() "
                "instead")
        confs = jnp.asarray(np.stack([np.asarray(c) for c in confidences]))
        ths = self.policy.resolve_thresholds(
            self.thresholds if thresholds is None else tuple(thresholds),
            explicit=thresholds is not None)
        gates = self.policy.gates(confs, ths)
        return np.asarray(_first_open_gate(confs, gates))
