"""Ablation: confidence measures from the policy registry on one trained
cascade — softmax-max (the paper) vs entropy (BranchyNet [TMK16]) vs the
top-2 margin (IDK-cascade style).

The paper argues max-softmax (i) needs no extra training and (ii) trades
compute/accuracy at least as well.  The §5 calibration procedure is
measure-agnostic (it only needs a scalar confidence), so every registered
measure runs through the identical calibrate → evaluate pipeline; adding a
measure to this table is one ``@register_measure`` class.

Logits are measure-independent, so the forward pass runs ONCE per split
(``collect_logits``) and every measure scores the cached tensors
(``score_logits``) — the table costs one cascade evaluation, not one per row.
"""
from benchmarks._shared import N_CLASSES, trained_cascade
from repro.core.cascade import cascade_evaluate
from repro.core.macs import resnet_component_macs
from repro.core.policy import get_calibrator
from repro.core.resnet_trainer import collect_logits, score_logits

MEASURES = ("softmax_max", "entropy", "margin")


def run():
    model, report, (train, val, test) = trained_cascade()
    mac_prefix = resnet_component_macs(model.n, N_CLASSES,
                                       enhance_dim=model.enhance_dim)
    calibrator = get_calibrator("self")
    # one forward pass per split; measures score the cached logits
    logits_v = collect_logits(model, report.params, report.state, val)
    logits_t = collect_logits(model, report.params, report.state, test)
    rows = []
    for name in MEASURES:
        conf_v, _, corr_v = score_logits(logits_v, val.labels, measure=name)
        conf_t, pred_t, _ = score_logits(logits_t, test.labels, measure=name)
        for eps in (0.01, 0.05):
            cal = calibrator.calibrate(conf_v, corr_v, eps)
            res = cascade_evaluate(conf_t, pred_t, test.labels, mac_prefix,
                                   cal.thresholds)
            rows.append((
                f"ablation/eps={eps:g}/{name}", 0.0,
                f"acc={res.accuracy:.4f};speedup={res.speedup:.3f}"))
    return rows
